"""Docs rot check: internal links resolve, code references import.

Run from the repo root (CI docs job / tests/test_docs.py):

    PYTHONPATH=src python tools/check_docs.py

Checks over ``README.md`` and ``docs/*.md``:

1. every relative markdown link ``[text](path)`` points at an existing
   file (external ``http``/``mailto`` links and pure anchors are skipped);
2. every inline-code repo path (a backticked token containing ``/``)
   exists on disk;
3. every inline-code dotted reference into the package (``repro.x.y`` or
   a known subpackage like ``ml.trainer.make_fused_epoch``) imports, and
   trailing attributes resolve via ``getattr`` — so renaming an API
   breaks the docs build, not the reader.
"""

from __future__ import annotations

import importlib
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

#: exit codes, one per failure class (CI and scripts key off these):
#: 0 clean; 1 broken markdown link; 2 missing inline repo path;
#: 3 unresolvable dotted code reference; 4 missing doc file.  With
#: mixed classes the smallest non-zero wins.  The last stdout line is
#: always a machine-readable JSON summary.
EXIT_CODES = {"ok": 0, "link": 1, "path": 2, "ref": 3, "missing": 4}

#: first segments that implicitly root at ``repro.``
_SUBPACKAGES = ("core", "ml", "sim", "parallel", "analysis", "launch",
                "kernels", "train", "serve", "models", "configs", "data",
                "insitu")

_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_RE = re.compile(r"`([^`\n]+)`")
_DOTTED_RE = re.compile(r"^[A-Za-z_][\w.]*$")


def doc_files() -> list[Path]:
    return [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]


def check_links(path: Path, text: str,
                errors: list[tuple[str, str]]) -> None:
    for m in _LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        if not (path.parent / target).exists():
            errors.append(("link", f"{path.name}: broken link -> {target}"))


def _strip_fences(text: str) -> str:
    """Remove fenced code blocks (their contents are examples, not
    references — the inline-code checks below would false-positive on
    shell flags and JSON)."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def check_inline_code(path: Path, text: str,
                      errors: list[tuple[str, str]]) -> None:
    for m in _CODE_RE.finditer(_strip_fences(text)):
        token = m.group(1).split()[0] if m.group(1).split() else ""
        if not token or any(c in token for c in "{}<>*$\"'"):
            continue
        if "/" in token:
            if not (REPO / token).exists():
                errors.append(("path",
                               f"{path.name}: missing repo path -> {token}"))
            continue
        if "." in token and _DOTTED_RE.match(token):
            root = token.split(".", 1)[0]
            if root == "repro":
                dotted = token
            elif root in _SUBPACKAGES:
                dotted = "repro." + token
            else:
                continue
            err = _resolve_dotted(dotted)
            if err:
                errors.append(("ref", f"{path.name}: {err} "
                               f"(from `{token}`)"))


def _resolve_dotted(dotted: str) -> str | None:
    """Import the longest module prefix of ``dotted``, then getattr the
    rest.  Returns an error string or None."""
    parts = dotted.split(".")
    mod, idx = None, 0
    for i in range(len(parts), 0, -1):
        try:
            mod = importlib.import_module(".".join(parts[:i]))
            idx = i
            break
        except ImportError:
            continue
    if mod is None:
        return f"cannot import any prefix of {dotted}"
    obj = mod
    for attr in parts[idx:]:
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            return f"{'.'.join(parts[:idx])} has no attribute " \
                   f"{'.'.join(parts[idx:])}"
    return None


def main() -> int:
    import json
    errors: list[tuple[str, str]] = []
    for path in doc_files():
        if not path.exists():
            errors.append(("missing",
                           f"missing doc file: {path.relative_to(REPO)}"))
            continue
        text = path.read_text()
        check_links(path, text, errors)
        check_inline_code(path, text, errors)
    counts = {kind: sum(1 for k, _ in errors if k == kind)
              for kind in ("link", "path", "ref", "missing")}
    if errors:
        print("docs check FAILED:")
        for kind, e in errors:
            print(f" - [{kind}]", e)
        code = min(EXIT_CODES[k] for k, _ in errors)
    else:
        print(f"docs check OK ({len(doc_files())} files)")
        code = EXIT_CODES["ok"]
    print(json.dumps({"tool": "check_docs", "exit_code": code,
                      "status": "ok" if code == 0 else "failed",
                      **counts}, sort_keys=True))
    return code


if __name__ == "__main__":
    sys.exit(main())
