#!/usr/bin/env python
"""repro-lint runner: static analysis + collective budgets + type check.

Usage (from the repo root)::

    python tools/run_static_analysis.py              # full pass
    python tools/run_static_analysis.py --list-rules # stable rule table
    python tools/run_static_analysis.py --no-budget  # AST rules only

Phases:

1. AST rules over ``src/repro`` and ``tools`` (lock discipline, trace
   safety, verb parity) — see ``tools/lint/``.
2. Collective-budget manifest: compiles the tiny tier grid with
   ``plan(hlo=True)`` and checks measured collective counts against
   ``tools/lint/budgets.py`` (skippable with ``--no-budget``; needs jax).
3. mypy over ``core/``, ``insitu/`` and ``tools/`` per ``mypy.ini`` —
   skipped with a note when mypy is not installed (CI installs it).

Exit codes: 0 clean, 1 lint findings, 2 budget violations, 3 internal
error, 4 type-check failures.  The last stdout line is a JSON summary
(``{"tool": "repro-lint", ...}``) for CI aggregation.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))
sys.path.insert(0, str(ROOT / "src"))

from lint.engine import all_rules, lint_tree  # noqa: E402

#: Phases that are not AST rules but still have stable ids so they can
#: be listed, suppressed in CI config, and documented alongside rules.
EXTRA_PHASES = (
    ("budget-collective",
     "per-tier collective counts stay within the declarative manifest "
     "(tools/lint/budgets.py), measured on compiled HLO"),
    ("type-check",
     "mypy passes over core/, insitu/ and tools/ per mypy.ini"),
)


def list_rules() -> None:
    rows = [(r.id, r.summary) for r in all_rules()]
    rows.extend(EXTRA_PHASES)
    for rid, summary in sorted(rows):
        print(f"{rid:20s} {summary}")


def run_mypy() -> str:
    """Run mypy when available.  Returns 'ok', 'failed' or 'skipped'."""
    if shutil.which("mypy") is None:
        try:
            import mypy  # noqa: F401
        except ImportError:
            print("type-check: mypy not installed — skipped "
                  "(the static-analysis CI job installs and runs it)")
            return "skipped"
    proc = subprocess.run(
        [sys.executable, "-m", "mypy", "--config-file",
         str(ROOT / "mypy.ini")],
        cwd=ROOT, capture_output=True, text=True)
    if proc.stdout:
        print(proc.stdout, end="")
    if proc.returncode != 0:
        if proc.stderr:
            print(proc.stderr, end="", file=sys.stderr)
        return "failed"
    return "ok"


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--list-rules", action="store_true",
                        help="print the stable rule table and exit")
    parser.add_argument("--no-budget", action="store_true",
                        help="skip the compiled collective-budget phase")
    parser.add_argument("--no-mypy", action="store_true",
                        help="skip the type-check phase")
    parser.add_argument("--root", default=str(ROOT),
                        help="repo root to analyse")
    args = parser.parse_args(argv)

    if args.list_rules:
        list_rules()
        return 0

    root = pathlib.Path(args.root)
    summary: dict = {"tool": "repro-lint", "status": "ok",
                     "findings": 0, "budget_violations": 0,
                     "type_check": "skipped"}

    findings = lint_tree(root)
    for f in findings:
        print(f)
    summary["findings"] = len(findings)

    budget_violations = []
    if not args.no_budget:
        from lint.budgets import check_budgets
        budget_violations = check_budgets()
        for f in budget_violations:
            print(f)
        summary["budget_violations"] = len(budget_violations)

    if not args.no_mypy:
        summary["type_check"] = run_mypy()

    code = 0
    if findings:
        code = 1
    elif budget_violations:
        code = 2
    elif summary["type_check"] == "failed":
        code = 4
    summary["status"] = "ok" if code == 0 else "fail"
    print(json.dumps(summary, sort_keys=True))
    return code


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception as exc:  # noqa: BLE001 — runner boundary
        print(f"repro-lint internal error: {exc!r}", file=sys.stderr)
        print(json.dumps({"tool": "repro-lint", "status": "error",
                          "error": repr(exc)}))
        sys.exit(3)
