"""Bench regression gate: fresh ``BENCH_*.json`` vs the committed trajectory.

Run from the repo root (CI bench-smoke job):

    PYTHONPATH=src python -m benchmarks.run --json --smoke --json-dir out
    python tools/check_bench.py --fresh-dir out

Checks ``BENCH_fused_pipeline.json`` (the session-API pipeline bench):

1. **Structural** (hardware-independent, hard):
   * fused consumer ``store_dispatches_per_epoch`` must stay <= 1.0 — the
     one-dispatch-epoch invariant;
   * fused producer ``dispatches_per_step`` must not exceed the committed
     value — chunking must not silently shrink.
2. **Performance** (vs the committed numbers, tolerance ``--tol``,
   default 0.2 = fail on >20% regression): fused producer steps/s.
   Raw throughput is hardware-dependent; on machines unlike the one that
   committed the baseline, gate on the producer fused/per-verb *speedup
   ratio* instead with ``--ratios-only`` (still catches the fused tier
   losing its edge).  The consumer side is gated structurally only —
   its epoch is dominated by real SGD compute, so its wall-clock is not
   a dispatch-overhead signal.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EPS = 1e-9


def _load(path: Path) -> dict:
    if not path.exists():
        raise SystemExit(f"check_bench: missing {path}")
    return json.loads(path.read_text())


def check_fused_pipeline(base: dict, fresh: dict, tol: float,
                         ratios_only: bool) -> list[str]:
    errors: list[str] = []

    # -- structural invariants --------------------------------------------
    d_epoch = fresh["consumer"]["fused"]["store_dispatches_per_epoch"]
    if d_epoch > 1.0 + EPS:
        errors.append(
            f"fused consumer store_dispatches_per_epoch regressed to "
            f"{d_epoch} (> 1.0): the one-dispatch epoch broke")
    d_step_base = base["producer"]["fused"]["dispatches_per_step"]
    d_step = fresh["producer"]["fused"]["dispatches_per_step"]
    if d_step > d_step_base + EPS:
        errors.append(
            f"fused producer dispatches_per_step regressed: "
            f"{d_step} > committed {d_step_base}")

    # -- performance ------------------------------------------------------
    def perf(name: str, b: float, f: float):
        if f < (1.0 - tol) * b:
            errors.append(
                f"{name} regressed >{tol:.0%}: {f:.2f} vs committed "
                f"{b:.2f}")

    if ratios_only:
        perf("producer fused/per-verb speedup",
             base["producer"]["speedup"], fresh["producer"]["speedup"])
    else:
        perf("producer fused steps/s",
             base["producer"]["fused"]["steps_per_s"],
             fresh["producer"]["fused"]["steps_per_s"])
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default="out",
                    help="directory holding the freshly measured "
                         "BENCH_*.json files")
    ap.add_argument("--baseline-dir", default=str(REPO),
                    help="directory holding the committed trajectory")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional perf regression (default 0.2)")
    ap.add_argument("--ratios-only", action="store_true",
                    help="gate on tier speedup ratios instead of raw "
                         "throughput (for hardware unlike the baseline's)")
    args = ap.parse_args()

    base = _load(Path(args.baseline_dir) / "BENCH_fused_pipeline.json")
    fresh = _load(Path(args.fresh_dir) / "BENCH_fused_pipeline.json")
    errors = check_fused_pipeline(base, fresh, args.tol, args.ratios_only)
    if errors:
        print("bench check FAILED:")
        for e in errors:
            print(" -", e)
        return 1
    print("bench check OK (BENCH_fused_pipeline.json within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
