"""Bench regression gate: fresh ``BENCH_*.json`` vs the committed trajectory.

Run from the repo root (CI bench-smoke job):

    PYTHONPATH=src python -m benchmarks.run --json --smoke --json-dir out
    python tools/check_bench.py --fresh-dir out

Checks ``BENCH_fused_pipeline.json`` (the session-API pipeline bench),
``BENCH_sharded_epoch.json`` (the sharded-epoch / data-plane-entry bench),
``BENCH_weak_scaling.json`` (the fig5 clustered fan-in sweep),
``BENCH_serving.json`` (the continuous-batching serving cells) and
``BENCH_turbulence.json`` (the halo-exchange sharded-producer cells):

1. **Structural** (hardware-independent, hard):
   * fused consumer ``store_dispatches_per_epoch`` must stay <= 1.0 — the
     one-dispatch-epoch invariant;
   * fused producer ``dispatches_per_step`` must not exceed the committed
     value — chunking must not silently shrink.
2. **Performance** (vs the committed numbers, tolerance ``--tol``,
   default 0.2 = fail on >20% regression): fused producer steps/s.
   Raw throughput is hardware-dependent; on machines unlike the one that
   committed the baseline, gate with ``--ratios-only`` instead: the
   producer fused/per-verb speedup must stay an order of magnitude
   (>= 10x).  An absolute floor, not a trajectory delta, because the
   per-verb denominator is host-dispatch-bound and swings severalfold
   with machine load (90-270x observed on one box), while the claim
   worth defending — fused capture amortizes dispatch — lives at the
   10x+ scale.  The consumer side is gated structurally only —
   its epoch is dominated by real SGD compute, so its wall-clock is not
   a dispatch-overhead signal.

For the sharded-epoch bench the gates are the data-plane claims:

* **Structural** (hard): every cell's ``dispatches_per_epoch`` <= 1.0;
  the slab-sharded entry's compiled epoch has ZERO table all-gathers and
  its per-device entry bytes shrink by the mesh factor
  (``entry_bytes_ratio == mesh``).
* **Performance** (absolute band): the slab-sharded vs replicated
  ``epochs_per_s_ratio`` — measured between two same-profile cells of
  the same run, so hardware-comparable — must stay above
  ``1 - 2*tol`` (default 0.6): pre-sharding the table must not cost
  meaningful throughput.  An absolute floor, not a trajectory delta:
  on a time-sliced CPU the two subprocess timings carry ±20-25% noise,
  so the true ~1.0 ratio would flake against any committed value.

For the weak-scaling bench the gates are the clustered data-plane claims:

* **Structural** (hard): every fan-in cell (overlap sweep AND the
  serial baseline) performs exactly ONE cross-mesh staged transfer per
  ``capture_scan`` chunk (``staged_per_chunk == 1.0``), the measured
  ``staged_transfers`` / ``op_count`` equal the plan's predictions —
  the fused clustered producer must never degrade back to per-element
  hops — and overlap cells show exactly ``chunks + 1`` dispatches (the
  one capture-end drain; more means the two-slot pipeline is flushing
  early).
* **Performance** (same-run bands, like fig10): the highest:lowest
  fan-in ``throughput_ratio`` must stay above ``1 - 2*tol`` — producer
  work is identical across cells, so a collapsing ratio means the
  fan-in path started paying per-element costs; the overlap:serial
  ratio at the most contended cell must stay above the same floor (the
  pipeline must never cost throughput); and the fitted contention
  model must both fit (``fit_residual <= 2*tol``) and predict each
  cell's throughput within the same band — ``plan.explain()``'s
  ``predicted_steps_per_s`` is only honest while that holds.

For the serving bench the gates are the serving-plane claims:

* **Structural** (hard): every continuous-batching cell costs exactly
  ONE store dispatch per drained batch (``dispatches_per_batch ==
  1.0``), its measured ``op_count`` and ``model_swaps`` equal the
  plan's predictions, and the hot-swap microbenchmark adopted every
  published generation.
* **Performance** (same-run band): the continuous-vs-three-step
  ``throughput_ratio`` at the widest client count must stay above
  ``1 - 2*tol`` — batched serving must not degrade back to
  per-request dispatch costs.

For the turbulence bench the gates are the sharded-producer claims:

* **Structural** (hard): every space-shard cell performs exactly ONE
  staged transfer per chunk with measured counters equal to the plan's
  predictions, and the snapshot that went THROUGH the store obeys the
  physics (energy decays, projected divergence stays small) — with the
  final energy and divergence agreeing across shard counts, i.e. the
  halo exchange reproduces the unsharded stencil.
* **Performance** (same-run band): the per-device-normalized
  sharded:unsharded ``throughput_ratio`` must stay above
  ``0.5*(1 - 2*tol)`` — the extra 2x headroom absorbs the CPU
  device-emulation noise of short smoke cells; the gate catches a
  sharded put that collapses into per-step gathers, not drift.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EPS = 1e-9

#: message prefix marking a performance-band failure (as opposed to a
#: structural-invariant failure) — drives the exit-code classes below
PERF = "perf: "

#: exit codes, one per failure class (CI and scripts key off these):
#: 0 clean; 1 structural invariant broken; 2 performance regression
#: only; 3 missing/unreadable bench input.  The last stdout line is
#: always a machine-readable JSON summary.
EXIT_OK, EXIT_STRUCTURAL, EXIT_PERF, EXIT_MISSING = 0, 1, 2, 3


class MissingInput(Exception):
    pass


def _load(path: Path) -> dict:
    if not path.exists():
        raise MissingInput(f"missing {path}")
    try:
        return json.loads(path.read_text())
    except ValueError as e:
        raise MissingInput(f"unreadable {path}: {e}") from e


def check_fused_pipeline(base: dict, fresh: dict, tol: float,
                         ratios_only: bool) -> list[str]:
    errors: list[str] = []

    # -- structural invariants --------------------------------------------
    d_epoch = fresh["consumer"]["fused"]["store_dispatches_per_epoch"]
    if d_epoch > 1.0 + EPS:
        errors.append(
            f"fused consumer store_dispatches_per_epoch regressed to "
            f"{d_epoch} (> 1.0): the one-dispatch epoch broke")
    d_step_base = base["producer"]["fused"]["dispatches_per_step"]
    d_step = fresh["producer"]["fused"]["dispatches_per_step"]
    if d_step > d_step_base + EPS:
        errors.append(
            f"fused producer dispatches_per_step regressed: "
            f"{d_step} > committed {d_step_base}")

    # -- performance ------------------------------------------------------
    def perf(name: str, b: float, f: float):
        if f < (1.0 - tol) * b:
            errors.append(
                PERF + f"{name} regressed >{tol:.0%}: {f:.2f} vs committed "
                f"{b:.2f}")

    if ratios_only:
        # the per-verb denominator is host-dispatch-bound and swings
        # severalfold with machine load (90-270x observed on one box),
        # so a vs-committed tolerance flakes; the claim worth gating is
        # order-of-magnitude: fused capture must keep amortizing dispatch
        s = fresh["producer"]["speedup"]
        if s < 10.0:
            errors.append(
                PERF + f"producer fused/per-verb speedup collapsed to {s:.2f}x "
                "(< 10x): fused capture no longer amortizes dispatch")
    else:
        perf("producer fused steps/s",
             base["producer"]["fused"]["steps_per_s"],
             fresh["producer"]["fused"]["steps_per_s"])
    return errors


def check_sharded_epoch(base: dict, fresh: dict, tol: float) -> list[str]:
    errors: list[str] = []

    # -- structural invariants --------------------------------------------
    for cell in fresh["cells"]:
        if cell["dispatches_per_epoch"] > 1.0 + EPS:
            errors.append(
                f"fig10 mesh={cell['mesh']} entry={cell['entry']}: "
                f"dispatches_per_epoch regressed to "
                f"{cell['dispatches_per_epoch']} (> 1.0)")
    cmp = fresh.get("entry_comparison")
    if cmp is None:
        errors.append("fig10: no replicated-vs-slab-sharded entry cells "
                      "at a shared mesh size (entry_comparison missing)")
        return errors
    if cmp["slab_entry_all_gather"] != 0:
        errors.append(
            f"fig10: slab-sharded entry compiled with "
            f"{cmp['slab_entry_all_gather']} all-gather op(s) — the table "
            f"is being gathered on entry")
    if cmp["slab_entry_all_reduce"] < 1:
        errors.append(
            "fig10: slab-sharded entry shows no all-reduce — the explicit "
            "batch-assembly psum / DDP sync is gone from the epoch")
    if cmp["entry_bytes_ratio"] < cmp["mesh"] - EPS:
        errors.append(
            f"fig10: per-device entry bytes ratio {cmp['entry_bytes_ratio']}"
            f" < mesh factor {cmp['mesh']} — the slab no longer shards")

    # -- performance (same-run, same-hardware cell pair; absolute band) ---
    del base  # structural + band checks only; see module docstring
    floor = 1.0 - 2.0 * tol
    if cmp["epochs_per_s_ratio"] < floor:
        errors.append(
            PERF + f"fig10 slab/replicated epochs_per_s ratio "
            f"{cmp['epochs_per_s_ratio']:.3f} below floor {floor:.2f}: "
            f"the slab-sharded entry is costing real throughput")
    return errors


def check_weak_scaling(fresh: dict, tol: float) -> list[str]:
    """Every fig5 gate is same-run (structural counts + the fan-in band
    measured between cells of one sweep), so no committed baseline is
    read — ``BENCH_weak_scaling.json`` at the repo root is the perf
    trajectory record, not a gate input."""
    errors: list[str] = []

    # -- structural invariants (hard) -------------------------------------
    serial = fresh.get("serial_baseline")
    for cell in fresh["cells"] + ([serial] if serial else []):
        where = f"fig5 fan_in={cell['fan_in']}"
        if cell.get("overlap"):
            where += " (overlap)"
        if abs(cell["staged_per_chunk"] - 1.0) > EPS:
            errors.append(
                f"{where}: staged transfers per chunk = "
                f"{cell['staged_per_chunk']} (!= 1.0): the clustered "
                f"fused put degraded from one reshard per chunk")
        if cell["staged_transfers"] != cell["predicted_staged"]:
            errors.append(
                f"{where}: measured staged_transfers "
                f"{cell['staged_transfers']} != plan prediction "
                f"{cell['predicted_staged']}")
        if cell["op_count"] != cell["predicted_ops"]:
            errors.append(
                f"{where}: measured op_count {cell['op_count']} != plan "
                f"prediction {cell['predicted_ops']}")
        # the overlap pipeline's drain shows up as exactly one dispatch
        # beyond the chunk count — anything more means restage churn
        if cell.get("overlap") and cell["op_count"] != cell["chunks"] + 1:
            errors.append(
                f"{where}: op_count {cell['op_count']} != chunks "
                f"{cell['chunks']} + 1 drain: the two-slot pipeline is "
                f"flushing more than its end-of-capture drain")

    # -- performance (same-run, same-hardware cell pairs; absolute band) --
    floor = 1.0 - 2.0 * tol
    cmp = fresh.get("fanin_comparison")
    if cmp is None:
        errors.append("fig5: no fan-in sweep pair (fanin_comparison "
                      "missing)")
        return errors
    if cmp["throughput_ratio"] < floor:
        errors.append(
            PERF + f"fig5 fan-in {cmp['fan_in_hi']}:{cmp['fan_in_lo']} "
            f"throughput ratio {cmp['throughput_ratio']:.3f} below floor "
            f"{floor:.2f}: clustered staging is paying per-element costs")
    ocmp = fresh.get("overlap_comparison")
    if ocmp is None:
        errors.append("fig5: no overlap-vs-serial pair "
                      "(overlap_comparison missing)")
    elif ocmp["throughput_ratio"] < floor:
        errors.append(
            PERF + f"fig5 overlap/serial throughput ratio "
            f"{ocmp['throughput_ratio']:.3f} at fan_in={ocmp['fan_in']} "
            f"below floor {floor:.2f}: the two-slot staging pipeline is "
            f"costing throughput vs serial stage-then-insert")

    # -- contention model (fit quality + per-cell prediction band) --------
    model = fresh.get("contention_model")
    if model is None:
        errors.append("fig5: no fitted contention model "
                      "(contention_model missing — sweep < 2 fan-in "
                      "points?)")
        return errors
    band = 2.0 * tol
    if model["fit_residual"] > band:
        errors.append(
            PERF + f"fig5: contention-model fit residual "
            f"{model['fit_residual']:.3f} > {band:.2f}: steps/s vs "
            f"fan-in is no longer linear enough for plan.explain() to "
            f"predict throughput from")
    for cell in fresh["cells"]:
        pred = cell.get("predicted_steps_per_s")
        if pred is None:
            errors.append(
                f"fig5 fan_in={cell['fan_in']}: no predicted_steps_per_s "
                f"(model predictions not folded into the sweep)")
            continue
        err = abs(pred / cell["steps_per_s"] - 1.0)
        if err > band:
            errors.append(
                PERF + f"fig5 fan_in={cell['fan_in']}: plan-predicted "
                f"throughput {pred:.1f} steps/s is {err:.1%} from "
                f"measured {cell['steps_per_s']:.1f} (band {band:.0%})")
    return errors


def check_turbulence(fresh: dict, tol: float) -> list[str]:
    """Every turbulence gate is same-run (structural counts, physics
    invariants of the stored snapshots, and the shard-sweep band
    measured between cells of one sweep), so no committed baseline is
    read — ``BENCH_turbulence.json`` at the repo root is the perf
    trajectory record, not a gate input."""
    errors: list[str] = []

    # -- structural invariants (hard) -------------------------------------
    for cell in fresh["cells"]:
        where = f"turbulence shards={cell['space_shards']}"
        if abs(cell["staged_per_chunk"] - 1.0) > EPS:
            errors.append(
                f"{where}: staged transfers per chunk = "
                f"{cell['staged_per_chunk']} (!= 1.0): the element-"
                f"sharded put degraded from one reshard per chunk")
        if cell["staged_transfers"] != cell["predicted_staged"]:
            errors.append(
                f"{where}: measured staged_transfers "
                f"{cell['staged_transfers']} != plan prediction "
                f"{cell['predicted_staged']}")
        if cell["op_count"] != cell["predicted_ops"]:
            errors.append(
                f"{where}: measured op_count {cell['op_count']} != plan "
                f"prediction {cell['predicted_ops']}")
        # physics of the snapshot that went THROUGH the store
        if cell["energy_final"] >= cell["energy_initial"]:
            errors.append(
                f"{where}: kinetic energy grew "
                f"({cell['energy_initial']} -> {cell['energy_final']}): "
                f"the viscous decay is wrong or the stored snapshot is "
                f"stale")
        if cell["divergence_max"] > 0.05:
            errors.append(
                f"{where}: max divergence {cell['divergence_max']} > "
                f"0.05: the projection (or the halo feeding it) broke")

    cmp = fresh.get("shards_comparison")
    if cmp is None:
        errors.append("turbulence: no shard sweep pair "
                      "(shards_comparison missing)")
        return errors
    # sharding must not CHANGE the physics — same grid, same init, so
    # the stored snapshots must agree across shard counts (fp32 halo
    # vs. padded reference is exact; allow accumulation-order slack)
    if cmp["energy_final_spread"] > 1e-4:
        errors.append(
            f"turbulence: final energy differs by "
            f"{cmp['energy_final_spread']} between "
            f"{cmp['shards_lo']}- and {cmp['shards_hi']}-shard cells: "
            f"the halo exchange is not reproducing the reference "
            f"stencil")
    if cmp["divergence_spread"] > 1e-4:
        errors.append(
            f"turbulence: max divergence differs by "
            f"{cmp['divergence_spread']} between shard counts")

    # -- performance (same-run, same-hardware cell pair; absolute band) ---
    # One core serializes all simulated devices, so the per-device
    # normalized ratio is the meaningful one; even that carries the
    # subprocess-timing noise of short smoke cells, so the floor gets an
    # extra 2x headroom — the gate catches collapse (an accidental
    # gather per step), not drift.
    floor = 0.5 * (1.0 - 2.0 * tol)
    if cmp["throughput_ratio_per_device"] < floor:
        errors.append(
            PERF + f"turbulence shards {cmp['shards_hi']}:{cmp['shards_lo']} "
            f"per-device throughput ratio "
            f"{cmp['throughput_ratio_per_device']:.3f} below floor "
            f"{floor:.2f}: the sharded producer is paying per-step "
            f"collective costs beyond the halo exchange")
    return errors


def check_serving(fresh: dict, tol: float) -> list[str]:
    """Every serving gate is same-run (structural counters + the
    tier-comparison band measured inside one sweep), so no committed
    baseline is read — ``BENCH_serving.json`` at the repo root is the
    perf trajectory record, not a gate input."""
    errors: list[str] = []

    # -- structural invariants (hard) -------------------------------------
    for cell in fresh["cells"]:
        where = f"serving clients={cell['clients']}"
        if abs(cell["dispatches_per_batch"] - 1.0) > EPS:
            errors.append(
                f"{where}: store dispatches per drained batch = "
                f"{cell['dispatches_per_batch']} (!= 1.0): the fused "
                f"gather → model → scatter drain degraded")
        if cell["op_count"] != cell["predicted_ops"]:
            errors.append(
                f"{where}: measured op_count {cell['op_count']} != plan "
                f"prediction {cell['predicted_ops']}")
        if cell["model_swaps"] != cell["predicted_swaps"]:
            errors.append(
                f"{where}: measured model_swaps {cell['model_swaps']} != "
                f"plan prediction {cell['predicted_swaps']}")
    swap = fresh.get("swap")
    if not swap or swap.get("adoptions", 0) < 1:
        errors.append("serving: hot-swap microbenchmark adopted no "
                      "published generation")

    # -- performance (same-run, same-hardware cell pair; absolute band) ---
    cmp = fresh.get("tier_comparison")
    if cmp is None:
        errors.append("serving: no continuous-vs-three-step pair "
                      "(tier_comparison missing)")
        return errors
    floor = 1.0 - 2.0 * tol
    if cmp["throughput_ratio"] < floor:
        errors.append(
            PERF + f"serving clients={cmp['clients']} continuous/three-step "
            f"throughput ratio {cmp['throughput_ratio']:.3f} below floor "
            f"{floor:.2f}: continuous batching is paying per-request "
            f"costs")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh-dir", default="out",
                    help="directory holding the freshly measured "
                         "BENCH_*.json files")
    ap.add_argument("--baseline-dir", default=str(REPO),
                    help="directory holding the committed trajectory")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional perf regression (default 0.2)")
    ap.add_argument("--ratios-only", action="store_true",
                    help="gate on tier speedup ratios instead of raw "
                         "throughput (for hardware unlike the baseline's)")
    args = ap.parse_args()

    base = _load(Path(args.baseline_dir) / "BENCH_fused_pipeline.json")
    fresh = _load(Path(args.fresh_dir) / "BENCH_fused_pipeline.json")
    errors = check_fused_pipeline(base, fresh, args.tol, args.ratios_only)
    errors += check_sharded_epoch(
        _load(Path(args.baseline_dir) / "BENCH_sharded_epoch.json"),
        _load(Path(args.fresh_dir) / "BENCH_sharded_epoch.json"),
        args.tol)
    errors += check_weak_scaling(
        _load(Path(args.fresh_dir) / "BENCH_weak_scaling.json"),
        args.tol)
    errors += check_serving(
        _load(Path(args.fresh_dir) / "BENCH_serving.json"), args.tol)
    errors += check_turbulence(
        _load(Path(args.fresh_dir) / "BENCH_turbulence.json"), args.tol)
    perf = [e[len(PERF):] for e in errors if e.startswith(PERF)]
    structural = [e for e in errors if not e.startswith(PERF)]
    if errors:
        print("bench check FAILED:")
        for e in structural:
            print(" - [structural]", e)
        for e in perf:
            print(" - [perf]", e)
    else:
        print("bench check OK (BENCH_fused_pipeline.json + "
              "BENCH_sharded_epoch.json + BENCH_weak_scaling.json + "
              "BENCH_serving.json + BENCH_turbulence.json within tolerance)")
    code = EXIT_STRUCTURAL if structural \
        else (EXIT_PERF if perf else EXIT_OK)
    _summary(code, structural=len(structural), perf=len(perf))
    return code


def _summary(code: int, **counts) -> None:
    """The machine-readable last stdout line."""
    print(json.dumps({"tool": "check_bench", "exit_code": code,
                      "status": "ok" if code == EXIT_OK else "failed",
                      **counts}, sort_keys=True))


if __name__ == "__main__":
    try:
        sys.exit(main())
    except MissingInput as e:
        print(f"bench check FAILED: {e}")
        _summary(EXIT_MISSING, structural=0, perf=0, missing=str(e))
        sys.exit(EXIT_MISSING)
