"""Plan <-> runtime <-> fault-walk verb-parity rules.

``StoreServer.stats()["op_count"]`` is the measured side of every
dispatch prediction, so the set of verbs that increment it is a public
contract.  Two drift hazards are checked statically:

- ``parity-verb``: every ``op_count``-incrementing public verb on
  ``StoreServer`` must be *declared* in ``insitu/plan.py`` — either in
  ``VERB_CAUSES`` (mapping it to the dispatch-prediction cause labels
  that account for it) or in ``UNPLANNED_VERBS`` (utility verbs no
  planned component issues).  A new verb cannot silently skew
  ``Plan.explain()``; a deleted verb cannot leave a stale declaration.

- ``parity-fault``: every verb routed through the client's fault
  boundary (``Client._call_verb`` / ``inj.on_verb``) must appear in
  ``faults.simulate_overhead``'s walk, so injected-fault overhead
  predictions cover every retryable call site.

Both rules are pure AST extraction — no imports of the checked modules.
"""

from __future__ import annotations

import ast
import pathlib

from .engine import Finding, Rule, register

__all__ = ["ParityVerbRule", "ParityFaultRule", "extract_bump_verbs",
           "extract_plan_declarations", "extract_boundary_verbs",
           "extract_walk_verbs"]

SERVER_PATH = "src/repro/core/server.py"
PLAN_PATH = "src/repro/insitu/plan.py"
CLIENT_PATH = "src/repro/core/client.py"
FAULTS_PATH = "src/repro/core/faults.py"


def extract_bump_verbs(server_src: str) -> set[str]:
    """Public ``StoreServer`` methods whose body calls ``self._bump_ops``."""
    tree = ast.parse(server_src)
    verbs: set[str] = set()
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        for func in [n for n in cls.body
                     if isinstance(n, ast.FunctionDef)]:
            if func.name.startswith("_"):
                continue
            for node in ast.walk(func):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr == "_bump_ops":
                    verbs.add(func.name)
                    break
    return verbs


def _string_dict(node: ast.Dict) -> dict[str, tuple[str, ...]]:
    out: dict[str, tuple[str, ...]] = {}
    for k, v in zip(node.keys, node.values):
        if not (isinstance(k, ast.Constant) and isinstance(k.value, str)):
            continue
        vals: list[str] = []
        if isinstance(v, (ast.Tuple, ast.List)):
            vals = [e.value for e in v.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str)]
        out[k.value] = tuple(vals)
    return out


def extract_plan_declarations(
        plan_src: str) -> tuple[dict[str, tuple[str, ...]],
                                tuple[str, ...], set[str]]:
    """``(VERB_CAUSES, UNPLANNED_VERBS, known_causes)`` from plan.py.

    ``known_causes`` is every string literal inside a top-level function
    named ``*_dispatches`` — the cause labels the prediction layer can
    actually emit.
    """
    tree = ast.parse(plan_src)
    verb_causes: dict[str, tuple[str, ...]] = {}
    unplanned: tuple[str, ...] = ()
    known: set[str] = set()
    for node in tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            names = [t.id for t in targets if isinstance(t, ast.Name)]
            if "VERB_CAUSES" in names and isinstance(node.value, ast.Dict):
                verb_causes = _string_dict(node.value)
            if "UNPLANNED_VERBS" in names and \
                    isinstance(node.value, (ast.Tuple, ast.List)):
                unplanned = tuple(
                    e.value for e in node.value.elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str))
        elif isinstance(node, ast.FunctionDef) and \
                node.name.endswith("_dispatches"):
            for sub in ast.walk(node):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str):
                    known.add(sub.value)
    return verb_causes, unplanned, known


def extract_boundary_verbs(client_src: str) -> set[str]:
    """Verb strings the client routes through the fault boundary."""
    tree = ast.parse(client_src)
    verbs: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in ("_call_verb", "on_verb") and node.args:
            a = node.args[0]
            if isinstance(a, ast.Constant) and isinstance(a.value, str):
                verbs.add(a.value)
    return verbs


def extract_walk_verbs(faults_src: str) -> set[str]:
    """Verb strings ``simulate_overhead``'s walk charges overhead to."""
    tree = ast.parse(faults_src)
    verbs: set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        arg = None
        if isinstance(node.func, ast.Name) and \
                node.func.id == "_verb" and len(node.args) >= 2:
            arg = node.args[1]
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "on_verb" and node.args:
            arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            verbs.add(arg.value)
    return verbs


def check_verb_parity(server_src: str, plan_src: str,
                      server_path: str = SERVER_PATH,
                      plan_path: str = PLAN_PATH) -> list[Finding]:
    verbs = extract_bump_verbs(server_src)
    causes, unplanned, known = extract_plan_declarations(plan_src)
    findings = []
    if not causes and not unplanned:
        return [Finding("parity-verb", plan_path, 1,
                        "plan.py declares no VERB_CAUSES/UNPLANNED_VERBS; "
                        "the op_count verb contract is unchecked")]
    declared = set(causes) | set(unplanned)
    for verb in sorted(verbs - declared):
        findings.append(Finding(
            "parity-verb", server_path, 1,
            f"StoreServer.{verb} increments op_count but is declared in "
            f"neither VERB_CAUSES nor UNPLANNED_VERBS in plan.py — "
            f"Plan.explain() would silently miscount it"))
    for verb in sorted(declared - verbs):
        findings.append(Finding(
            "parity-verb", plan_path, 1,
            f"plan.py declares verb {verb!r} but StoreServer has no such "
            f"op_count-incrementing method (stale declaration)"))
    for verb in sorted(set(causes) & set(unplanned)):
        findings.append(Finding(
            "parity-verb", plan_path, 1,
            f"verb {verb!r} appears in both VERB_CAUSES and "
            f"UNPLANNED_VERBS (pick one)"))
    for verb, vc in sorted(causes.items()):
        for cause in vc:
            if cause not in known:
                findings.append(Finding(
                    "parity-verb", plan_path, 1,
                    f"VERB_CAUSES[{verb!r}] names cause {cause!r} which "
                    f"no *_dispatches prediction emits"))
    return findings


def check_fault_parity(client_src: str, faults_src: str,
                       client_path: str = CLIENT_PATH,
                       faults_path: str = FAULTS_PATH) -> list[Finding]:
    boundary = extract_boundary_verbs(client_src)
    walk = extract_walk_verbs(faults_src)
    return [Finding(
        "parity-fault", faults_path, 1,
        f"client fault-boundary verb {v!r} never appears in "
        f"simulate_overhead's walk — injected-fault overhead on it is "
        f"unpredicted") for v in sorted(boundary - walk)]


@register
class ParityVerbRule(Rule):
    id = "parity-verb"
    summary = ("every op_count-incrementing StoreServer verb is declared "
               "in plan.py VERB_CAUSES or UNPLANNED_VERBS (and vice versa)")
    scope = "project"

    def check_project(self, root: pathlib.Path) -> list[Finding]:
        server = (root / SERVER_PATH)
        plan = (root / PLAN_PATH)
        if not server.is_file() or not plan.is_file():
            return []
        return check_verb_parity(server.read_text(), plan.read_text())


@register
class ParityFaultRule(Rule):
    id = "parity-fault"
    summary = ("every client fault-boundary verb appears in "
               "faults.simulate_overhead's walk")
    scope = "project"

    def check_project(self, root: pathlib.Path) -> list[Finding]:
        client = (root / CLIENT_PATH)
        faults = (root / FAULTS_PATH)
        if not client.is_file() or not faults.is_file():
            return []
        return check_fault_parity(client.read_text(), faults.read_text())
