"""Lock-discipline rules for ``core/server.py``-style classes.

The store server's thread-safety contract is simple and must stay
machine-checkable:

- every mutation of the table/registry/WAL dicts happens inside a
  ``with`` on the owning lock (``self._table_locks[...]`` for slab
  state, ``self._lock``/``self._meta_event`` for registries and
  metadata);
- a method whose *caller* holds the lock (the capture-txn helpers) is
  explicitly marked ``# lint: holds-lock`` on its ``def`` line, and its
  call sites must sit inside a lock or capture context;
- acquiring two table locks uses the canonical ``first, second =
  sorted(...)`` order, in a single ``with`` statement;
- ``_ops_lock`` (the stats counter mutex) is a leaf: nothing else is
  acquired while holding it.

The runtime twin of these rules is ``repro.core.locktrack.LockTracker``,
which records the realised lock-order graph during the chaos suite and
fails on cycles.
"""

from __future__ import annotations

import ast
import pathlib

from .engine import (Finding, HOLDS_LOCK_MARKER, Rule, add_parents,
                     ancestors, register)

__all__ = ["GUARDED_ATTRS", "MUTATOR_METHODS", "LockMutationRule",
           "LockOrderRule", "LockLeafRule", "LockHoldsRule"]

#: ``self.<attr>`` collections whose mutation requires a held lock.
GUARDED_ATTRS = frozenset({
    "_specs", "_state", "_counts", "_placements", "_models", "_model_raw",
    "_model_versions", "_meta", "_gathers", "_wal", "_wal_base", "_acked",
    "_recovery", "_tables", "_watermarks",
})

#: Method names that mutate the collection they are called on.
MUTATOR_METHODS = frozenset({
    "append", "add", "pop", "popitem", "clear", "update", "remove",
    "discard", "extend", "insert", "setdefault",
})

_GUARD_ATTRS = ("_lock", "_meta_event", "_ops_lock")


def _self_attr(node: ast.AST) -> str | None:
    """``self.<attr>`` -> attr name (else None)."""
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _root_self_attr(node: ast.AST) -> str | None:
    """Peel Subscript/Attribute wrappers down to a rooting ``self.<attr>``.

    ``self._wal[t].append`` -> ``_wal``; ``txn.state`` -> None.
    """
    while True:
        direct = _self_attr(node)
        if direct is not None:
            return direct
        if isinstance(node, (ast.Subscript, ast.Attribute)):
            node = node.value
            continue
        return None


def _is_table_lock_subscript(node: ast.AST) -> bool:
    """``<obj>._table_locks[...]`` (any root object, not just self)."""
    return (isinstance(node, ast.Subscript)
            and isinstance(node.value, ast.Attribute)
            and node.value.attr == "_table_locks")


def _is_guard_expr(node: ast.AST) -> bool:
    if _is_table_lock_subscript(node):
        return True
    return (isinstance(node, ast.Attribute)
            and node.attr in _GUARD_ATTRS)


def _with_has_guard(node: ast.With) -> bool:
    return any(_is_guard_expr(item.context_expr) for item in node.items)


def _under_guard(node: ast.AST) -> bool:
    return any(isinstance(a, ast.With) and _with_has_guard(a)
               for a in ancestors(node))


def _has_marker(lines: list[str], func: ast.FunctionDef) -> bool:
    for ln in (func.lineno, func.lineno - 1):
        if 1 <= ln <= len(lines) and HOLDS_LOCK_MARKER in lines[ln - 1]:
            return True
    return False


def _mutation_sites(func: ast.FunctionDef):
    """Yield ``(node, attr)`` for every guarded-attribute mutation."""
    for node in ast.walk(func):
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                attr = _root_self_attr(t)
                if attr in GUARDED_ATTRS:
                    yield node, attr
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = _root_self_attr(t)
                if attr in GUARDED_ATTRS:
                    yield node, attr
        elif isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr in MUTATOR_METHODS:
            attr = _root_self_attr(node.func.value)
            if attr in GUARDED_ATTRS:
                yield node, attr


def _lock_classes(tree: ast.Module):
    """Classes that own a ``self._table_locks`` map (lock discipline
    applies to these; plain classes are out of scope)."""
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Assign, ast.AnnAssign)):
                    targets = sub.targets if isinstance(sub, ast.Assign) \
                        else [sub.target]
                    if any(_self_attr(t) == "_table_locks"
                           for t in targets):
                        yield node
                        break


@register
class LockMutationRule(Rule):
    """Guarded state mutated outside any lock context."""

    id = "lock-mutation"
    summary = ("mutation of guarded server state (tables/WAL/registry) "
               "outside a with-lock context")

    def check_file(self, path: str, src: str,
                   tree: ast.Module) -> list[Finding]:
        add_parents(tree)
        lines = src.splitlines()
        findings = []
        for cls in _lock_classes(tree):
            for func in [n for n in ast.walk(cls)
                         if isinstance(n, ast.FunctionDef)]:
                if func.name == "__init__" or _has_marker(lines, func):
                    continue
                for node, attr in _mutation_sites(func):
                    if not _under_guard(node):
                        findings.append(Finding(
                            self.id, path, node.lineno,
                            f"{cls.name}.{func.name} mutates self.{attr} "
                            f"outside a lock context (wrap in `with "
                            f"self._lock:` / `with self._table_locks"
                            f"[...]:`, or mark the def `# {HOLDS_LOCK_MARKER}`"
                            f" if the caller holds it)"))
        return findings


def _sorted_unpack_orders(func: ast.FunctionDef) -> list[tuple[str, ...]]:
    """Name tuples bound by ``a, b = sorted(...)`` in ``func``."""
    orders = []
    for node in ast.walk(func):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Tuple) and \
                isinstance(node.value, ast.Call) and \
                isinstance(node.value.func, ast.Name) and \
                node.value.func.id == "sorted":
            names = tuple(e.id for e in node.targets[0].elts
                          if isinstance(e, ast.Name))
            if len(names) == len(node.targets[0].elts):
                orders.append(names)
    return orders


@register
class LockOrderRule(Rule):
    """Multi-table-lock acquisition not in canonical sorted order."""

    id = "lock-order"
    summary = ("two table locks must be taken in one `with`, indexed by "
               "names from a `first, second = sorted(...)` unpack")

    def check_file(self, path: str, src: str,
                   tree: ast.Module) -> list[Finding]:
        add_parents(tree)
        findings = []
        funcs = [n for n in ast.walk(tree)
                 if isinstance(n, ast.FunctionDef)]
        for func in funcs:
            orders = _sorted_unpack_orders(func)
            for node in ast.walk(func):
                if not isinstance(node, ast.With):
                    continue
                locks = [i.context_expr for i in node.items
                         if _is_table_lock_subscript(i.context_expr)]
                if len(locks) >= 2:
                    findings.extend(self._check_multi(
                        path, node, locks, orders))
                elif len(locks) == 1 and self._nested_inside_table_lock(
                        node):
                    findings.append(Finding(
                        self.id, path, node.lineno,
                        "nested table-lock acquisition: take both locks "
                        "in ONE `with`, ordered by `sorted(...)` "
                        "(deadlock risk otherwise)"))
        return findings

    @staticmethod
    def _nested_inside_table_lock(node: ast.With) -> bool:
        return any(isinstance(a, ast.With) and
                   any(_is_table_lock_subscript(i.context_expr)
                       for i in a.items)
                   for a in ancestors(node))

    def _check_multi(self, path: str, node: ast.With, locks,
                     orders) -> list[Finding]:
        idx_names = []
        for lock in locks:
            sl = lock.slice
            if not isinstance(sl, ast.Name):
                return [Finding(
                    self.id, path, node.lineno,
                    "multi-lock acquisition must index by names bound "
                    "from `first, second = sorted(...)`, not literals "
                    "or expressions")]
            idx_names.append(sl.id)
        seq = tuple(idx_names)
        for order in orders:
            if seq == order[:len(seq)]:
                return []
        return [Finding(
            self.id, path, node.lineno,
            f"table locks acquired in order {seq} with no matching "
            f"`{', '.join(seq)} = sorted(...)` unpack in this function "
            f"(canonical order prevents AB/BA deadlock)")]


@register
class LockLeafRule(Rule):
    """``_ops_lock`` must be a leaf in the lock-order graph."""

    id = "lock-leaf"
    summary = ("no lock may be acquired while holding `_ops_lock` "
               "(the stats mutex is a leaf)")

    def check_file(self, path: str, src: str,
                   tree: ast.Module) -> list[Finding]:
        findings = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.With):
                continue
            holds_ops = any(
                isinstance(i.context_expr, ast.Attribute) and
                i.context_expr.attr == "_ops_lock"
                for i in node.items)
            if not holds_ops:
                continue
            for sub in ast.walk(node):
                if sub is node:
                    continue
                if isinstance(sub, ast.With):
                    for item in sub.items:
                        e = item.context_expr
                        if _is_guard_expr(e) and not (
                                isinstance(e, ast.Attribute) and
                                e.attr == "_ops_lock"):
                            findings.append(Finding(
                                self.id, path, sub.lineno,
                                "lock acquired while holding _ops_lock; "
                                "_ops_lock must stay a leaf"))
                if isinstance(sub, ast.Call) and \
                        isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr == "acquire" and \
                        _root_self_attr(sub.func.value) in (
                            "_lock", "_meta_event", "_table_locks"):
                    findings.append(Finding(
                        self.id, path, sub.lineno,
                        "lock.acquire() while holding _ops_lock; "
                        "_ops_lock must stay a leaf"))
        return findings


def _marked_methods(tree: ast.Module, lines: list[str]) -> set[str]:
    return {n.name for n in ast.walk(tree)
            if isinstance(n, ast.FunctionDef) and _has_marker(lines, n)}


def _call_in_lock_context(node: ast.Call) -> bool:
    """Lexically inside a `with` on a lock or a capture txn."""
    for a in ancestors(node):
        if not isinstance(a, ast.With):
            continue
        for item in a.items:
            e = item.context_expr
            if _is_guard_expr(e):
                return True
            if isinstance(e, ast.Call) and \
                    isinstance(e.func, ast.Attribute) and \
                    e.func.attr == "capture":
                return True
    return False


@register
class LockHoldsRule(Rule):
    """Calls to holds-lock-marked methods outside any lock context."""

    id = "lock-holds"
    summary = ("a `# lint: holds-lock` method may only be called inside "
               "a with-lock or `with ...capture(...)` context")
    scope = "project"

    def check_project(self, root: pathlib.Path) -> list[Finding]:
        modules = []
        for sub in ("src/repro", "tools"):
            base = root / sub
            if base.is_dir():
                for p in sorted(base.rglob("*.py")):
                    try:
                        src = p.read_text()
                        tree = ast.parse(src)
                    except (OSError, SyntaxError):
                        continue
                    modules.append((str(p.relative_to(root)), src, tree))
        return self.check_modules(modules)

    def check_modules(self, modules) -> list[Finding]:
        marked: set[str] = set()
        for _path, src, tree in modules:
            marked |= _marked_methods(tree, src.splitlines())
        if not marked:
            return []
        findings = []
        for path, _src, tree in modules:
            add_parents(tree)
            for node in ast.walk(tree):
                if isinstance(node, ast.Call) and \
                        isinstance(node.func, ast.Attribute) and \
                        node.func.attr in marked and \
                        not _call_in_lock_context(node):
                    # skip the defining `def` site itself
                    findings.append(Finding(
                        self.id, path, node.lineno,
                        f"call to caller-holds-lock method "
                        f"{node.func.attr!r} outside any lock/capture "
                        f"context"))
        return findings
