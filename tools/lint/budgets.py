"""Declarative collective-budget manifest, checked against compiled HLO.

Each :data:`MANIFEST` row claims, for one (deployment, component-kind,
tier) cell of the tier grid, the MAXIMUM number of each collective op
(``analysis/hlo.COLLECTIVE_OPS``) the compiled hot path may contain;
ops absent from a row's budget are budgeted at zero.  The checker
builds the repo's tiny reference sessions for every deployment in
{local, colocated, clustered, clustered_2d}, compiles the grid with
``plan(hlo=True)`` (which counts ops via ``analysis/hlo.count_ops``),
and fails on

- an overrun (measured count above budget),
- a measured cell with no manifest row (unbudgeted tier), and
- a manifest row no session exercises (stale row).

This replaces ad-hoc ``assert_collective_free`` sprinkling with one
machine-checked table: the whole data plane — fused puts, the fused
trainer epoch, the continuous-batching drain — is budgeted at zero
collectives on every deployment (interconnect hops are host-driven
staged transfers, never in-program collectives; the multi-device
DDP/halo claims live in ``predicted_collectives`` and are property-
tested under real device meshes in the test suite).

Budget grammar, by example::

    BudgetRow("clustered", "trainer", "sharded_fused",
              budget={"all-reduce": 2})   # at most 2, everything else 0

Run via ``python tools/run_static_analysis.py`` (phase id
``budget-collective``; skip with ``--no-budget``).
"""

from __future__ import annotations

import dataclasses

from .engine import Finding

__all__ = ["BudgetRow", "MANIFEST", "DEPLOYMENTS", "match_cells",
           "check_budgets"]

MANIFEST_PATH = "tools/lint/budgets.py"

DEPLOYMENTS = ("local", "colocated", "clustered", "clustered_2d")


@dataclasses.dataclass(frozen=True)
class BudgetRow:
    deployment: str
    kind: str
    tier: str
    #: op name -> max allowed count; ops not listed are budgeted at 0.
    budget: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def cell(self) -> tuple[str, str, str]:
        return (self.deployment, self.kind, self.tier)


def _zero_grid(kind: str, tier: str) -> tuple[BudgetRow, ...]:
    return tuple(BudgetRow(d, kind, tier) for d in DEPLOYMENTS)


#: The full {local, colocated, clustered, clustered_2d} x
#: {producer, trainer, serving} grid, budgeted at ZERO collectives:
#: the store data plane must compile collective-free everywhere.
MANIFEST: tuple[BudgetRow, ...] = (
    _zero_grid("producer", "capture_scan")
    + _zero_grid("trainer", "fused")
    + _zero_grid("serving", "continuous_batch")
)


def match_cells(cells, manifest: tuple[BudgetRow, ...] = MANIFEST
                ) -> list[Finding]:
    """Check measured cells against the manifest (pure — unit-testable).

    ``cells`` is an iterable of ``(deployment, kind, tier, collectives)``
    where ``collectives`` is the plan entry's ``((op, count), ...)``.
    """
    rows = {r.cell: r for r in manifest}
    seen: set[tuple[str, str, str]] = set()
    findings: list[Finding] = []
    for deployment, kind, tier, collectives in cells:
        key = (deployment, kind, tier)
        row = rows.get(key)
        if row is None:
            findings.append(Finding(
                "budget-collective", MANIFEST_PATH, 1,
                f"cell {key} compiled with collectives "
                f"{dict(collectives)} but has no manifest row — add a "
                f"BudgetRow for it"))
            continue
        seen.add(key)
        for op, count in collectives:
            allowed = row.budget.get(op, 0)
            if count > allowed:
                findings.append(Finding(
                    "budget-collective", MANIFEST_PATH, 1,
                    f"cell {key}: {count} x {op} in compiled HLO "
                    f"exceeds budget {allowed}"))
    for key in sorted(rows.keys() - seen):
        findings.append(Finding(
            "budget-collective", MANIFEST_PATH, 1,
            f"manifest row {key} was not exercised by any session "
            f"(stale row, or the grid builder lost a cell)"))
    return findings


# -- the tiny reference grid (compiled only when the phase runs) ------------

def _deployment(kind: str):
    from jax.sharding import PartitionSpec as PS

    from repro.core.deployment import (make_clustered_1d, make_clustered_2d,
                                       make_colocated_1d)
    if kind == "local":
        return None
    if kind == "colocated":
        return make_colocated_1d(ndim=2)
    if kind == "clustered":
        return make_clustered_1d()
    # rank-2 element spec: fits both the (4, N) field table and the
    # (2, 4) serving tables (degenerate on one visible device)
    return make_clustered_2d(PS(None, "space"))


def _grid_sessions(deployment: str):
    import jax
    import jax.numpy as jnp

    from repro.core import TableSpec
    from repro.core import store as S
    from repro.insitu import (InSituSession, Producer, ServingClients,
                              ServingConsumer, TrainerConsumer)
    from repro.ml import autoencoder as ae
    from repro.ml import trainer as tr
    from repro.sim import flatplate as fp

    fcfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
    n = fcfg.n_points
    snaps = jnp.stack([fp.snapshot(fcfg, jax.random.key(0), t)
                       for t in range(4)])

    def step(carry, rank, t):
        return carry, S.make_key(rank, t), snaps[t % 4]

    tiny = ae.AEConfig(n_points=n, mode="ref", latent=4, internal=4,
                       blocks=1, mlp_width=8, mlp_depth=2)
    cfg = tr.TrainerConfig(ae=tiny, epochs=1, gather=4, batch_size=2,
                           lr=1e-3, fused=True)
    pipeline = InSituSession(
        tables=[TableSpec("field", shape=(4, n), capacity=16,
                          engine="ring")],
        components=[
            Producer(step, table="field", steps=4, carry=jnp.zeros(()),
                     emit_every=1, chunk=2),
            TrainerConsumer(cfg, fp.grid_coords(fcfg))],
        deployment=_deployment(deployment))

    shape = (2, 4)

    def feed(c, s):
        return jnp.full(shape, float(100 * c + s))

    serving = InSituSession(
        tables=[TableSpec("sreq", shape=shape, capacity=32, engine="ring"),
                TableSpec("sres", shape=shape, capacity=32,
                          engine="ring")],
        components=[
            ServingClients(feed, table="sreq", clients=2, requests=2,
                           submit=True, collect=False, name="writers"),
            ServingConsumer("m", table="sreq", results="sres", clients=2,
                            requests=2, max_batch=4,
                            tier="continuous_batch", name="serving")],
        deployment=_deployment(deployment))
    return [pipeline, serving]


def check_budgets(manifest: tuple[BudgetRow, ...] = MANIFEST
                  ) -> list[Finding]:
    """Compile the tier grid and check it against the manifest."""
    cells = []
    for deployment in DEPLOYMENTS:
        for sess in _grid_sessions(deployment):
            plan = sess.plan(hlo=True)
            for entry in plan.components:
                if entry.collectives is None:
                    continue
                cells.append((deployment, entry.kind, entry.tier,
                              entry.collectives))
    return match_cells(cells, manifest)
