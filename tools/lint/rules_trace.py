"""Trace-safety rule: fused bodies must stay pure device programs.

``capture_scan[_collect][_multi]`` and ``serve_batch`` owe their
one-dispatch guarantees to bodies that trace once and replay forever.
A body handed to ``lax.scan`` / ``shard_map`` / ``pallas_call`` that
calls host clocks, host RNGs, threading, or forces a host sync
(``.item()``, ``float()``/``np.asarray`` on a traced argument) either
breaks under jit or silently bakes a host value into the compiled
program.  This rule finds those calls statically.

Name resolution is deliberately conservative: only bodies that are
local/module ``def``s, lambdas, or ``functools.partial`` over those are
inspected, and ``random.*`` only counts when ``random`` resolves to the
*stdlib* module in that file (``from jax import random`` is fine).
"""

from __future__ import annotations

import ast

from .engine import Finding, Rule, add_parents, register

__all__ = ["TraceHostRule", "TRACE_ENTRY_POINTS", "HOST_MODULES"]

#: Callable names whose FIRST positional argument is a traced body.
TRACE_ENTRY_POINTS = frozenset({"scan", "shard_map", "pallas_call"})

#: Module paths whose calls are host effects inside a traced body.
HOST_MODULES = frozenset({"time", "random", "threading", "numpy.random"})

#: Host-sync constructors: calling these on a traced body argument
#: forces a device->host transfer at trace time.
_SYNC_CALLS = frozenset({"float", "int", "bool"})
_NUMPY_SYNC_ATTRS = frozenset({"asarray", "array"})


def _imports(tree: ast.Module) -> dict[str, str]:
    """Local name -> dotted module path for module imports."""
    out: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = f"{node.module}.{a.name}"
    return out


def _dotted(node: ast.AST) -> str | None:
    """Attribute chain -> dotted string (``np.random.normal`` ...)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _resolve_module(dotted: str, imports: dict[str, str]) -> str | None:
    """Resolve the module a call chain roots at, through import aliases."""
    head, _, rest = dotted.partition(".")
    base = imports.get(head)
    if base is None:
        return None
    return f"{base}.{rest}" if rest else base


def _entry_name(func: ast.AST) -> str | None:
    """scan/shard_map/pallas_call regardless of alias depth
    (``lax.scan``, ``jax.lax.scan``, ``pl.pallas_call``, bare name)."""
    if isinstance(func, ast.Attribute) and func.attr in TRACE_ENTRY_POINTS:
        return func.attr
    if isinstance(func, ast.Name) and func.id in TRACE_ENTRY_POINTS:
        return func.id
    return None


def _local_callables(tree: ast.Module) -> dict[str, ast.AST]:
    """name -> FunctionDef/Lambda for every def and ``x = lambda`` bind."""
    out: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            out[node.name] = node
        elif isinstance(node, ast.Assign) and \
                isinstance(node.value, ast.Lambda):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value
    return out


def _resolve_body(arg: ast.AST,
                  local: dict[str, ast.AST]) -> ast.AST | None:
    if isinstance(arg, ast.Lambda):
        return arg
    if isinstance(arg, ast.Name):
        return local.get(arg.id)
    if isinstance(arg, ast.Call):
        # functools.partial(f, ...) / partial(f, ...)
        fname = _dotted(arg.func) or ""
        if fname.split(".")[-1] == "partial" and arg.args:
            return _resolve_body(arg.args[0], local)
    return None


def _body_params(body: ast.AST) -> set[str]:
    args = body.args
    names = {a.arg for a in list(args.posonlyargs) + list(args.args)
             + list(args.kwonlyargs)}
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    return names


@register
class TraceHostRule(Rule):
    """Host effects / host syncs inside scan, shard_map, pallas bodies."""

    id = "trace-host"
    summary = ("no time./random./np.random./threading. calls, .item(), "
               "or float()/np.asarray on traced args inside "
               "scan/shard_map/pallas bodies")

    def check_file(self, path: str, src: str,
                   tree: ast.Module) -> list[Finding]:
        add_parents(tree)
        imports = _imports(tree)
        local = _local_callables(tree)
        findings: list[Finding] = []
        seen_bodies: set[int] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            entry = _entry_name(node.func)
            if entry is None or not node.args:
                continue
            body = _resolve_body(node.args[0], local)
            if body is None or id(body) in seen_bodies:
                continue
            seen_bodies.add(id(body))
            findings.extend(self._check_body(path, entry, body, imports))
        return findings

    def _check_body(self, path: str, entry: str, body: ast.AST,
                    imports: dict[str, str]) -> list[Finding]:
        params = _body_params(body)
        findings = []
        for node in ast.walk(body):
            if not isinstance(node, ast.Call):
                continue
            dotted = _dotted(node.func)
            if dotted is not None and "." in dotted:
                mod = _resolve_module(dotted, imports)
                if mod is not None:
                    for host in HOST_MODULES:
                        if mod == host or mod.startswith(host + "."):
                            findings.append(Finding(
                                self.id, path, node.lineno,
                                f"{dotted}() inside a {entry} body is a "
                                f"host effect; the body traces once and "
                                f"replays on device"))
                            break
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item":
                findings.append(Finding(
                    self.id, path, node.lineno,
                    f".item() inside a {entry} body forces a host sync"))
            findings.extend(
                self._check_sync(path, entry, node, params, imports))
        return findings

    def _check_sync(self, path: str, entry: str, node: ast.Call,
                    params: set[str],
                    imports: dict[str, str]) -> list[Finding]:
        traced_arg = (len(node.args) >= 1 and
                      isinstance(node.args[0], ast.Name) and
                      node.args[0].id in params)
        if not traced_arg:
            return []
        if isinstance(node.func, ast.Name) and \
                node.func.id in _SYNC_CALLS:
            return [Finding(
                self.id, path, node.lineno,
                f"{node.func.id}() on traced argument "
                f"{node.args[0].id!r} inside a {entry} body bakes a "
                f"host value into the compiled program")]
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr in _NUMPY_SYNC_ATTRS:
            dotted = _dotted(node.func) or ""
            mod = _resolve_module(dotted, imports)
            if mod is not None and mod.startswith("numpy."):
                return [Finding(
                    self.id, path, node.lineno,
                    f"{dotted}() on traced argument "
                    f"{node.args[0].id!r} inside a {entry} body forces "
                    f"a host sync")]
        return []
