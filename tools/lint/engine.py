"""Rule engine for repro-lint.

A :class:`Rule` is either *file-scoped* (checked against every parsed
module independently) or *project-scoped* (checked once against the repo
root — cross-file invariants like verb parity).  Rules register
themselves via the :func:`register` decorator; the runner and the tests
discover them through :func:`all_rules`.

Suppression: a finding is dropped when the flagged source line, or the
line directly above it, carries ``# lint: disable=<rule-id>`` (several
ids may be comma-separated).  Suppressions are per-rule and per-line by
design — there is no file-level or wildcard off switch.
"""

from __future__ import annotations

import ast
import dataclasses
import pathlib
import re
from typing import Iterable

__all__ = ["Finding", "Rule", "register", "all_rules", "lint_source",
           "lint_tree", "DEFAULT_SUBDIRS"]

#: Directories (relative to the repo root) the tree walk covers.
DEFAULT_SUBDIRS = ("src/repro", "tools")

_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=([\w,\- ]+)")

#: Marker comment exempting a function from lexical lock-domination
#: checks: the function's contract is that its *caller* already holds
#: the relevant lock (see ``rules_locks``).
HOLDS_LOCK_MARKER = "lint: holds-lock"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at a source location."""

    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class Rule:
    """Base class for lint rules.

    Subclasses set ``id`` (stable kebab-case identifier used in
    suppression comments and ``--list-rules``), ``summary`` (one line)
    and ``scope`` (``"file"`` or ``"project"``), and override the
    matching ``check_*`` hook.
    """

    id: str = ""
    summary: str = ""
    scope: str = "file"

    def check_file(self, path: str, src: str,
                   tree: ast.Module) -> list[Finding]:
        return []

    def check_project(self, root: pathlib.Path) -> list[Finding]:
        return []


_REGISTRY: dict[str, Rule] = {}


def register(rule_cls: type[Rule]) -> type[Rule]:
    rule = rule_cls()
    if not rule.id or rule.id in _REGISTRY:
        raise ValueError(f"rule id {rule.id!r} missing or duplicated")
    _REGISTRY[rule.id] = rule
    return rule_cls


def all_rules() -> list[Rule]:
    """Registered rules, sorted by id (the ``--list-rules`` order)."""
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def _disabled_ids(line: str) -> set[str]:
    m = _DISABLE_RE.search(line)
    if not m:
        return set()
    return {s.strip() for s in m.group(1).split(",") if s.strip()}


def suppressed(lines: list[str], finding: Finding) -> bool:
    """True when the finding's line (or the one above) disables its rule."""
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(lines) and \
                finding.rule in _disabled_ids(lines[ln - 1]):
            return True
    return False


def _filter_suppressed(findings: Iterable[Finding],
                       source_lines: dict[str, list[str]]) -> list[Finding]:
    out = []
    for f in findings:
        lines = source_lines.get(f.path)
        if lines is None:
            try:
                lines = pathlib.Path(f.path).read_text().splitlines()
            except OSError:
                lines = []
            source_lines[f.path] = lines
        if not suppressed(lines, f):
            out.append(f)
    return out


def lint_source(src: str, path: str = "<string>",
                rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run the file-scoped rules over one source string.

    The entry point the fixture tests use: every rule must fire on its
    violating fixture here and stay silent on the repaired twin.
    """
    tree = ast.parse(src, filename=path)
    lines = src.splitlines()
    findings: list[Finding] = []
    for rule in (rules if rules is not None else all_rules()):
        if rule.scope == "file":
            findings.extend(rule.check_file(path, src, tree))
    return _filter_suppressed(findings, {path: lines})


def _walk_py(root: pathlib.Path,
             subdirs: tuple[str, ...]) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for sub in subdirs:
        base = root / sub
        if base.is_file():
            files.append(base)
        elif base.is_dir():
            files.extend(sorted(base.rglob("*.py")))
    return files


def lint_tree(root: str | pathlib.Path,
              subdirs: tuple[str, ...] = DEFAULT_SUBDIRS,
              rules: Iterable[Rule] | None = None) -> list[Finding]:
    """Run every rule over the repo tree rooted at ``root``.

    File rules see each module under ``subdirs``; project rules see the
    root once.  Suppression comments are honoured for both.
    """
    root = pathlib.Path(root)
    chosen = list(rules if rules is not None else all_rules())
    source_lines: dict[str, list[str]] = {}
    findings: list[Finding] = []
    for path in _walk_py(root, subdirs):
        rel = str(path.relative_to(root))
        try:
            src = path.read_text()
            tree = ast.parse(src, filename=rel)
        except (OSError, SyntaxError) as exc:
            findings.append(Finding("parse-error", rel, 1, str(exc)))
            continue
        source_lines[rel] = src.splitlines()
        for rule in chosen:
            if rule.scope == "file":
                for f in rule.check_file(rel, src, tree):
                    findings.append(f)
    for rule in chosen:
        if rule.scope == "project":
            findings.extend(rule.check_project(root))
    return _filter_suppressed(findings, source_lines)


def add_parents(tree: ast.AST) -> None:
    """Annotate every node with a ``lint_parent`` backlink (the engine's
    one AST extension — rules walk ancestors for with-context checks)."""
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            child.lint_parent = node  # type: ignore[attr-defined]


def ancestors(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "lint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "lint_parent", None)
