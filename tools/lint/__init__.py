"""repro-lint: repo-native static analysis for the in situ framework.

The paper's framework is trusted because costs are predicted and then
measured exactly; this package proves the invariants behind those
predictions *statically*, over the whole tree, instead of sampling them
at runtime:

- lock discipline in ``core/server.py`` (``rules_locks``)
- trace safety of fused scan/shard_map/pallas bodies (``rules_trace``)
- plan <-> runtime <-> fault-walk verb parity (``rules_parity``)
- per-tier collective budgets over compiled HLO (``budgets``)

Run ``python tools/run_static_analysis.py`` from the repo root, or use
the engine programmatically::

    from lint.engine import lint_tree
    findings = lint_tree(root)

Suppress a finding with a trailing ``# lint: disable=<rule-id>`` comment
on the flagged line (or the line above it).
"""

from .engine import Finding, Rule, all_rules, lint_source, lint_tree  # noqa: F401

# Rule modules register themselves on import.
from . import rules_locks   # noqa: F401,E402
from . import rules_trace   # noqa: F401,E402
from . import rules_parity  # noqa: F401,E402
