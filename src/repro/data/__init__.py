"""Host data pipeline: synthetic token streams, background prefetch."""

from . import pipeline
from .pipeline import PrefetchIterator, TokenStream

__all__ = ["pipeline", "PrefetchIterator", "TokenStream"]
