"""Host data pipeline: synthetic token streams with background prefetch.

Production trait being exercised: the input pipeline must never block the
accelerator.  ``PrefetchIterator`` runs the batch generator on a host
thread with a bounded buffer (double/triple buffering) and hands out
device-ready arrays; ``TokenStream`` is the deterministic synthetic corpus
(zipfian unigram mixture with a repeating-ngram structure so that small
models actually have something to learn in the examples)."""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["TokenStream", "PrefetchIterator"]


@dataclass
class TokenStream:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    structure: float = 0.7     # fraction of deterministic-ngram positions

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        # zipf-ish unigram distribution
        ranks = np.arange(1, self.vocab + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        while True:
            base = rng.choice(self.vocab, size=(self.batch, self.seq_len),
                              p=probs).astype(np.int32)
            # structured positions: token t = (prev*31 + 7) mod vocab — a
            # learnable next-token rule, applied sequentially so the
            # invariant holds through cascaded replacements
            mask = rng.random((self.batch, self.seq_len - 1)) < self.structure
            for t in range(1, self.seq_len):
                det = (base[:, t - 1] * 31 + 7) % self.vocab
                base[:, t] = np.where(mask[:, t - 1], det, base[:, t])
            yield {"tokens": base, "labels": base.copy()}


class PrefetchIterator:
    """Background-thread prefetch with a bounded buffer (never blocks the
    device on host-side batch building)."""

    def __init__(self, it, buffer_size: int = 2, device_put: bool = True,
                 sharding=None):
        self._q: queue.Queue = queue.Queue(maxsize=buffer_size)
        self._sentinel = object()
        self.dropped = 0

        def _producer():
            try:
                for item in it:
                    if device_put:
                        item = jax.tree.map(
                            lambda a: jax.device_put(jnp.asarray(a), sharding)
                            if sharding is not None else jnp.asarray(a), item)
                    self._q.put(item)
            finally:
                self._q.put(self._sentinel)

        self._thread = threading.Thread(target=_producer, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._sentinel:
            raise StopIteration
        return item
