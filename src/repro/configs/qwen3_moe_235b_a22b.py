"""Qwen3-MoE 235B-A22B.  [hf:Qwen/Qwen3-30B-A3B family; hf]

94L, d_model 4096, 64 heads (GQA kv=4), expert d_ff 1536, vocab 151936;
128 experts, top-8, QK-norm (Qwen3).  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
        d_head=128, d_ff=1536, vocab=151936,
        pattern=(("attn", "moe"),),
        mlp_act="swiglu", norm="rmsnorm", rope_theta=1_000_000.0,
        qk_norm=True,
        n_experts=128, top_k=8, d_ff_moe=1536,
        ce_chunk=512, grad_accum=8, optimizer="adafactor",
        notes="128-expert top-8 EP over the model axis.",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke",
        family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=512,
        pattern=(("attn", "moe"),),
        mlp_act="swiglu", norm="rmsnorm", qk_norm=True,
        n_experts=8, top_k=2, d_ff_moe=96, capacity_factor=8.0,
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
