"""Mamba-2 1.3B.  [arXiv:2405.21060; unverified]

48L, d_model 2048, attention-free (SSD), ssm_state 128, headdim 64,
expand 2, vocab 50280, tied embeddings.  Sub-quadratic: runs long_500k
(decode state is O(1) in context length).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=0, vocab=50280,
        pattern=(("mamba", "none"),),
        norm="rmsnorm", tie_embeddings=True,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ce_chunk=512, grad_accum=2,
        notes="SSD chunked scan; vocab 50280 is not 16-divisible — GSPMD "
              "pads the vocab shard (see DESIGN).",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=512,
        pattern=(("mamba", "none"),),
        norm="rmsnorm", tie_embeddings=True,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
        remat=False, dtype=jnp.float32,
    )
