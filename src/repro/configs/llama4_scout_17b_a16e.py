"""Llama-4 Scout 17B-active/16-expert (109B total).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
48L, d_model 5120, 40 heads (GQA kv=8), d_ff 8192, vocab 202048;
MoE: 16 routed experts, top-1, plus a Llama-4 always-on shared expert.
Assigned config uses plain GQA (no chunked-attention long-ctx variant), so
``long_500k`` is skipped (full attention).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048,
        pattern=(("attn", "moe"),),
        mlp_act="swiglu", norm="rmsnorm", rope_theta=500_000.0,
        n_experts=16, top_k=1, d_ff_moe=8192, shared_expert=True,
        ce_chunk=512, grad_accum=8,
        notes="MoE top-1 + shared expert; early-fusion frontends not in "
              "scope of the LM backbone shapes.",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-smoke",
        family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        pattern=(("attn", "moe"),),
        mlp_act="swiglu", norm="rmsnorm",
        n_experts=4, top_k=1, d_ff_moe=128, shared_expert=True, capacity_factor=8.0,
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
