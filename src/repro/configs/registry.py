"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Each ``src/repro/configs/<id>.py`` exports ``config()`` (the exact assigned
configuration) and ``smoke_config()`` (a reduced same-family config for CPU
smoke tests).  The registry also owns the assigned input-shape table and the
per-(arch × shape) applicability rules (long_500k → sub-quadratic archs
only; encoder-only would skip decode — none assigned).
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models.config import ModelConfig

__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "get_config",
           "get_smoke_config", "cells", "cell_applicable"]

ARCH_IDS = (
    "llama4_scout_17b_a16e",
    "qwen3_moe_235b_a22b",
    "starcoder2_7b",
    "phi4_mini_3_8b",
    "nemotron_4_340b",
    "starcoder2_3b",
    "mamba2_1_3b",
    "jamba_1_5_large_398b",
    "whisper_large_v3",
    "llava_next_34b",
)

# canonical external ids (assignment spelling) -> module name
_ALIASES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "starcoder2-7b": "starcoder2_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "starcoder2-3b": "starcoder2_3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-large-v3": "whisper_large_v3",
    "llava-next-34b": "llava_next_34b",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def _module(name: str):
    mod = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).config()


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Is (arch × shape) a runnable cell?  Returns (ok, reason)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k dense-KV decode "
                       "skipped per assignment (see DESIGN.md)")
    return True, ""


def cells():
    """All applicable (arch_id, shape_name) cells (the 40-cell table minus
    assignment-mandated skips)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, reason = cell_applicable(cfg, shape)
            out.append((arch, shape.name, ok, reason))
    return out
