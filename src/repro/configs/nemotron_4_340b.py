"""Nemotron-4 340B.  [arXiv:2402.16819; unverified]

96L, d_model 18432, 96 heads (GQA kv=8), d_ff 73728, vocab 256000;
squared-ReLU MLP (no gate), RoPE.  The 340B scale makes optimizer-state
memory the binding constraint: the train config uses Adafactor (factored
second moments) + ZeRO-3; see EXPERIMENTS §Dry-run.  Full attention ->
long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-340b",
        family="dense",
        n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8,
        d_head=192, d_ff=73728, vocab=256000,
        pattern=(("attn", "mlp"),),
        mlp_act="squared_relu", norm="layernorm", rope_theta=10_000.0,
        ce_chunk=512, grad_accum=64, optimizer="adafactor",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-smoke",
        family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
        d_ff=256, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="squared_relu", norm="layernorm",
        attn_chunk=64, remat=False, dtype=jnp.float32, optimizer="adafactor",
    )
