"""The paper's own architecture: the QuadConv autoencoder (paper §4).

Full config mirrors the paper's setup scaled to its per-rank partition:
36M elements / 960 ranks = 37,500 points per rank (we use the nearest
structured grid 48x25x32 = 38,400), 4 channels, 16 internal channels,
2 blocks, latent 100 -> ~1536x compression (paper: 1700x).
"""

from repro.ml.autoencoder import AEConfig
from repro.sim.flatplate import FlatPlateConfig


def config() -> AEConfig:
    return AEConfig(n_points=38_400, channels=4, internal=16, latent=100,
                    blocks=2, pool=4, mlp_width=64, mlp_depth=5)


def grid_config() -> FlatPlateConfig:
    return FlatPlateConfig(nx=48, ny=25, nz=32)


def smoke_config() -> AEConfig:
    return AEConfig(n_points=256, channels=4, internal=8, latent=16,
                    blocks=2, pool=4, mlp_width=16, mlp_depth=3, mode="ref")


def smoke_grid_config() -> FlatPlateConfig:
    return FlatPlateConfig(nx=8, ny=8, nz=4)
