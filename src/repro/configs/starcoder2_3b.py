"""StarCoder2-3B.  [arXiv:2402.19173; hf]

30L, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152;
GELU, LayerNorm, RoPE.  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2,
        d_ff=12288, vocab=49152,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm", rope_theta=100_000.0,
        ce_chunk=512, grad_accum=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        family="dense",
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm",
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
