"""Phi-4-mini 3.8B.  [arXiv:2412.08905; hf]

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 8192, vocab 200064;
SwiGLU, RMSNorm, RoPE, tied embeddings.  Full attention -> long_500k skip.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-3.8b",
        family="dense",
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=200064,
        pattern=(("attn", "mlp"),),
        mlp_act="swiglu", norm="rmsnorm", rope_theta=10_000.0,
        tie_embeddings=True,
        ce_chunk=512, grad_accum=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4-mini-smoke",
        family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="swiglu", norm="rmsnorm", tie_embeddings=True,
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
