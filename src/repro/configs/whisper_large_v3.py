"""Whisper large-v3.  [arXiv:2212.04356; unverified]

Enc-dec: 32 encoder + 32 decoder layers, d_model 1280, 20 heads (MHA,
kv=20), d_ff 5120, vocab 51866, GELU, LayerNorm, learned positions.
Conv/mel frontend is a STUB per the assignment — input_specs() provides
precomputed frame embeddings [B, 1500, 1280].  Decode shapes exercise the
decoder (self-KV + precomputed cross-KV); long_500k skipped (full attn).
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
        d_ff=5120, vocab=51866,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm",
        tie_embeddings=True,
        encoder_layers=32, encoder_ctx=1500,
        frontend="audio",
        ce_chunk=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm", tie_embeddings=True,
        encoder_layers=2, encoder_ctx=64,
        frontend="audio",
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
