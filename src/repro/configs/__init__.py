"""Architecture configs: the 10 assigned archs + the paper's QuadConv AE."""

from .registry import (ARCH_IDS, SHAPES, ShapeSpec, cell_applicable, cells,
                       get_config, get_smoke_config)

__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "cell_applicable", "cells",
           "get_config", "get_smoke_config"]
