"""StarCoder2-7B.  [arXiv:2402.19173; hf]

32L, d_model 4608, 36 heads (GQA kv=4), d_ff 18432, vocab 49152;
GELU MLP, LayerNorm, RoPE (sliding-window attention of the release is not
part of the assigned config).  Full attention -> long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
        d_head=128, d_ff=18432, vocab=49152,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm", rope_theta=100_000.0,
        ce_chunk=512, grad_accum=4,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="gelu", norm="layernorm",
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
