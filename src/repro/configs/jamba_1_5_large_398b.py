"""Jamba-1.5 Large (398B total / 94B active).  [arXiv:2403.19887; hf]

72L, d_model 8192; hybrid period-8 blocks: 1 attention layer (64H, GQA
kv=8) per 7 mamba layers; MoE (16 experts, top-2, d_ff 24576) on every
other layer.  Sub-quadratic (mamba state + 9 attention layers) -> runs
long_500k with the attention KV sharded over `data` (sequence parallel).

Adaptation note: Jamba ships Mamba-1 layers; we use the Mamba-2/SSD form
(scalar-decay — the TPU-native chunked-matmul formulation).  Recorded in
DESIGN.md §Arch-applicability.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig

_PERIOD = (
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("attn", "moe"),
    ("mamba", "mlp"), ("mamba", "moe"), ("mamba", "mlp"), ("mamba", "moe"),
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        family="hybrid",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8,
        d_head=128, d_ff=24576, vocab=65536,
        pattern=_PERIOD,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=10_000.0,
        n_experts=16, top_k=2, d_ff_moe=24576,
        ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_chunk=256,
        ce_chunk=512, grad_accum=32, optimizer="adafactor",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke",
        family="hybrid",
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        pattern=tuple(_PERIOD),
        mlp_act="swiglu", norm="rmsnorm",
        n_experts=4, top_k=2, d_ff_moe=128, capacity_factor=8.0,
        ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_chunk=16,
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
