"""LLaVA-NeXT 34B (Yi/NH2 backbone).  [hf:llava-hf/llava-v1.6; unverified]

60L, d_model 7168, 56 heads (GQA kv=8), d_ff 20480, vocab 64000.
Anyres tiling frontend is a STUB per the assignment: input_specs() provides
1152 precomputed patch embeddings (base tile + 1 anyres tile) prefixed to
the token sequence; seq_len counts image+text tokens.  Full attention ->
long_500k skipped.
"""

import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_head=128, d_ff=20480, vocab=64000,
        pattern=(("attn", "mlp"),),
        mlp_act="swiglu", norm="rmsnorm", rope_theta=5_000_000.0,
        frontend="vision", frontend_tokens=1152,
        ce_chunk=512, grad_accum=8,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke",
        family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512,
        pattern=(("attn", "mlp"),),
        mlp_act="swiglu", norm="rmsnorm",
        frontend="vision", frontend_tokens=16,
        attn_chunk=64, remat=False, dtype=jnp.float32,
    )
