"""Pipeline parallelism: SPMD GPipe over a mesh axis (default: ``pod``).

The multi-pod mesh can spend its ``pod`` axis as pipeline stages instead of
extra data parallelism: layer periods are split across stages, microbatches
flow stage-to-stage over ``lax.ppermute`` (on hardware: the inter-pod DCN
hop happens once per microbatch per stage boundary instead of once per
gradient all-reduce).

SPMD formulation (single program, all stages): over ``T = M + n_stages − 1``
iterations every stage runs its block on whatever activation it holds,
masked to zero outside its active window; activations hop one stage per
iteration via ppermute; stage ``n−1``'s outputs are collected and
``psum``-broadcast at the end.  ``jax.grad`` differentiates straight
through (ppermute transposes to the reverse permutation), giving the
backward pipeline for free.

``pipeline_forward`` is generic over ``stage_fn``; correctness is asserted
against the plain scanned forward in tests (same params, same batch,
2-stage mesh).  The bubble fraction is the usual (n−1)/(M+n−1) — pick
M ≫ n_stages.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["pipeline_forward", "split_stages", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def split_stages(stacked_params: Any, n_stages: int) -> Any:
    """Reshape leaves [P, ...] → [n_stages, P/n_stages, ...] for stage
    sharding.  P must divide evenly (pad periods upstream otherwise)."""
    def _split(a):
        p = a.shape[0]
        if p % n_stages:
            raise ValueError(f"{p} periods not divisible by {n_stages} stages")
        return a.reshape(n_stages, p // n_stages, *a.shape[1:])
    return jax.tree.map(_split, stacked_params)


def pipeline_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     stage_params: Any, x_micro: jax.Array, mesh: Mesh,
                     stage_axis: str = "pod") -> jax.Array:
    """Run ``x_micro [M, ...mb]`` through ``n_stages`` of ``stage_fn``.

    ``stage_params`` leaves are [n_stages, ...] (see ``split_stages``) and
    will be sharded over ``stage_axis``; every other mesh axis can keep
    sharding the microbatch dims as usual.  Returns [M, ...mb] outputs.
    """
    n_stages = mesh.shape[stage_axis]
    M = x_micro.shape[0]

    param_specs = jax.tree.map(lambda _: P(stage_axis), stage_params)

    def _worker(params_local, x_all):
        params_local = jax.tree.map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index(stage_axis)
        T = M + n_stages - 1
        h0 = jnp.zeros_like(x_all[0])
        outs0 = jnp.zeros_like(x_all)
        fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

        def step(carry, t):
            h_prev, outs = carry
            mb_in = jnp.clip(t, 0, M - 1)
            x_in = jnp.where(sid == 0, x_all[mb_in], h_prev)
            active = (sid <= t) & (t < sid + M)
            h = stage_fn(params_local, x_in)
            h = jnp.where(active, h, jnp.zeros_like(h))
            out_idx = jnp.clip(t - (n_stages - 1), 0, M - 1)
            is_out = (sid == n_stages - 1) & (t >= n_stages - 1)
            outs = outs.at[out_idx].set(
                jnp.where(is_out, h, outs[out_idx]))
            h_next = jax.lax.ppermute(h, stage_axis, fwd_perm)
            return (h_next, outs), None

        (_, outs), _ = jax.lax.scan(step, (h0, outs0), jnp.arange(T))
        # outputs are nonzero only on the last stage: broadcast to all
        return jax.lax.psum(outs, stage_axis)

    fn = shard_map(
        _worker, mesh=mesh,
        in_specs=(param_specs, P()),
        out_specs=P(),
        check_rep=False)
    return fn(stage_params, x_micro)
