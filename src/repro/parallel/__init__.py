"""Mesh-independent distribution machinery: logical-axis sharding rules,
pipeline parallelism, gradient compression."""

from . import compress, pipeline, sharding

__all__ = ["compress", "pipeline", "sharding"]
