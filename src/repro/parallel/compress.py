"""Gradient / payload compression (distributed-optimization tricks).

* ``quantize_int8`` / ``dequantize_int8`` — per-tensor-block symmetric int8
  with fp32 scales: 4× wire-size reduction for DP gradient all-reduce or
  store transfers (the in-situ framework's send path can compress solution
  snapshots the same way — the paper's autoencoder is the learned version
  of this lever).
* ``ErrorFeedback`` — residual accumulation (1-bit-Adam style): the
  quantization error of step *t* is added back to the gradient of step
  *t+1*, which keeps SGD convergence unbiased.
* ``compressed_psum_mean`` — the *inside-shard_map* form: int8-quantize the
  local gradient, ``psum`` the int32 accumulator over a named mesh axis,
  dequantize with the rank-mean scale.  This is the DDP gradient sync the
  sharded fused epoch (``ml.trainer.make_sharded_fused_epoch``) embeds in
  its one-dispatch ``shard_map``.
* ``compressed_allreduce`` — standalone shard_map DP all-reduce built on
  ``compressed_psum_mean`` (wire bytes ≈ ¼ of fp32), used by the
  explicit-DP in-situ trainer.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["quantize_int8", "dequantize_int8", "ErrorFeedback",
           "compressed_psum_mean", "compressed_psum_mean_ef",
           "compressed_allreduce", "compression_ratio"]


class QTensor(NamedTuple):
    q: jax.Array        # int8 payload
    scale: jax.Array    # fp32 per-block scale


def quantize_int8(x: jax.Array, block: int = 256) -> QTensor:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return QTensor(q=q, scale=scale)


def dequantize_int8(qt: QTensor, shape, dtype=jnp.float32) -> jax.Array:
    flat = (qt.q.astype(jnp.float32) * qt.scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


def compression_ratio(x: jax.Array, block: int = 256) -> float:
    raw = x.size * jnp.dtype(jnp.float32).itemsize
    comp = x.size * 1 + (x.size // block + 1) * 4
    return raw / comp


class ErrorFeedback:
    """Residual error feedback for biased compressors (host-side state)."""

    def __init__(self):
        self.residual: Any = None

    def compress(self, grads: Any, block: int = 256):
        if self.residual is not None:
            grads = jax.tree.map(lambda g, r: g + r.astype(g.dtype),
                                 grads, self.residual)
        qts = jax.tree.map(lambda g: quantize_int8(g, block), grads,
                           is_leaf=lambda x: isinstance(x, jax.Array))
        deq = jax.tree.map(
            lambda g, qt: dequantize_int8(qt, g.shape, g.dtype),
            grads, qts, is_leaf=lambda x: isinstance(x, jax.Array))
        self.residual = jax.tree.map(lambda g, d: (g - d), grads, deq)
        return qts, deq


def _wire_psum_mean(g: jax.Array, axis: str, n_ranks: int, block: int
                    ) -> tuple[jax.Array, QTensor]:
    """The int8 wire for one leaf: quantize the local value, ``psum`` the
    int8 payload in int32 (no overflow for ≤2^23 ranks), dequantize with
    the rank-mean scale.  Returns ``(mean, local QTensor)`` so callers
    can also reconstruct their own contribution (error feedback)."""
    qt = quantize_int8(g, block)
    qsum = jax.lax.psum(qt.q.astype(jnp.int32), axis)
    # per-rank scales differ; dequantize with the mean scale and let
    # error feedback absorb the residual bias.
    smean = jax.lax.psum(qt.scale, axis) / n_ranks
    mean = (qsum.astype(jnp.float32) * smean) / n_ranks
    return mean.reshape(-1)[: g.size].reshape(g.shape).astype(g.dtype), qt


def compressed_psum_mean(grads: Any, axis: str, n_ranks: int,
                         block: int = 256) -> Any:
    """int8-wire mean-all-reduce of a *local* gradient pytree.

    Call inside a ``shard_map``/``pmap`` body over the named mesh axis
    ``axis`` (of size ``n_ranks``): each rank quantizes its local
    gradient and the payloads meet on the wire (see
    :func:`_wire_psum_mean`) — the traffic is ≈ ¼ of an fp32 all-reduce.
    Per-step bias from the shared scale is absorbed by
    :class:`ErrorFeedback` / :func:`compressed_psum_mean_ef` when
    convergence parity matters; the sharded fused epoch exposes it as the
    ``ddp="int8"`` knob.
    """
    return jax.tree.map(
        lambda g: _wire_psum_mean(g, axis, n_ranks, block)[0], grads)


def compressed_psum_mean_ef(grads: Any, residuals: Any, axis: str,
                            n_ranks: int, block: int = 256
                            ) -> tuple[Any, Any]:
    """:func:`compressed_psum_mean` with error feedback in the carry.

    The host-side :class:`ErrorFeedback` cannot ride a fused epoch — its
    residual lives outside the jit.  This is the traceable form: the
    caller threads ``residuals`` (same pytree as ``grads``, zeros at epoch
    start) through its ``lax.scan`` carry.  Each rank adds its residual to
    the local gradient *before* quantizing, and the new residual is the
    part of the compensated gradient its own int8 contribution dropped —
    so the compressed wire no longer silently discards quantization error
    step after step.  Returns ``(mean_grads, new_residuals)``.
    """
    def _one(g, r):
        comp = g + r.astype(g.dtype)
        mean, qt = _wire_psum_mean(comp, axis, n_ranks, block)
        return mean, comp - dequantize_int8(qt, g.shape, g.dtype)

    leaves_g, tdef = jax.tree.flatten(grads)
    leaves_r = jax.tree.leaves(residuals)
    outs = [_one(g, r) for g, r in zip(leaves_g, leaves_r)]
    return (tdef.unflatten([m for m, _ in outs]),
            tdef.unflatten([r for _, r in outs]))


def compressed_allreduce(grad_stack: Any, mesh: Mesh, axis: str = "data",
                         block: int = 256) -> Any:
    """Mean-all-reduce of per-rank gradients with an int8 wire format.

    ``grad_stack`` leaves are [n_ranks, ...] (rank axis sharded over
    ``axis``): the standalone ``shard_map`` wrapper around
    :func:`compressed_psum_mean`.  Biased per step — pair with
    ErrorFeedback.  Returns the mean gradient, replicated (leaves [...]).
    """
    n = mesh.shape[axis]

    def _one(g_stack):
        def _worker(gl):
            return compressed_psum_mean(gl[0], axis, n, block)

        fn = shard_map(_worker, mesh=mesh,
                       in_specs=(P(axis),), out_specs=P(),
                       check_rep=False)
        return fn(g_stack)

    return jax.tree.map(_one, grad_stack)
