"""Logical-axis sharding rules (MaxText-style) for the production meshes.

Models annotate every tensor with *logical* axes ("batch", "embed", "heads",
…); this module maps them onto the physical mesh axes of the assignment:

    single-pod: (16, 16)      = ("data", "model")
    multi-pod:  (2, 16, 16)   = ("pod", "data", "model")

Default rules:

| logical axis | mesh axes        | role                                  |
|--------------|------------------|---------------------------------------|
| batch        | ("pod", "data")  | DP                                    |
| embed        | "data"           | FSDP / ZeRO-3 param shard             |
| heads/kv_heads/mlp/vocab | "model" | TP                               |
| expert       | "model"          | EP                                    |
| kv_length    | "data"           | SP for long-context KV caches         |
| length       | (replicated)     | activation sequence axis              |
| stage        | "pod"            | pipeline stages (parallel/pipeline)   |

Non-divisible dims (e.g. 40 heads over 16-way "model", vocab 50280) rely on
GSPMD's implicit padding — verified to compile; the padding waste is called
out per-arch in the roofline notes.

``use_mesh`` installs a mesh for the annotation helpers; outside any mesh
(unit tests, laptop runs) ``shard`` is a no-op so the same model code runs
anywhere.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["DEFAULT_RULES", "use_mesh", "current_mesh", "spec_for", "shard",
           "sharding_for", "fitted_sharding", "logical_sharding", "ParamSpec",
           "init_params", "param_specs_to_shardings", "param_axes",
           "data_mesh", "space_mesh", "disjoint_data_meshes",
           "slab_sharding"]

# logical axis -> mesh axis name(s)
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "embed2": None,            # second embed axis of square weights
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "length": None,
    "kv_length": "data",
    "layers": None,
    "d_head": None,
    "state": None,
    "conv": None,
    "stage": "pod",
    None: None,
}

_local = threading.local()


@contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    prev = getattr(_local, "ctx", (None, None))
    _local.ctx = (mesh, rules or DEFAULT_RULES)
    try:
        yield mesh
    finally:
        _local.ctx = prev


def current_mesh() -> Mesh | None:
    return getattr(_local, "ctx", (None, None))[0]


def _current_rules() -> dict:
    return getattr(_local, "ctx", (None, DEFAULT_RULES))[1] or DEFAULT_RULES


def spec_for(axes: Sequence[str | None], mesh: Mesh | None = None,
             rules: dict | None = None) -> P:
    """Map logical axes to a PartitionSpec valid on ``mesh``."""
    mesh = mesh or current_mesh()
    rules = rules or _current_rules()
    names = set(mesh.shape) if mesh is not None else set()
    parts = []
    used: set[str] = set()
    for ax in axes:
        target = rules.get(ax, None)
        if target is None:
            parts.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        chosen = tuple(t for t in target if t in names and t not in used)
        used.update(chosen)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(chosen)
    return P(*parts)


def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for e in entry:
        n *= mesh.shape[e]
    return n


def fitted_sharding(mesh: Mesh | None, shape: Sequence[int],
                    axes: Sequence[str | None], rules: dict | None = None
                    ) -> NamedSharding | None:
    """Sharding for a jit *input*: non-divisible dims fall back to
    replicated (GSPMD pads intermediates, but input shardings must divide
    the shape exactly)."""
    if mesh is None:
        return None
    spec = spec_for(axes, mesh, rules)
    parts = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is not None and dim % _axis_size(mesh, entry) != 0:
            entry = None
        parts.append(entry)
    return NamedSharding(mesh, P(*parts))


def sharding_for(axes: Sequence[str | None], mesh: Mesh | None = None,
                 rules: dict | None = None) -> NamedSharding | None:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(axes, mesh, rules))


# Back-compat alias
logical_sharding = sharding_for


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes (no-op when no mesh installed)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard: {len(axes)} axes for rank-{x.ndim} tensor")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(axes, mesh)))


def shard_fit(x: jax.Array, *axes: str | None) -> jax.Array:
    """Like ``shard`` but drops mesh axes that do not divide the dim —
    used for tensors where GSPMD padding causes pathological reshards
    (e.g. 2 KV heads over a 16-way model axis: replicate instead)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    sh = fitted_sharding(mesh, x.shape, axes, _current_rules())
    return jax.lax.with_sharding_constraint(x, sh)


# ---------------------------------------------------------------------------
# DDP helpers (the sharded fused epoch's mesh plumbing)
# ---------------------------------------------------------------------------

def data_mesh(n_devices: int | None = None, axis: str = "data") -> Mesh:
    """A 1-D mesh over ``axis`` for pure data parallelism.

    ``n_devices`` defaults to every visible device.  This is the mesh the
    sharded fused epoch (``ml.trainer.make_sharded_fused_epoch``) runs its
    single ``shard_map`` over; on CPU, force multiple devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the
    first jax call.
    """
    from ..launch.mesh import axis_types_kw
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), (axis,), **axis_types_kw(1))


def space_mesh(n_devices: int | None = None, axis: str = "space") -> Mesh:
    """A 1-D mesh over ``axis`` for domain decomposition.

    The producer-side twin of :func:`data_mesh`: the axis a
    halo-exchanged solver (``sim.distributed``) partitions its grid rows
    over inside one ``shard_map``, and the axis its ``elem_sharding``
    carries into the store so puts stay shard-local.  Name it to match
    the db mesh's element axis (``core.deployment.make_clustered_2d``)
    when staging across meshes.
    """
    from ..launch.mesh import axis_types_kw
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return jax.make_mesh((n,), (axis,), **axis_types_kw(1))


def slab_sharding(spec, mesh: Mesh | None, axis: str = "data"
                  ) -> NamedSharding | None:
    """Placement rule of the slab-sharded data plane: partition a store
    table's ``[capacity, *elem]`` slab along its *slot* axis over mesh
    axis ``axis``, so each rank owns ``capacity/D`` slots and per-device
    table memory stops growing with total capacity (the co-located
    scaling property of the paper's Fig. 5).

    ``spec`` is a ``core.store.TableSpec`` (duck-typed: anything with
    ``capacity`` and ``shape``).  The per-slot metadata stays replicated —
    ``core.store.init_table`` handles that when given this sharding.
    Falls back to a replicated slab when ``capacity`` does not divide the
    axis size (jit input shardings must divide exactly).
    """
    if mesh is None:
        return None
    part = axis if spec.capacity % int(mesh.shape[axis]) == 0 else None
    return NamedSharding(mesh, P(part, *([None] * len(spec.shape))))


def disjoint_data_meshes(count: int, axis: str = "data", devices=None
                         ) -> list[Mesh | None]:
    """Split the visible devices into ``count`` disjoint 1-D data meshes.

    The multi-consumer deployment: each trainer replica runs its sharded
    fused epoch on its own device slice, all sharing one store.  Devices
    are divided evenly (``len(devices) // count`` each; the remainder is
    left idle so every replica sees the same shape).  A slice of fewer
    than 2 devices returns ``None`` — that replica falls back to the
    single-device fused tier, which keeps the same session declaration
    runnable on a 1-device laptop and on a real mesh.
    """
    if count < 1:
        raise ValueError("count must be >= 1")
    devices = list(devices if devices is not None else jax.devices())
    per = len(devices) // count
    if per < 2:
        return [None] * count
    return [Mesh(np.asarray(devices[i * per:(i + 1) * per]), (axis,))
            for i in range(count)]


# ---------------------------------------------------------------------------
# Parameter specs: one source of truth for shape + logical axes + init
# ---------------------------------------------------------------------------

class ParamSpec:
    """Declares one parameter: shape, logical axes, initializer."""

    __slots__ = ("shape", "axes", "init", "scale")

    def __init__(self, shape: Sequence[int], axes: Sequence[str | None],
                 init: str = "normal", scale: float | None = None):
        if len(shape) != len(axes):
            raise ValueError(f"ParamSpec rank mismatch: {shape} vs {axes}")
        self.shape = tuple(int(s) for s in shape)
        self.axes = tuple(axes)
        self.init = init
        self.scale = scale

    def __repr__(self):
        return f"ParamSpec({self.shape}, {self.axes}, {self.init})"


def _is_spec(x):
    return isinstance(x, ParamSpec)


def _init_leaf(key, spec: ParamSpec, dtype):
    if spec.init == "zeros":
        return jax.numpy.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jax.numpy.ones(spec.shape, dtype)
    if spec.init == "normal":
        # fan-in over the trailing input dim (stacked-layer dims excluded)
        if spec.scale is not None:
            std = spec.scale
        else:
            fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
            std = float(np.sqrt(1.0 / max(1, fan_in)))
        return (jax.random.normal(key, spec.shape) * std).astype(dtype)
    if spec.init == "embed":
        std = spec.scale if spec.scale is not None else 0.02
        return (jax.random.normal(key, spec.shape) * std).astype(dtype)
    if spec.init == "const":
        return jax.numpy.full(spec.shape, spec.scale or 0.0, dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(key, specs, dtype=jax.numpy.float32):
    """Materialize a specs pytree into a params pytree (same structure)."""
    leaves, tree = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s, dtype) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(tree, vals)


def abstract_params(specs, dtype=jax.numpy.bfloat16):
    """ShapeDtypeStruct pytree for dry-run lowering (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
        is_leaf=_is_spec)


def param_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=_is_spec)


def param_specs_to_shardings(specs, mesh: Mesh, rules: dict | None = None):
    """NamedSharding pytree for the params described by ``specs``."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, mesh, rules)),
        specs, is_leaf=_is_spec)
