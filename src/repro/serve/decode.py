"""Serving loop: prefill + continuous-batching greedy decode.

Drives the compiled ``prefill``/``decode_step`` against the ``Batcher``.
Laptop-scale (smoke configs) it runs for real; at pod scale the same loop
is what ``launch/serve.py`` jits onto the production mesh.  Also the host
of the in-situ serving hook: each decode step can capture hidden states /
KV into the co-located store (``capture_table``) with zero extra
collectives — the paper's in-situ inference applied to LM serving.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from .batching import Batcher

__all__ = ["greedy_generate", "serve_loop"]


def greedy_generate(params, cfg, prompt_tokens: jax.Array, max_new: int,
                    t_max: int | None = None):
    """Single-batch greedy decode (examples + tests).

    prompt_tokens: [B, S0].  Returns [B, max_new] generated ids.
    """
    B, S0 = prompt_tokens.shape
    t_max = t_max or (S0 + max_new)
    logits, caches, pos = lm.prefill(params, cfg, prompt_tokens, t_max=t_max)
    step_fn = jax.jit(
        lambda p, c, t, i: lm.decode_step(p, cfg, c, t, i))
    out = []
    token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(max_new):
        out.append(token)
        logits, caches = step_fn(params, caches, token, jnp.int32(pos + i))
        token = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def serve_loop(params, cfg, batcher: Batcher, t_max: int,
               max_steps: int = 1000, capture_client=None,
               capture_table: str = "serving"):
    """Continuous batching: admit → decode-step → retire, until idle.

    All slots share one fixed-shape cache of depth ``t_max``; admissions
    prefill their prompt into their slot via single-token steps (simple and
    shape-stable; bulk prefill is a per-slot optimization the benchmarks
    explore separately).  Returns (completed requests, steps, tok/s).
    """
    B = batcher.max_batch
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          jax.eval_shape(lambda: lm.init_caches(cfg, B, t_max)))
    step_fn = jax.jit(lambda p, c, t, i: lm.decode_step(p, cfg, c, t, i))
    tokens = np.zeros((B, 1), np.int32)
    pos_per_slot = np.zeros(B, np.int32)
    pending_prompt: dict[int, list[int]] = {}

    t0 = time.perf_counter()
    steps = 0
    total_tokens = 0
    while steps < max_steps and not batcher.idle:
        for slot, req in batcher.admit():
            pending_prompt[slot] = list(req.prompt)
            pos_per_slot[slot] = 0
        # feed: prompt token if pending, else last generated token
        feeding = np.zeros(B, bool)
        for i in range(B):
            if pending_prompt.get(i):
                tokens[i, 0] = pending_prompt[i].pop(0)
                feeding[i] = True
        pos = int(pos_per_slot.max())
        logits, caches = step_fn(params, caches, jnp.asarray(tokens),
                                 jnp.int32(pos))
        steps += 1
        nxt = np.asarray(jnp.argmax(logits, -1))
        active = batcher.active_mask()
        emit = active & ~feeding
        if emit.any():
            batcher.record_tokens(np.where(emit, nxt, 0))
            total_tokens += int(emit.sum())
        for i in range(B):
            if active[i] or feeding[i]:
                pos_per_slot[i] += 1
            if not feeding[i] and active[i]:
                tokens[i, 0] = int(nxt[i])
        if capture_client is not None and steps % 8 == 0:
            capture_client.send_step(capture_table, steps,
                                     jnp.asarray(logits))
    dt = time.perf_counter() - t0
    return batcher.completed, steps, total_tokens / max(dt, 1e-9)
