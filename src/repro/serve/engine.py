"""Store-backed serving loop: continuous batching over the request table.

The production shape of the paper's inference workflow (SmartSim's
ocean-climate deployment: many concurrent clients, one in-database model)
as a store protocol:

* **Request queue** = a ring table plus per-client host metadata counters.
  Client ``c`` submits request ``s`` by ``put``-ting its payload under
  ``request_key(c, s)`` and bumping ``"<table>.submitted.<c>"`` — the
  submission watermark the consumer sweeps (metadata reads are free: zero
  store dispatches, so queue discovery costs nothing on the dispatch
  budget).
* **Continuous batching** = a :class:`~repro.serve.batching.Batcher` over
  ring slots; each drained batch is ONE fused dispatch
  (``Client.serve_batch``: gather → model → scatter, the serving analogue
  of ``capture_scan``).
* **Responses** = the same packed keys in a results table the clients
  poll; the results watermark doubles as the exactly-once recovery
  cursor (see :meth:`ServeLoop.recover`).
* **Hot-swap** = the model registry's version counter
  (``StoreServer.model_version``); the loop re-binds between batches via
  ``bind_model`` — an atomic (fn, params, version) read, never a torn
  pair.

Discovery sweeps round-robin over clients, admitting at most one request
per client per sweep: for a fixed set of submitted requests the admission
order — and therefore the batch count, ``ceil(total / max_batch)`` — is
canonical regardless of arrival interleaving, which is what lets
``plan.explain()`` predict drained batches exactly.
"""

from __future__ import annotations

import threading

import numpy as np

from ..core.client import Client
from ..core.faults import StoreTimeout
from ..core.telemetry import poll_backoff

__all__ = ["ServeLoop", "request_key", "submitted_meta"]


def request_key(client: int, seq: int) -> int:
    """Host-int mirror of ``store.make_key(client, seq)`` — the packed
    uint32 key a (client, sequence-id) request lives under in both the
    request and results tables."""
    key = (1 << 31) | ((seq & 0x7FFFF) << 12) | (client & 0xFFF)
    return 0x7FFFFFFF if key == 0xFFFFFFFF else key


def submitted_meta(table: str, client: int) -> str:
    """Metadata key carrying client ``client``'s submission watermark for
    ``table`` (the count of requests it has made visible)."""
    return f"{table}.submitted.{client}"


class ServeLoop:
    """Drains a request table through the fused serving dispatch.

    One loop serves ``clients * requests`` total requests (``requests``
    per client, sequence ids ``0..requests-1``), in batches of up to
    ``max_batch`` ring slots.  ``reload_every`` sets the hot-swap cadence:
    the model version is re-checked every that many drained batches (and
    always before the first).

    The loop object is the unit of crash recovery: a component restart
    reuses the SAME ``ServeLoop`` (see :meth:`recover`), so the adopted
    model generation survives the crash and recovery never re-binds — the
    swap count stays exactly what the plan predicted.
    """

    def __init__(self, client: Client, *, model_key: str,
                 request_table: str, response_table: str,
                 clients: int, requests: int, max_batch: int,
                 reload_every: int = 1, component: str = "serving"):
        self.client = client
        self.model_key = model_key
        self.request_table = request_table
        self.response_table = response_table
        self.clients = int(clients)
        self.requests = int(requests)
        self.max_batch = int(max_batch)
        self.reload_every = int(reload_every)
        self.component = component
        self.total = self.clients * self.requests
        from .batching import Batcher
        self.batcher = Batcher(max_batch=self.max_batch)
        self._enqueued = [0] * self.clients   # next seq to discover, per client
        self._discovered: list[tuple[int, int]] = []  # admission order log
        self.served = 0                       # responses committed
        self.batches = 0                      # fused serve dispatches
        self.swaps = 0                        # model generations adopted
        self._apply = None
        self._params = None
        self._version: int | None = None

    # -- model binding -------------------------------------------------------

    def wait_model(self, timeout: float = 60.0,
                   stop_event: threading.Event | None = None) -> None:
        """Block until the first model generation is published (the paper's
        "ML ranks poll the DB" moment, against the version counter instead
        of a tensor key — zero store dispatches while spinning)."""
        server = self.client.server
        for _ in poll_backoff(timeout, 1e-4, 0.01):
            if server.model_version(self.model_key) > 0:
                return
            if stop_event is not None and stop_event.is_set():
                return
        if server.model_version(self.model_key) > 0:
            return
        raise StoreTimeout("model", self.model_key, timeout)

    def maybe_swap(self) -> bool:
        """Adopt a newer model generation if one is published.  Atomic:
        ``bind_model`` reads (fn, params, version) under one registry
        lock, so the loop never holds a torn pair."""
        bound = self.client.server.bind_model(self.model_key, self._version)
        if bound is None:
            return False
        self._apply, self._params, self._version = bound
        self.swaps += 1
        return True

    # -- queue discovery -----------------------------------------------------

    def _discover(self) -> None:
        """Sweep the per-client submission watermarks round-robin,
        admitting at most one request per client per sweep, until a full
        sweep makes no progress.  Canonical admission order for any
        arrival interleave; free (metadata reads only)."""
        server = self.client.server
        progress = True
        while progress:
            progress = False
            for c in range(self.clients):
                s = self._enqueued[c]
                if s >= self.requests:
                    continue
                submitted = server.get_meta(
                    submitted_meta(self.request_table, c), 0)
                if submitted > s:
                    self.batcher.submit([c, s], max_new_tokens=1)
                    self._discovered.append((c, s))
                    self._enqueued[c] = s + 1
                    progress = True

    # -- continuous-batching drain -------------------------------------------

    def step(self) -> bool:
        """One drain iteration: swap check → discover → admit → ONE fused
        serve dispatch over the active slots.  Returns False when no slot
        was active (nothing discovered yet)."""
        if self._apply is None or self.batches % self.reload_every == 0:
            self.maybe_swap()
        self._discover()
        self.batcher.admit()
        keys = np.zeros(self.max_batch, np.uint32)
        mask = np.zeros(self.max_batch, bool)
        for i, req in enumerate(self.batcher.slots):
            if req is not None and not req.done:
                c, s = req.prompt
                keys[i] = request_key(c, s)
                mask[i] = True
        if not mask.any():
            return False
        self.client.fault_point(self.component, self.batches)
        self.client.serve_batch(self.request_table, self.response_table,
                                keys, mask, self._apply, self._params)
        # max_new_tokens=1: one served token retires every active slot.
        self.batcher.record_tokens(np.zeros(self.max_batch, np.int64))
        self.batches += 1
        self.served += int(mask.sum())
        return True

    def run(self, stop_event: threading.Event | None = None,
            timeout: float = 60.0) -> None:
        """Continuous-batching tier: drain until every request is
        answered.  Idle spins (queue empty, slots empty) back off without
        dispatching; a full ``timeout`` of no progress raises."""
        self.wait_model(timeout, stop_event)
        while self.served < self.total:
            if stop_event is not None and stop_event.is_set():
                return
            if self.step():
                continue
            progressed = False
            for _ in poll_backoff(timeout, 1e-4, 0.01):
                if self.step():
                    progressed = True
                    break
                if stop_event is not None and stop_event.is_set():
                    return
            if not progressed and self.served < self.total:
                raise StoreTimeout("serving", self.request_table, timeout,
                                   f"served {self.served}/{self.total}")

    # -- three-step baseline -------------------------------------------------

    def run_three_step(self, stop_event: threading.Event | None = None,
                       timeout: float = 60.0) -> None:
        """Paper-protocol baseline: drain the same requests one at a time
        via ``get → run_model → put`` (one store dispatch per get and per
        put, no batching, no swap accounting — ``run_model`` always sees
        the latest weights).  Canonical client-major order per sequence
        id; parity tests assert bit-identical responses vs :meth:`run`."""
        self.wait_model(timeout, stop_event)
        server = self.client.server
        order = [(c, s) for s in range(self.requests)
                 for c in range(self.clients)]
        for c, s in order[self.served:]:
            if stop_event is not None and stop_event.is_set():
                return
            meta = submitted_meta(self.request_table, c)
            for _ in poll_backoff(timeout, 1e-4, 0.01):
                if server.get_meta(meta, 0) > s:
                    break
            else:
                if not server.get_meta(meta, 0) > s:
                    raise StoreTimeout("serving", self.request_table,
                                       timeout, f"waiting for ({c},{s})")
            self.client.fault_point(self.component, self.served)
            key = request_key(c, s)
            x, found = self.client.get_kv(self.request_table, key)
            y = server.run_model(self.model_key, x)
            self.client.put_kv(self.response_table, key, y)
            self.served += 1

    # -- crash recovery ------------------------------------------------------

    def recover(self) -> None:
        """Resume after an injected crash: the results watermark counts
        responses already committed (responses commit in admission order,
        and crashes fire *before* a dispatch), so it is the exact cursor.
        The batcher is rebuilt from the discovery log's tail — in-flight
        slots from the crashed drain are re-admitted, already-answered
        requests are not.  ``_version`` survives (same loop object), so
        recovery never re-binds the model."""
        self.served = int(self.client.server.watermark(self.response_table))
        from .batching import Batcher
        self.batcher = Batcher(max_batch=self.max_batch)
        for c, s in self._discovered[self.served:]:
            self.batcher.submit([c, s], max_new_tokens=1)
