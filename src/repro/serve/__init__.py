"""Serving substrate: continuous batching + greedy decode loops + the
store-backed serving plane (``ServeLoop``)."""

from . import batching, decode, engine
from .batching import Batcher, Request
from .engine import ServeLoop, request_key, submitted_meta

__all__ = ["batching", "decode", "engine", "Batcher", "Request",
           "ServeLoop", "request_key", "submitted_meta"]
