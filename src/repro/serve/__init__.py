"""Serving substrate: continuous batching + greedy decode loops."""

from . import batching, decode
from .batching import Batcher, Request

__all__ = ["batching", "decode", "Batcher", "Request"]
