"""Request batching for the serving loop.

A minimal continuous-batching front end: requests arrive with a prompt and
a token budget; the ``Batcher`` packs up to ``max_batch`` active requests
into the fixed-shape decode step (padding empty slots), admits new
requests into freed slots between steps, and retires finished sequences.
Fixed shapes keep one compiled ``serve_step`` for the whole run — slot
admission is pure host logic.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np

__all__ = ["Request", "Batcher"]


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int = 16
    submitted_at: float = field(default_factory=time.perf_counter)
    tokens: list[int] = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None


class Batcher:
    """Slot-based continuous batching over a fixed decode batch size."""

    def __init__(self, max_batch: int, eos_id: int | None = None):
        self.max_batch = max_batch
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.slots: list[Request | None] = [None] * max_batch
        self._ids = itertools.count()
        self.completed: list[Request] = []

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> Request:
        req = Request(rid=next(self._ids), prompt=list(prompt),
                      max_new_tokens=max_new_tokens)
        self.queue.append(req)
        return req

    def admit(self) -> list[tuple[int, Request]]:
        """Fill free slots from the queue; returns newly placed (slot, req)."""
        placed = []
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                placed.append((i, req))
        return placed

    def active_mask(self) -> np.ndarray:
        return np.array([s is not None and not s.done for s in self.slots])

    def record_tokens(self, token_per_slot: np.ndarray) -> None:
        now = time.perf_counter()
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            tok = int(token_per_slot[i])
            if req.first_token_at is None:
                req.first_token_at = now
            req.tokens.append(tok)
            if (self.eos_id is not None and tok == self.eos_id) or \
                    len(req.tokens) >= req.max_new_tokens:
                req.done = True
                req.finished_at = now
                self.completed.append(req)
                self.slots[i] = None

    @property
    def idle(self) -> bool:
        return not self.queue and all(s is None for s in self.slots)
