"""InSitu-JAX: in-situ simulation/ML coupling framework for TPU pods.

Reproduction + TPU-native extension of Balin et al. (2023), "In Situ
Framework for Coupling Simulation and Machine Learning with Application
to CFD".  See DESIGN.md for the system inventory.
"""

__version__ = "0.1.0"
