"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching serve loop on a smoke config (CPU-real) or
lowers the production decode step (pod-scale path = the dry-run cells).
Demonstrates the paper's in-situ inference integration: the server
registers the model in the store's ModelRegistry and the decode loop can
stream captures to the co-located store.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs.registry import get_smoke_config
from ..core import Client, StoreServer, TableSpec
from ..models import lm
from ..parallel.sharding import init_params
from ..serve.batching import Batcher
from ..serve.decode import serve_loop
from .steps import model_specs


def run(arch: str, n_requests: int = 8, batch: int = 4, prompt_len: int = 8,
        max_new: int = 16, seed: int = 0, capture: bool = False):
    cfg = get_smoke_config(arch)
    if cfg.is_encdec:
        raise SystemExit("use examples/ for enc-dec serving demos")
    params = init_params(jax.random.key(seed), model_specs(cfg), cfg.dtype)

    capture_client = None
    if capture:
        server = StoreServer()
        server.create_table(TableSpec("serving", shape=(batch, cfg.vocab),
                                      capacity=16, engine="ring"))
        capture_client = Client(server)
        capture_client.set_model(
            "lm", lambda p, t: lm.forward(p, cfg, t)[0], params)

    batcher = Batcher(max_batch=batch)
    rng = jax.random.key(seed + 1)
    for r in range(n_requests):
        rng, k = jax.random.split(rng)
        prompt = jax.random.randint(k, (prompt_len,), 0, cfg.vocab)
        batcher.submit([int(t) for t in prompt], max_new_tokens=max_new)

    t0 = time.perf_counter()
    completed, steps, tps = serve_loop(
        params, cfg, batcher, t_max=prompt_len + max_new + 8,
        max_steps=5000, capture_client=capture_client)
    wall = time.perf_counter() - t0
    lat = [r.finished_at - r.submitted_at for r in completed
           if r.finished_at is not None]
    print(f"served {len(completed)}/{n_requests} requests in {wall:.2f}s "
          f"({steps} steps, {tps:.1f} tok/s, "
          f"p50 latency {sorted(lat)[len(lat)//2]*1e3:.0f}ms)" if lat else
          f"served {len(completed)} in {wall:.2f}s")
    return completed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--capture", action="store_true")
    args = ap.parse_args()
    run(args.arch, n_requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, max_new=args.max_new,
        capture=args.capture)


if __name__ == "__main__":
    main()
