"""Splice generated tables into EXPERIMENTS.md.

Replaces ``<!-- INCLUDE:path -->`` markers with the file contents (between
BEGIN/END guard comments so re-assembly is idempotent).

Usage: PYTHONPATH=src python -m repro.launch.assemble_experiments
"""

from __future__ import annotations

import re
from pathlib import Path

DOC = Path("EXPERIMENTS.md")
MARK = re.compile(
    r"<!-- INCLUDE:(?P<path>[^ ]+) -->"
    r"(?:\n<!-- BEGIN-INCLUDE -->.*?<!-- END-INCLUDE -->)?",
    re.DOTALL)


def main() -> None:
    text = DOC.read_text()

    def _sub(m):
        path = m.group("path")
        body = Path(path).read_text().rstrip()
        return (f"<!-- INCLUDE:{path} -->\n<!-- BEGIN-INCLUDE -->\n"
                f"{body}\n<!-- END-INCLUDE -->")

    new = MARK.sub(_sub, text)
    DOC.write_text(new)
    print(f"assembled {len(MARK.findall(text))} includes")


if __name__ == "__main__":
    main()
