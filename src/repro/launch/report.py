"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
cached cell JSONs.  ``python -m repro.launch.report`` writes
``experiments/dryrun_table.md`` + ``experiments/roofline_table.md``.
"""

from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path("experiments/dryrun")


def load(include_variants=False):
    cells = []
    for p in sorted(DRYRUN.glob("*.json")):
        parts = p.stem.split("__")
        if not include_variants and len(parts) > 3:
            continue
        d = json.loads(p.read_text())
        d["_tag"] = parts[3] if len(parts) > 3 else ""
        cells.append(d)
    return cells


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | chips | status | compile | est HBM GiB/chip"
        " (fits 16?) | HLO collectives (AG/AR/RS/A2A/CP) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["status"] == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | "
                         f"skip: long-ctx needs sub-quadratic | — | — | — |")
            continue
        if c["status"] != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | "
                         f"ERROR | — | — | — |")
            continue
        oc = c.get("collective_op_counts", {})
        ops = "/".join(str(oc.get(k, 0)) for k in (
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"))
        occ = c.get("analytic", {}).get("hbm_occupancy", {})
        tot = occ.get("total", 0)
        fits = "yes" if tot <= 16 * 2**30 else "NO*"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['chips']} | ok "
            f"| {c['compile_s']:.1f}s | {fmt_bytes(tot)} ({fits})"
            f" | {ops} |")
    return "\n".join(lines)


def roofline_table(cells) -> str:
    lines = [
        "| arch | shape | mesh | chips | t_compute | t_memory | t_coll |"
        " bound | useful ratio | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c["status"] != "ok":
            continue
        rt = c["roofline"]
        fb = rt.get("extra", {}).get("flop_breakdown", {})
        cb = rt.get("extra", {}).get("comm_breakdown", {})
        if rt["bound"] == "compute":
            note = "dominant: " + max(fb, key=fb.get) if fb else ""
        elif rt["bound"] == "collective":
            note = "dominant: " + max(cb, key=cb.get) if cb else ""
        else:
            note = "params+cache stream"
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['chips']} "
            f"| {rt['t_compute']*1e3:.2f}ms | {rt['t_memory']*1e3:.2f}ms "
            f"| {rt['t_collective']*1e3:.2f}ms | **{rt['bound']}** "
            f"| {rt['useful_ratio']:.2f} | {rt['roofline_fraction']:.3f} "
            f"| {note} |")
    return "\n".join(lines)


def main() -> None:
    cells = load()
    Path("experiments/dryrun_table.md").write_text(dryrun_table(cells) + "\n")
    Path("experiments/roofline_table.md").write_text(
        roofline_table(cells) + "\n")
    ok = sum(1 for c in cells if c["status"] == "ok")
    sk = sum(1 for c in cells if c["status"] == "skipped")
    print(f"wrote tables: {ok} ok, {sk} skipped, "
          f"{len(cells) - ok - sk} errors")


if __name__ == "__main__":
    main()
