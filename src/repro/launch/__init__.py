"""Launchers: mesh construction, multi-pod dry-run, train/serve/in-situ."""

from .mesh import HW, make_production_mesh

__all__ = ["HW", "make_production_mesh"]
