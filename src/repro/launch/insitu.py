"""In-situ driver launcher (the paper's §2.2 "driver program").

``python -m repro.launch.insitu`` wires up the full paper workflow:
a pseudo-spectral NS simulation (or the synthetic flat-plate generator)
producing solution snapshots into the co-located TensorStore, and the
QuadConv-autoencoder trainer consuming them asynchronously — then switches
the simulation to in-situ *inference*, encoding subsequent snapshots with
the freshly trained encoder at runtime (the paper's rich-time-history
use-case).  Prints the paper-Tables-1/2-style overhead report.

Producer tiers: when the solver cost is emulated (``compute_s > 0``,
paper-ratio benchmarks) the producer runs the paper-fidelity per-verb loop
— one ``send_step`` dispatch per send.  Otherwise it runs the fused
capture pipeline: ``store.capture_scan`` folds a whole chunk of solver
steps *and* their ring puts into one dispatch under one table-lock
round-trip (``Client.capture``), so the send cost is pure enqueue.  With
``--producers R > 1`` the fused tier switches to the multi-producer form
(``store.capture_scan_multi``): R simulation ranks advance in lockstep
inside the same dispatch and interleave their snapshots into the ring
each emitting step — the paper's n-sim-ranks-per-node topology with still
O(1) dispatches per chunk.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..core import Client, InSituDriver, StragglerPolicy, TableSpec
from ..core import store as S
from ..ml import autoencoder as ae
from ..ml import trainer as tr
from ..sim import flatplate as fp
from ..sim import spectral as sp


def run(epochs: int = 40, sim_steps: int = 200, points: str = "small",
        producer: str = "flatplate", send_every: int = 2,
        capacity: int = 24, gather: int = 6, latent: int = 16,
        lr: float = 1e-3, compute_s: float = 0.0, seed: int = 0,
        producers: int = 1, verbose: bool = True):
    """``compute_s``: emulated PDE-integration cost per step (the paper's
    reproducer sleeps to stand in for the solver; our synthetic producer
    costs ~9 ms/step vs PHASTA's ~500 s, so overhead *ratios* against the
    solver need the emulation — the absolute send cost is measured
    either way).  ``producers``: simulation ranks sharing the fused
    capture (>1 requires the fused tier, i.e. ``compute_s == 0``)."""
    if producers > 1 and compute_s:
        raise ValueError("multi-producer capture requires the fused tier "
                         "(compute_s == 0)")
    if points == "small":
        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    else:
        fcfg = fp.FlatPlateConfig(nx=16, ny=16, nz=8)
    coords = fp.grid_coords(fcfg)
    n_points = fcfg.n_points
    ncfg = sp.NSConfig(n=16, nu=0.02, dt=0.01, forcing=True)

    driver = InSituDriver(
        tables=[TableSpec("field", shape=(4, n_points), capacity=capacity,
                          engine="ring")],
        straggler=StragglerPolicy(consumer_wait_s=30.0))

    def _fit_points(snap3):
        # spectral grid 16^3=4096 points; re-tile to n_points
        return snap3[:, :n_points] if snap3.shape[1] >= n_points \
            else jnp.tile(snap3, (1, n_points // snap3.shape[1] + 1))[:, :n_points]

    def producer_fn(client: Client, stop):
        """PHASTA stand-in: integrate, send every ``send_every`` steps."""
        key = jax.random.key(seed)
        if compute_s:
            # -- per-verb tier: the sleep-emulated solver cannot be traced,
            # and the paper's per-component send measurement wants one
            # dispatch per send anyway.
            if producer == "spectral":
                state = sp.random_turbulence(ncfg, key)
            steps = 0
            for step in range(sim_steps):
                if stop.is_set():
                    break
                with client.timers.time("equation_solution") as box:
                    time.sleep(compute_s)
                    if producer == "spectral":
                        state = sp.step(ncfg, state)
                        box[0] = state.uhat
                    else:
                        snap = fp.snapshot(fcfg, key, step)
                        box[0] = snap
                if step % send_every == 0:
                    if producer == "spectral":
                        snap = _fit_points(sp.snapshot(ncfg, state))
                    client.send_step("field", step, snap)
                steps += 1
            client.put_metadata("sim_done", True)
            return steps

        # -- fused tier: capture_scan folds a chunk of solver steps + ring
        # puts into ONE dispatch; t0 is traced so every full chunk reuses
        # the same compiled executable.  producers > 1 uses the
        # multi-producer form: R ranks advance in lockstep, all R
        # snapshots interleave into the ring each emitting step.
        spec = client.server.spec("field")
        rank = client.rank
        R = producers

        def step_fn(carry, t):
            if producer == "spectral":
                carry = sp.step(ncfg, carry)
                snap = _fit_points(sp.snapshot(ncfg, carry))
            else:
                snap = fp.snapshot(fcfg, key, t)
            return carry, S.make_key(rank, t), snap

        def step_fn_multi(carry_r, rnk, t):
            if producer == "spectral":
                carry_r = sp.step(ncfg, carry_r)
                snap = _fit_points(sp.snapshot(ncfg, carry_r))
            else:
                snap = fp.snapshot(fcfg, jax.random.fold_in(key, rnk), t)
            return carry_r, S.make_key(rnk, t), snap

        if R == 1:
            carry = sp.random_turbulence(ncfg, key) \
                if producer == "spectral" else jnp.zeros(())
        else:
            carry = jax.vmap(lambda r: sp.random_turbulence(
                ncfg, jax.random.fold_in(key, r)))(jnp.arange(R)) \
                if producer == "spectral" else jnp.zeros((R,))
        chunk = max(8 * send_every, 8)
        # Warm the capture executable (every distinct chunk length — the
        # tail chunk compiles separately since length is static) on a
        # throwaway table so the timed chunks measure enqueue + solve,
        # not compilation.
        lengths = {min(chunk, sim_steps - base)
                   for base in range(0, sim_steps, chunk)}
        with client.timers.time("jit_compile"):
            for wk in sorted(lengths):
                if R == 1:
                    wst, _ = S.capture_scan(spec, S.init_table(spec),
                                            step_fn, carry, wk, send_every,
                                            t0=0)
                else:
                    wst, _ = S.capture_scan_multi(
                        spec, S.init_table(spec), step_fn_multi, carry, wk,
                        R, send_every, t0=0)
                jax.block_until_ready(wst.count)
        steps = 0
        srv = client.server
        for base in range(0, sim_steps, chunk):
            if stop.is_set():
                break
            k = min(chunk, sim_steps - base)
            # The ring puts ride the solver dispatch (that is the point of
            # the fused tier), so the chunk is charged to equation_solution
            # and "send" counts only the enqueue + commit bookkeeping
            # (Client.capture_scan times it into the send bucket).
            with client.timers.time("equation_solution") as box:
                carry = client.capture_scan(
                    "field", step_fn if R == 1 else step_fn_multi, carry, k,
                    send_every, t0=base, n_ranks=None if R == 1 else R)
                box[0] = srv.checkout("field").count  # block on the chunk
            steps += k
        client.put_metadata("sim_done", True)
        return steps

    def consumer_fn(client: Client, stop):
        cfg = tr.TrainerConfig(
            ae=ae.AEConfig(n_points=n_points, latent=latent, mlp_width=16,
                           mode="ref"),
            epochs=epochs, gather=gather, batch_size=4, lr=lr,
            # paper-comparison runs (emulated solver cost) measure the
            # per-verb consumer so "retrieve" means what Table 2 means
            fused=(compute_s == 0))
        state, history, levels, stats = tr.insitu_train(
            client, coords, cfg, stop_event=stop,
            on_epoch=(lambda r: print(
                f"  epoch {r.epoch:3d} train {r.train_loss:.4f} "
                f"val {r.val_loss:.4f} relF {r.val_rel_error:.3f}"))
            if verbose else None)
        # register the trained encoder for in-situ inference
        client.set_model(
            "encoder",
            lambda p, f: ae.encode(p, cfg.ae, levels, f),
            state.params)
        client.put_metadata("trained", True)
        return len(history)

    res = driver.run({"simulation": producer_fn, "training": consumer_fn},
                     max_wall_s=3600)

    # --- in-situ inference phase (paper: encode future snapshots) ---------
    client = driver.client(rank=99)
    mu, sd = client.get_metadata("norm_stats")
    n_inf = 5
    t_inf = []
    for step in range(sim_steps, sim_steps + n_inf):
        snap = fp.snapshot(fcfg, jax.random.key(seed), step)
        x = ((snap.T[None] - mu) / sd)
        t0 = time.perf_counter()
        z = client.infer("encoder", x)
        jax.block_until_ready(z)
        t_inf.append(time.perf_counter() - t0)
    cf = ae.compression_factor(tr.TrainerConfig(
        ae=ae.AEConfig(n_points=n_points, latent=latent)).ae)
    print(f"\nin-situ inference: latent {z.shape}, compression {cf:.0f}x, "
          f"{min(t_inf)*1e3:.1f}ms/snapshot")
    print("\n" + res.timers.table("In-situ component overheads "
                                  "(paper Tables 1-2 analogue)"))
    sol = res.timers.total("equation_solution")
    send = res.timers.total("send")
    tr_total = res.timers.total("total_training")
    retr = res.timers.total("retrieve")
    if sol:
        print(f"\nsend overhead / solver time: {100*send/sol:.2f}% "
              f"(paper: <<1%)")
    if tr_total:
        print(f"retrieve overhead / training time: {100*retr/tr_total:.2f}% "
              f"(paper: ~1%)")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--sim-steps", type=int, default=200)
    ap.add_argument("--producer", choices=["flatplate", "spectral"],
                    default="flatplate")
    ap.add_argument("--points", choices=["small", "medium"], default="small")
    ap.add_argument("--producers", type=int, default=1,
                    help="simulation ranks sharing the fused capture")
    args = ap.parse_args()
    run(epochs=args.epochs, sim_steps=args.sim_steps,
        producer=args.producer, points=args.points,
        producers=args.producers)


if __name__ == "__main__":
    main()
