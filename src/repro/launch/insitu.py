"""In-situ driver launcher (the paper's §2.2 "driver program").

``python -m repro.launch.insitu`` wires up the full paper workflow as ONE
declarative :class:`repro.insitu.InSituSession`: a pseudo-spectral NS
simulation (or the synthetic flat-plate generator) producing solution
snapshots into the co-located TensorStore, the QuadConv-autoencoder
trainer consuming them asynchronously, and an in-situ *inference*
component encoding subsequent snapshots with the freshly trained encoder
(the paper's rich-time-history use-case).  Prints the resolved plan and
the paper-Tables-1/2-style overhead report.

Tier selection lives in the session's plan, not here: an emulated solver
cost (``compute_s > 0``, paper-ratio benchmarks) marks the producer
non-traceable, which pins the paper-fidelity per-verb tier and the
per-verb consumer; otherwise the plan picks the fused capture pipeline —
``capture_scan`` (or ``capture_scan_multi`` with ``--producers R``) on
the producer side and the fused one-dispatch epoch on the consumer side.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..core import TableSpec
from ..core import store as S
from ..insitu import (InferenceConsumer, InSituSession, Producer,
                      TrainerConsumer)
from ..core.orchestrator import StragglerPolicy
from ..ml import autoencoder as ae
from ..ml import trainer as tr
from ..sim import flatplate as fp
from ..sim import spectral as sp


def make_producer(*, sim_steps: int, producer: str, fcfg, ncfg,
                  send_every: int, compute_s: float, seed: int,
                  producers: int) -> Producer:
    """Declare the simulation producer for the session.

    With ``compute_s > 0`` the solver cost is emulated with a sleep —
    untraceable, so the declaration carries ``traceable=False`` and the
    plan pins the per-verb tier (one dispatch per send, each component in
    its paper bucket).  Otherwise the step is pure JAX and the plan fuses
    whole chunks of steps + ring puts into single dispatches.
    """
    key = jax.random.key(seed)
    n_points = fcfg.n_points

    def _fit_points(snap3):
        # spectral grid 16^3=4096 points; re-tile to n_points
        return snap3[:, :n_points] if snap3.shape[1] >= n_points \
            else jnp.tile(snap3,
                          (1, n_points // snap3.shape[1] + 1))[:, :n_points]

    def step_fn(carry, rank, t):
        if compute_s:
            time.sleep(compute_s)          # per-verb tier only (eager)
        if producer == "spectral":
            carry = sp.step(ncfg, carry)
            snap = _fit_points(sp.snapshot(ncfg, carry))
        else:
            snap = fp.snapshot(fcfg, jax.random.fold_in(key, rank), t)
        return carry, S.make_key(rank, t), snap

    if producer == "spectral":
        if producers == 1:
            carry = sp.random_turbulence(ncfg, key)
        else:
            carry = jax.vmap(lambda r: sp.random_turbulence(
                ncfg, jax.random.fold_in(key, r)))(jnp.arange(producers))
    else:
        carry = jnp.zeros(()) if producers == 1 else jnp.zeros((producers,))

    return Producer(step_fn, table="field", steps=sim_steps,
                    ranks=producers, carry=carry, emit_every=send_every,
                    traceable=(compute_s == 0))


def run(epochs: int = 40, sim_steps: int = 200, points: str = "small",
        producer: str = "flatplate", send_every: int = 2,
        capacity: int = 24, gather: int = 6, latent: int = 16,
        lr: float = 1e-3, compute_s: float = 0.0, seed: int = 0,
        producers: int = 1, consumers: int = 1, verbose: bool = True):
    """``compute_s``: emulated PDE-integration cost per step (the paper's
    reproducer sleeps to stand in for the solver; our synthetic producer
    costs ~9 ms/step vs PHASTA's ~500 s, so overhead *ratios* against the
    solver need the emulation — the absolute send cost is measured either
    way).  ``producers``/``consumers``: simulation ranks sharing the
    fused capture / trainer replicas on disjoint mesh slices.
    """
    if producers > 1 and compute_s:
        raise ValueError("multi-producer capture requires the fused tier "
                         "(compute_s == 0)")
    if points == "small":
        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    else:
        fcfg = fp.FlatPlateConfig(nx=16, ny=16, nz=8)
    coords = fp.grid_coords(fcfg)
    n_points = fcfg.n_points
    ncfg = sp.NSConfig(n=16, nu=0.02, dt=0.01, forcing=True)

    cfg = tr.TrainerConfig(
        ae=ae.AEConfig(n_points=n_points, latent=latent, mlp_width=16,
                       mode="ref"),
        epochs=epochs, gather=gather, batch_size=4, lr=lr,
        # paper-comparison runs (emulated solver cost) measure the
        # per-verb consumer so "retrieve" means what Table 2 means
        fused=(compute_s == 0))

    def feed(client, step):
        """Encode post-training snapshots (the in-situ inference phase)."""
        mu, sd = client.get_metadata("norm_stats")
        snap = fp.snapshot(fcfg, jax.random.key(seed), sim_steps + step)
        return (snap.T[None] - mu) / sd

    n_inf = 5
    session = InSituSession(
        tables=[TableSpec("field", shape=(4, n_points), capacity=capacity,
                          engine="ring")],
        components=[
            make_producer(sim_steps=sim_steps, producer=producer, fcfg=fcfg,
                          ncfg=ncfg, send_every=send_every,
                          compute_s=compute_s, seed=seed,
                          producers=producers),
            TrainerConsumer(cfg, coords, count=consumers,
                            model_key="encoder"),
            InferenceConsumer("encoder", feed, steps=n_inf,
                              wait_meta="trained"),
        ],
        straggler=StragglerPolicy(consumer_wait_s=30.0))

    plan = session.plan()
    if verbose:
        print(plan.describe(), "\n")
    res = session.run(plan=plan, max_wall_s=3600, verbose=verbose)

    # --- report (paper Tables 1-2 analogue) -------------------------------
    inf = res.output(plan.components[-1].name)
    timers = res.run.timers
    if inf is not None and inf.last is not None:
        cf = ae.compression_factor(cfg.ae)
        t_inf = timers.mean("model_eval") or 0.0
        print(f"\nin-situ inference: latent {inf.last.shape}, "
              f"compression {cf:.0f}x, {t_inf*1e3:.1f}ms/snapshot")
    print("\n" + timers.table("In-situ component overheads "
                              "(paper Tables 1-2 analogue)"))
    sol = timers.total("equation_solution")
    send = timers.total("send")
    tr_total = timers.total("total_training")
    retr = timers.total("retrieve")
    if sol:
        print(f"\nsend overhead / solver time: {100*send/sol:.2f}% "
              f"(paper: <<1%)")
    if tr_total:
        print(f"retrieve overhead / training time: {100*retr/tr_total:.2f}% "
              f"(paper: ~1%)")
    return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=40)
    ap.add_argument("--sim-steps", type=int, default=200)
    ap.add_argument("--producer", choices=["flatplate", "spectral"],
                    default="flatplate")
    ap.add_argument("--points", choices=["small", "medium"], default="small")
    ap.add_argument("--producers", type=int, default=1,
                    help="simulation ranks sharing the fused capture")
    ap.add_argument("--consumers", type=int, default=1,
                    help="trainer replicas on disjoint mesh slices")
    args = ap.parse_args()
    run(epochs=args.epochs, sim_steps=args.sim_steps,
        producer=args.producer, points=args.points,
        producers=args.producers, consumers=args.consumers)


if __name__ == "__main__":
    main()
