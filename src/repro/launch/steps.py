"""Step builders + abstract input specs for every (arch × shape) cell.

``build_step(cfg, shape_kind)`` returns the jit-able step function and
``abstract_inputs`` the matching ShapeDtypeStruct pytree (with NamedShardings
attached) — exactly what the dry-run lowers and what the real launcher feeds.

Step kinds (per the assignment):
  train    — ``train_step(state, batch)``: loss, grads, optimizer update.
             Lowered for the ``train_4k`` cells.
  prefill  — ``prefill_step(params, batch)``: prompt pass returning last
             logits + KV/Mamba caches (``prefill_32k``).
  decode   — ``serve_step(params, caches, token, pos)``: one new token
             against a seq_len-deep cache (``decode_32k`` / ``long_500k``).

Sharding rules per cell come from ``rules_for``: the long-context decode
cell re-maps ``batch→(none)`` / ``kv_length→(pod,data)`` (sequence-parallel
KV with LSE-merged partial attention), everything else uses the defaults.
"""

from __future__ import annotations

from dataclasses import replace
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.registry import ShapeSpec
from ..models import lm, whisper
from ..models.layers import KVCache, QuantKVCache
from ..models.ssd import MambaCache
from ..parallel import sharding as shd
from ..train import optimizer as opt
from ..train.train_state import (TrainState, abstract_params,
                                 abstract_train_state, make_tx)

__all__ = ["rules_for", "model_specs", "build_step", "abstract_inputs",
           "abstract_state_for"]


def rules_for(cfg, shape: ShapeSpec) -> dict:
    rules = dict(shd.DEFAULT_RULES)
    if shape.kind == "decode" and shape.global_batch == 1:
        # long-context single-sequence decode: no batch to shard — spend the
        # mesh on sequence-parallel KV instead.
        rules["batch"] = None
        rules["kv_length"] = ("pod", "data")
    if not cfg.moe_ep:
        # §Perf H2: drop expert parallelism — experts replicated across the
        # mesh (weights still TP-sharded on mlp/embed dims); the dispatch
        # all-to-all disappears.
        rules["expert"] = None
    if cfg.serve_replicate_params and shape.kind == "decode":
        # §Perf H3: weights-stationary serving — params replicated over
        # `data`, sharded over `model` only; no per-step ZeRO gathers.
        rules["embed"] = None
    if cfg.serve_2d_tp and shape.kind == "decode":
        # §Perf H3': 2-D tensor-parallel decode — batch replicated, the
        # `data` axis shards the contraction (embed) dim: weights stay
        # resident, each matmul is a partial-sum + tiny activation AR.
        rules["batch"] = None
    return rules


def model_specs(cfg):
    return whisper.whisper_specs(cfg) if cfg.is_encdec else lm.lm_specs(cfg)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def _loss_fn(cfg):
    if cfg.is_encdec:
        def loss(params, batch):
            return whisper.whisper_loss(params, cfg, batch["frames"],
                                        batch["tokens"], batch["labels"])
    elif cfg.frontend == "vision":
        def loss(params, batch):
            return lm.lm_loss(params, cfg, batch["tokens"], batch["labels"],
                              batch["patches"])
    else:
        def loss(params, batch):
            return lm.lm_loss(params, cfg, batch["tokens"], batch["labels"])
    return loss


def make_train_step(cfg) -> Callable:
    tx = make_tx(cfg)
    loss_fn = _loss_fn(cfg)
    accum = max(1, cfg.grad_accum)

    def train_step(state: TrainState, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            # microbatching: activation residency ∝ 1/accum; grads
            # accumulate in fp32 (sharded like the params — local adds)
            micro = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum,
                                    *x.shape[1:]), batch)

            def mb_step(carry, mbatch):
                gsum, lsum = carry
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), ms = jax.lax.scan(
                mb_step, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = lsum / accum
            metrics = jax.tree.map(lambda x: x[-1], ms)
        updates, opt_state = tx.update(grads, state.opt_state, state.params)
        params = opt.apply_updates(state.params, updates)
        metrics = dict(metrics, loss=loss,
                       grad_norm=opt.global_norm(grads))
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg, t_max: int | None = None) -> Callable:
    if cfg.is_encdec:
        def prefill_step(params, batch):
            return whisper.whisper_prefill(
                params, cfg, batch["frames"], batch["tokens"],
                t_max=t_max or batch["tokens"].shape[1])
    elif cfg.frontend == "vision":
        def prefill_step(params, batch):
            return lm.prefill(params, cfg, batch["tokens"],
                              batch["patches"], t_max=t_max)
    else:
        def prefill_step(params, batch):
            return lm.prefill(params, cfg, batch["tokens"], t_max=t_max)
    return prefill_step


def make_decode_step(cfg, kv_sharded: bool = False) -> Callable:
    if cfg.is_encdec:
        def decode_step(params, caches, token, pos):
            return whisper.whisper_decode_step(params, cfg, caches, token,
                                               pos)
    else:
        def decode_step(params, caches, token, pos):
            return lm.decode_step(params, cfg, caches, token, pos,
                                  kv_sharded=kv_sharded)
    return decode_step


def build_step(cfg, shape: ShapeSpec):
    """(step_fn, donate_argnums) for the cell's kind."""
    if shape.kind == "train":
        return make_train_step(cfg), (0,)
    if shape.kind == "prefill":
        return make_prefill_step(cfg), ()
    kv_sharded = shape.global_batch == 1
    return make_decode_step(cfg, kv_sharded=kv_sharded), (1,)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, axes, rules):
    sh = shd.fitted_sharding(mesh, shape, axes, rules)
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sh)


def _train_batch(cfg, shape: ShapeSpec, mesh, rules):
    B, S = shape.global_batch, shape.seq_len
    tok = lambda s: _sds(s, jnp.int32, mesh, ("batch", "length"), rules)
    if cfg.is_encdec:
        return {
            "frames": _sds((B, cfg.encoder_ctx, cfg.d_model), cfg.dtype,
                           mesh, ("batch", "length", None), rules),
            "tokens": tok((B, S)),
            "labels": tok((B, S)),
        }
    if cfg.frontend == "vision":
        S_text = S - cfg.frontend_tokens
        return {
            "patches": _sds((B, cfg.frontend_tokens, cfg.d_model), cfg.dtype,
                            mesh, ("batch", "length", None), rules),
            "tokens": tok((B, S_text)),
            "labels": tok((B, S)),
        }
    return {"tokens": tok((B, S)), "labels": tok((B, S))}


def _cache_axes(cfg, kv_sharded: bool):
    t_axis = "kv_length" if kv_sharded else "length"
    if cfg.kv_cache_quant:
        kv_axes = QuantKVCache(
            k=("layers", "batch", t_axis, "kv_heads"),
            v=("layers", "batch", t_axis, "kv_heads"),
            k_scale=("layers", "batch", t_axis, "kv_heads"),
            v_scale=("layers", "batch", t_axis, "kv_heads"))
    else:
        kv_axes = KVCache(k=("layers", "batch", t_axis, "kv_heads"),
                          v=("layers", "batch", t_axis, "kv_heads"))
    mamba_axes = MambaCache(
        conv_x=("layers", "batch", None, "mlp"),
        conv_b=("layers", "batch", None, None),
        conv_c=("layers", "batch", None, None),
        state=("layers", "batch", "heads", None, None))
    return kv_axes, mamba_axes


def abstract_caches(cfg, batch: int, t_max: int, mesh, rules,
                    kv_sharded: bool = False):
    """ShapeDtypeStruct cache pytree with shardings (mirrors lm.init_caches)."""
    kv_axes, mamba_axes = _cache_axes(cfg, kv_sharded)
    if cfg.is_encdec:
        shapes = jax.eval_shape(
            lambda: whisper.init_decoder_caches(cfg, batch, t_max))
        axes = {"self": kv_axes, "cross": kv_axes}
        return jax.tree.map(
            lambda s, a: _sds(s.shape, s.dtype, mesh, a, rules),
            shapes, axes,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
    caches = []
    shapes = jax.eval_shape(lambda: lm.init_caches(cfg, batch, t_max))
    for (mixer, _), cache_shape in zip(cfg.pattern, shapes):
        ax = kv_axes if mixer == "attn" else mamba_axes
        caches.append(jax.tree.map(
            lambda s, a: _sds(s.shape, s.dtype, mesh, a, rules),
            cache_shape, ax,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)))
    return caches


def abstract_state_for(cfg, shape: ShapeSpec, mesh, rules=None):
    """Abstract params / train state for the cell."""
    rules = rules or rules_for(cfg, shape)
    specs = model_specs(cfg)
    if shape.kind == "train":
        return abstract_train_state(cfg, specs, mesh, rules)
    return abstract_params(specs, mesh, cfg.dtype, rules)


def abstract_inputs(cfg, shape: ShapeSpec, mesh, rules=None):
    """Full abstract argument tuple for the cell's step function."""
    rules = rules or rules_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        state = abstract_state_for(cfg, shape, mesh, rules)
        return (state, _train_batch(cfg, shape, mesh, rules))
    params = abstract_state_for(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        batch = _train_batch(cfg, shape, mesh, rules)
        batch.pop("labels")
        return (params, batch)
    # decode: cache of depth seq_len, one new token
    kv_sharded = B == 1
    caches = abstract_caches(cfg, B, S, mesh, rules, kv_sharded)
    token = _sds((B, 1), jnp.int32, mesh, ("batch", "length"), rules)
    pos = _sds((), jnp.int32, mesh, (), rules)
    return (params, caches, token, pos)
