"""Recompute the roofline section of cached dry-run JSONs without
recompiling (the analytic FLOP/byte/comm models are pure functions of the
config; the compiled memory/HLO fields are untouched).

Usage: PYTHONPATH=src python -m repro.launch.refresh_roofline [dir]
"""

from __future__ import annotations

import dataclasses
import json
import sys
from pathlib import Path

from ..analysis import comm as comm_mod
from ..analysis import flops as flops_mod
from ..analysis.roofline import roofline
from ..configs.registry import SHAPES, get_config
from .steps import rules_for


def refresh(path: Path) -> bool:
    d = json.loads(path.read_text())
    if d.get("status") != "ok":
        return False
    cfg = get_config(d["arch"])
    if d.get("overrides"):
        cfg = dataclasses.replace(cfg, **d["overrides"])
    shape = SHAPES[d["shape"]]
    rules = rules_for(cfg, shape)
    rep = flops_mod.analyze(cfg, shape)
    occ = flops_mod.hbm_occupancy(cfg, shape, d["chips"])
    comm = comm_mod.collective_model(cfg, shape, d["mesh"], rules)
    corrected = d.get("cost_analysis_corrected", {})
    hlo_coll = corrected.get(
        "collective_link_bytes",
        d.get("collectives_raw", {}).get("link_bytes", 0))
    rt = roofline(d["arch"], d["shape"], d["mesh"], d["chips"],
                  machine_flops=rep.machine_flops,
                  model_flops=rep.model_flops,
                  hbm_bytes=rep.hbm_bytes,
                  collective_bytes=comm.per_device_bytes,
                  useful_bytes=rep.param_bytes + rep.cache_bytes,
                  extra={"flop_breakdown": rep.breakdown,
                         "comm_breakdown": comm.breakdown,
                         "hlo_link_bytes_upper_bound": float(hlo_coll)})
    d["analytic"] = {
        "machine_flops": rep.machine_flops, "model_flops": rep.model_flops,
        "param_bytes": rep.param_bytes, "cache_bytes": rep.cache_bytes,
        "act_bytes": rep.act_bytes,
        "comm_per_device_bytes": comm.per_device_bytes,
        "hbm_occupancy": occ,
    }
    d["roofline"] = rt.as_dict()
    path.write_text(json.dumps(d, indent=1, default=str))
    return True


def main() -> None:
    out_dir = Path(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
    n = 0
    for p in sorted(out_dir.glob("*.json")):
        if refresh(p):
            n += 1
    print(f"refreshed {n} cells")


if __name__ == "__main__":
    main()
