import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): prove the distribution config is
coherent without hardware.

For one (arch × shape × mesh) cell:
  1. build the production mesh (16×16 single-pod / 2×16×16 multi-pod);
  2. build the cell's step function and abstract inputs
     (ShapeDtypeStruct + NamedShardings — no allocation);
  3. ``jax.jit(step).lower(...).compile()`` — sharding mismatches, OOM-at-
     compile and unsupported collectives are bugs and fail here;
  4. record memory_analysis / cost_analysis / collective bytes.

Scan-trip correction: XLA cost analysis counts ``lax.scan`` bodies once, so
we also compile 1-period and 2-period variants of the model and report
``corrected = f(1) + (periods-1)·(f(2)−f(1))`` for FLOPs/bytes/collectives.
Roofline terms (§Roofline) use the analytic model of ``analysis.flops``;
the corrected HLO numbers are the compiled cross-check.

Usage:
  python -m repro.launch.dryrun --arch llama4-scout-17b-a16e \
      --shape train_4k --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both        # full sweep
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from ..analysis import comm as comm_mod
from ..analysis import flops as flops_mod
from ..analysis import hlo as hlo_mod
from ..analysis.roofline import roofline
from ..configs.registry import (ARCH_IDS, SHAPES, cell_applicable,
                                get_config)
from ..parallel import sharding as shd
from .mesh import HW, make_production_mesh
from .steps import abstract_inputs, build_step, rules_for


def _reduced(cfg, periods: int):
    """Same arch with n_periods=periods (and encoder stack shrunk alike)."""
    kw = {"n_layers": len(cfg.pattern) * periods}
    if cfg.is_encdec:
        kw["encoder_layers"] = periods
    return dataclasses.replace(cfg, **kw)


def _compile_cell(cfg, shape, mesh, rules):
    step, donate = build_step(cfg, shape)
    args = abstract_inputs(cfg, shape, mesh, rules)
    with shd.use_mesh(mesh, rules):
        lowered = jax.jit(step, donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _cost(compiled):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


_VARIANT_TYPES = {
    "ce_fp32": lambda s: s in ("1", "true", "True"),
    "bf16_grads": lambda s: s in ("1", "true", "True"),
    "remat_policy": str,
    "pad_heads": lambda s: s in ("1", "true", "True"),
    "kv_cache_quant": lambda s: s in ("1", "true", "True"),
    "remat": lambda s: s in ("1", "true", "True"),
    "attn_impl": str,
    "moe_ep": lambda s: s in ("1", "true", "True"),
    "serve_replicate_params": lambda s: s in ("1", "true", "True"),
    "serve_2d_tp": lambda s: s in ("1", "true", "True"),
    "capacity_factor": float,
    "attn_chunk": int,
    "ce_chunk": int,
    "ssm_chunk": int,
    "optimizer": str,
}


def parse_overrides(pairs):
    out = {}
    for p in pairs or ():
        k, v = p.split("=", 1)
        if k not in _VARIANT_TYPES:
            raise SystemExit(f"unknown override {k!r}; allowed: "
                             f"{sorted(_VARIANT_TYPES)}")
        out[k] = _VARIANT_TYPES[k](v)
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             correction: bool = True, overrides: dict | None = None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    rules = rules_for(cfg, shape)
    t0 = time.perf_counter()
    try:
        lowered, compiled = _compile_cell(cfg, shape, mesh, rules)
    except Exception:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": traceback.format_exc()}
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    mem_d = {k: int(getattr(mem, k, 0)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes",
        "generated_code_size_in_bytes")}
    cost_full = _cost(compiled)
    coll_full = hlo_mod.collective_bytes(compiled.as_text())
    opct = hlo_mod.count_ops(compiled.as_text())

    corrected = {}
    if correction and cfg.n_periods > 2:
        # The 1-/2-period variants run UNROLLED (lm.forward unrolls depth≤2),
        # so per-period HLO cost appears with the right multiplicity and
        # total = outside + periods·body extrapolates exactly:
        #   body = f(2) − f(1),  outside = 2·f(1) − f(2).
        try:
            _, c1 = _compile_cell(_reduced(cfg, 1), shape, mesh, rules)
            _, c2 = _compile_cell(_reduced(cfg, 2), shape, mesh, rules)
            f1, f2 = _cost(c1), _cost(c2)
            x1 = hlo_mod.collective_bytes(c1.as_text())
            x2 = hlo_mod.collective_bytes(c2.as_text())
            P = cfg.n_periods
            lin = lambda a, b: a + (P - 1) * (b - a)
            corrected = {
                "flops": lin(f1["flops"], f2["flops"]),
                "bytes": lin(f1["bytes"], f2["bytes"]),
                "collective_bytes": lin(x1.get("total", 0),
                                        x2.get("total", 0)),
                "collective_link_bytes": lin(x1.get("link_bytes", 0),
                                             x2.get("link_bytes", 0)),
            }
        except Exception:
            corrected = {"error": traceback.format_exc(limit=2)}

    rep = flops_mod.analyze(cfg, shape)
    comm = comm_mod.collective_model(cfg, shape, mesh_kind, rules)
    hlo_coll = corrected.get("collective_link_bytes",
                             coll_full.get("link_bytes", 0))
    rt = roofline(arch, shape_name, mesh_kind, chips,
                  machine_flops=rep.machine_flops,
                  model_flops=rep.model_flops,
                  hbm_bytes=rep.hbm_bytes,
                  collective_bytes=comm.per_device_bytes,
                  useful_bytes=rep.param_bytes + rep.cache_bytes,
                  extra={"flop_breakdown": rep.breakdown,
                         "comm_breakdown": comm.breakdown,
                         # compiled cross-check; CPU target lowers bf16 dots
                         # through f32, so this is ~2x the TPU-target bytes
                         "hlo_link_bytes_upper_bound": float(hlo_coll)})

    bytes_per_device = (mem_d["argument_size_in_bytes"]
                        + mem_d["temp_size_in_bytes"]) / chips
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "chips": chips, "compile_s": t_compile,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "memory": mem_d,
        "bytes_per_device_est": bytes_per_device,
        "hbm_per_chip": HW["hbm_bytes"],
        "cost_analysis_raw": cost_full,
        "cost_analysis_corrected": corrected,
        "collectives_raw": coll_full,
        "collective_op_counts": opct,
        "analytic": {
            "machine_flops": rep.machine_flops,
            "model_flops": rep.model_flops,
            "param_bytes": rep.param_bytes,
            "cache_bytes": rep.cache_bytes,
            "act_bytes": rep.act_bytes,
        },
        "roofline": rt.as_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="sweep all cells")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-correction", action="store_true")
    ap.add_argument("--force", action="store_true", help="recompute cached")
    ap.add_argument("--set", action="append", default=[], metavar="K=V",
                    help="config override (perf variants), e.g. "
                         "--set ce_fp32=0 --set pad_heads=1")
    ap.add_argument("--tag", default="", help="suffix for variant outputs")
    args = ap.parse_args()
    overrides = parse_overrides(args.set)

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    for arch, shape_name in cells:
        for mesh_kind in meshes:
            slug = f"{arch}__{shape_name}__{mesh_kind}"
            if args.tag:
                slug += f"__{args.tag}"
            path = out_dir / f"{slug}.json"
            if path.exists() and not args.force:
                print(f"[cached] {slug}")
                continue
            t0 = time.perf_counter()
            res = run_cell(arch, shape_name, mesh_kind,
                           correction=not args.no_correction,
                           overrides=overrides)
            res["overrides"] = overrides
            res["wall_s"] = time.perf_counter() - t0
            path.write_text(json.dumps(res, indent=1, default=str))
            status = res["status"]
            msg = res.get("reason", res.get("error", ""))
            if status == "ok":
                rt = res["roofline"]
                msg = (f"bound={rt['bound']} frac={rt['roofline_fraction']:.3f} "
                       f"mem/dev={res['bytes_per_device_est']/2**30:.2f}GiB "
                       f"compile={res['compile_s']:.1f}s")
            print(f"[{status}] {slug}: {str(msg).splitlines()[-1] if msg else ''}",
                  flush=True)


if __name__ == "__main__":
    main()
