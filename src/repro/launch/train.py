"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Laptop-scale real training on smoke configs (CPU) and the pjit path the
production mesh uses (the same ``train_step`` the dry-run compiles).
Features exercised here because a 1000-node fleet needs them:

* async sharded checkpointing with retention + in-memory (store) ckpt,
* restart: ``--resume`` restores the latest checkpoint (elastic: onto the
  current mesh/sharding, whatever it is),
* background-prefetched data pipeline,
* straggler telemetry: step-time watchdog logs outliers,
* optional in-situ capture: hidden states streamed to a co-located store
  (``--capture``), the paper's technique as a first-class training feature.
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config, get_smoke_config
from ..core import Client, StoreServer, TableSpec
from ..data.pipeline import PrefetchIterator, TokenStream
from ..parallel import sharding as shd
from ..train import checkpoint as ckpt
from ..train.train_state import TrainState, init_train_state, make_tx
from .steps import make_train_step, model_specs


def run(arch: str, steps: int = 50, batch: int = 4, seq_len: int = 64,
        smoke: bool = True, ckpt_dir: str | None = None,
        ckpt_every: int = 20, resume: bool = False, capture: bool = False,
        seed: int = 0, log_every: int = 10, straggler_factor: float = 3.0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    if cfg.is_encdec:
        raise SystemExit("use examples/ for enc-dec training demos")
    specs = model_specs(cfg)
    tx = make_tx(cfg, total_steps=steps)
    state = init_train_state(jax.random.key(seed), cfg, specs, tx)

    checkpointer = None
    if ckpt_dir:
        checkpointer = ckpt.Checkpointer(ckpt_dir, interval_steps=ckpt_every)
        if resume and ckpt.latest_step(ckpt_dir) is not None:
            state = ckpt.restore(ckpt_dir, state)
            print(f"resumed from step {int(state.step)}")

    server = client = None
    if capture:
        server = StoreServer()
        server.create_table(TableSpec(
            "hidden", shape=(batch, cfg.d_model), capacity=32,
            dtype=np.float32, engine="ring"))
        client = Client(server)

    step_fn = jax.jit(make_train_step(cfg), donate_argnums=0)
    stream = PrefetchIterator(iter(TokenStream(cfg.vocab, batch, seq_len,
                                               seed=seed)), buffer_size=2)
    times = []
    losses = []
    t_start = time.perf_counter()
    for i, raw in zip(range(steps), stream):
        batch_dev = {k: jnp.asarray(v) for k, v in raw.items()}
        if cfg.frontend == "vision":
            batch_dev["patches"] = jnp.zeros(
                (batch, cfg.frontend_tokens, cfg.d_model), cfg.dtype)
            batch_dev["labels"] = jnp.concatenate(
                [jnp.full((batch, cfg.frontend_tokens), -1, jnp.int32),
                 batch_dev["labels"]], axis=1)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch_dev)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        times.append(dt)
        losses.append(float(metrics["loss"]))
        # straggler watchdog
        if len(times) > 5 and dt > straggler_factor * float(np.median(times)):
            print(f"[straggler] step {i}: {dt*1e3:.1f}ms vs median "
                  f"{np.median(times)*1e3:.1f}ms")
        if capture and i % 4 == 0:
            client.send_step("hidden", i, jnp.zeros((batch, cfg.d_model)))
        if checkpointer is not None:
            checkpointer.maybe_save(i + 1, state)
        if i % log_every == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"ce {float(metrics['ce']):.4f} {dt*1e3:.0f}ms")
    if checkpointer is not None:
        checkpointer.maybe_save(steps, state, force=True)
        checkpointer.wait()
    wall = time.perf_counter() - t_start
    print(f"done: {steps} steps in {wall:.1f}s; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod-scale; default: smoke)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--capture", action="store_true")
    args = ap.parse_args()
    run(args.arch, steps=args.steps, batch=args.batch, seq_len=args.seq_len,
        smoke=not args.full, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume,
        capture=args.capture)


if __name__ == "__main__":
    main()
