"""Production meshes for the assigned TPU v5e pods.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets its
placeholder-device XLA flag before the first jax call, and smoke
tests/benches must keep seeing the single real device.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_for", "HW"]


#: TPU v5e hardware constants used by the roofline (per chip).
HW = {
    "name": "TPU v5e",
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bytes_per_s": 819e9,      # HBM bandwidth
    "ici_bytes_per_s_per_link": 50e9,
    "ici_links": 4,                # 2D torus: 4 links/chip (x±, y±)
    "hbm_bytes": 16 * 2**30,       # 16 GiB HBM per chip
    "vmem_bytes": 128 * 2**20,
}


def axis_types_kw(n: int) -> dict:
    """``axis_types=(Auto,)*n`` kwargs only where this jax version has
    ``jax.sharding.AxisType`` (older versions default to auto anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (axis_type.Auto,) * n} if axis_type is not None \
        else {}



def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_mesh_for(n_devices: int, model_axis: int = 1, name_data: str = "data",
                  name_model: str = "model"):
    """Small helper for laptop-scale runs/tests: (n/model, model) mesh."""
    if n_devices % model_axis:
        raise ValueError(f"{n_devices} devices, model axis {model_axis}")
    return jax.make_mesh(
        (n_devices // model_axis, model_axis), (name_data, name_model),
        **axis_types_kw(2))
