"""Pseudo-spectral incompressible Navier-Stokes solver (the PHASTA analogue).

The paper's data producer is PHASTA, a stabilized finite-element DNS code.
For a self-contained JAX substrate we implement a classic pseudo-spectral
solver for the incompressible Navier-Stokes equations on a triply periodic
box — the standard DNS workhorse (Rogallo 1981) — which produces exactly the
data the paper streams: instantaneous pressure + three velocity components.

Numerics
--------
* Fourier collocation on an ``n³`` grid (``rfftn`` storage ``[3,n,n,n//2+1]``).
* Rotational form nonlinear term ``u × ω`` evaluated pseudo-spectrally with
  2/3-rule dealiasing; the gradient part is absorbed by the projection.
* Helmholtz (Leray) projection enforces ``∇·u = 0`` to round-off.
* Explicit low-storage RK4 in time; viscous term integrated explicitly
  (laptop-scale runs use moderate Reynolds numbers).
* Optional negative-viscosity band forcing at ``|k| ∈ [kf_lo, kf_hi]`` to
  sustain turbulence for long in-situ runs.
* Pressure recovered spectrally from the Poisson equation
  ``∇²p = -∂ᵢ∂ⱼ(uᵢuⱼ)`` when a snapshot is taken.

Exactness check: the 2-D Taylor-Green vortex embedded in 3-D is an exact NS
solution (its nonlinear term is a pure gradient) — the solver reproduces its
analytic viscous decay to discretization precision (see tests).

The solver is domain-decomposed for the framework by sharding snapshots over
the mesh ``data`` axis (each "rank" owns a contiguous point slab, matching
PHASTA's element partitions).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["NSConfig", "NSState", "taylor_green", "taylor_green_2d",
           "random_turbulence", "step", "snapshot", "energy", "enstrophy",
           "max_divergence", "partition_snapshot"]


@dataclass(frozen=True)
class NSConfig:
    n: int = 32                 # grid points per dimension
    nu: float = 1.0 / 100.0     # kinematic viscosity
    dt: float = 5e-3
    forcing: bool = False
    f_amp: float = 0.08         # negative-viscosity forcing gain
    kf_lo: float = 0.5
    kf_hi: float = 2.5
    precision: str = "float32"

    @property
    def shape(self) -> tuple[int, int, int]:
        return (self.n, self.n, self.n)

    @property
    def n_points(self) -> int:
        return self.n ** 3


class NSState(NamedTuple):
    uhat: jax.Array   # complex [3, n, n, n//2+1], divergence-free
    t: jax.Array      # scalar time
    step: jax.Array   # int32 step counter


# ---------------------------------------------------------------------------
# Spectral machinery
# ---------------------------------------------------------------------------

def _wavenumbers(n: int):
    k1 = jnp.fft.fftfreq(n, d=1.0 / n)                # full axes
    kr = jnp.fft.rfftfreq(n, d=1.0 / n)               # last (real) axis
    kx = k1[:, None, None]
    ky = k1[None, :, None]
    kz = kr[None, None, :]
    k2 = kx**2 + ky**2 + kz**2
    return kx, ky, kz, k2


def _dealias_mask(n: int):
    k1 = jnp.abs(jnp.fft.fftfreq(n, d=1.0 / n))
    kr = jnp.abs(jnp.fft.rfftfreq(n, d=1.0 / n))
    kmax = n // 2
    cut = (2.0 / 3.0) * kmax
    return ((k1[:, None, None] <= cut)
            & (k1[None, :, None] <= cut)
            & (kr[None, None, :] <= cut))


def _project(cfg: NSConfig, vhat):
    """Leray projection onto divergence-free fields: v - k (k·v)/k²."""
    kx, ky, kz, k2 = _wavenumbers(cfg.n)
    k2s = jnp.where(k2 == 0, 1.0, k2)
    div = kx * vhat[0] + ky * vhat[1] + kz * vhat[2]
    return jnp.stack([
        vhat[0] - kx * div / k2s,
        vhat[1] - ky * div / k2s,
        vhat[2] - kz * div / k2s,
    ])


def _rhs(cfg: NSConfig, uhat):
    """du_hat/dt = P[(u×ω)_hat·dealias] - ν k² u_hat (+ band forcing)."""
    kx, ky, kz, k2 = _wavenumbers(cfg.n)
    u = jnp.fft.irfftn(uhat, s=cfg.shape, axes=(-3, -2, -1))
    # vorticity ω = ∇×u (spectral curl)
    what = jnp.stack([
        1j * (ky * uhat[2] - kz * uhat[1]),
        1j * (kz * uhat[0] - kx * uhat[2]),
        1j * (kx * uhat[1] - ky * uhat[0]),
    ])
    w = jnp.fft.irfftn(what, s=cfg.shape, axes=(-3, -2, -1))
    # u × ω in physical space
    nphys = jnp.stack([
        u[1] * w[2] - u[2] * w[1],
        u[2] * w[0] - u[0] * w[2],
        u[0] * w[1] - u[1] * w[0],
    ])
    nhat = jnp.fft.rfftn(nphys, axes=(-3, -2, -1)) * _dealias_mask(cfg.n)
    rhs = _project(cfg, nhat) - cfg.nu * k2 * uhat
    if cfg.forcing:
        kmag = jnp.sqrt(k2)
        band = (kmag >= cfg.kf_lo) & (kmag <= cfg.kf_hi)
        rhs = rhs + cfg.f_amp * jnp.where(band, uhat, 0.0)
    return rhs


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def step(cfg: NSConfig, state: NSState) -> NSState:
    """One RK4 time step (divergence-free in, divergence-free out)."""
    h = cfg.dt
    u0 = state.uhat
    k1 = _rhs(cfg, u0)
    k2 = _rhs(cfg, u0 + 0.5 * h * k1)
    k3 = _rhs(cfg, u0 + 0.5 * h * k2)
    k4 = _rhs(cfg, u0 + h * k3)
    unew = u0 + (h / 6.0) * (k1 + 2 * k2 + 2 * k3 + k4)
    return NSState(uhat=_project(cfg, unew), t=state.t + h, step=state.step + 1)


# ---------------------------------------------------------------------------
# Initial conditions
# ---------------------------------------------------------------------------

def _grid(n: int):
    x = jnp.linspace(0.0, 2 * jnp.pi, n, endpoint=False)
    return jnp.meshgrid(x, x, x, indexing="ij")


def taylor_green(cfg: NSConfig) -> NSState:
    """Classic 3-D Taylor-Green vortex (transitions to turbulence)."""
    X, Y, Z = _grid(cfg.n)
    u = jnp.stack([
        jnp.cos(X) * jnp.sin(Y) * jnp.sin(Z),
        -jnp.sin(X) * jnp.cos(Y) * jnp.sin(Z),
        jnp.zeros_like(X),
    ])
    uhat = jnp.fft.rfftn(u, axes=(-3, -2, -1))
    return NSState(uhat=_project(cfg, uhat), t=jnp.zeros(()),
                   step=jnp.zeros((), jnp.int32))


def taylor_green_2d(cfg: NSConfig) -> NSState:
    """2-D TGV embedded in 3-D: an *exact* NS solution,
    u = cos(x) sin(y) e^{-2νt}, v = -sin(x) cos(y) e^{-2νt}, w = 0."""
    X, Y, _ = _grid(cfg.n)
    u = jnp.stack([
        jnp.cos(X) * jnp.sin(Y),
        -jnp.sin(X) * jnp.cos(Y),
        jnp.zeros_like(X),
    ])
    uhat = jnp.fft.rfftn(u, axes=(-3, -2, -1))
    return NSState(uhat=_project(cfg, uhat), t=jnp.zeros(()),
                   step=jnp.zeros((), jnp.int32))


def random_turbulence(cfg: NSConfig, key, e0: float = 0.5,
                      k_peak: float = 3.0) -> NSState:
    """Divergence-free random field with a von-Karman-ish spectrum
    E(k) ∝ k⁴ exp(-2(k/k_peak)²), normalized to kinetic energy ``e0``."""
    kx, ky, kz, k2 = _wavenumbers(cfg.n)
    kmag = jnp.sqrt(k2)
    kr, ki = jax.random.split(key)
    shape = (3, cfg.n, cfg.n, cfg.n // 2 + 1)
    noise = (jax.random.normal(kr, shape) + 1j * jax.random.normal(ki, shape))
    amp = (kmag ** 2) * jnp.exp(-((kmag / k_peak) ** 2))
    uhat = _project(cfg, noise * amp)
    uhat = uhat * _dealias_mask(cfg.n)
    state = NSState(uhat=uhat, t=jnp.zeros(()), step=jnp.zeros((), jnp.int32))
    e = energy(cfg, state)
    scale = jnp.sqrt(e0 / jnp.maximum(e, 1e-30))
    return state._replace(uhat=uhat * scale)


# ---------------------------------------------------------------------------
# Diagnostics + snapshots
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=0)
def energy(cfg: NSConfig, state: NSState):
    """Mean kinetic energy ½⟨|u|²⟩ via Parseval on the rfft storage."""
    n = cfg.n
    # rfft stores only half the kz modes: weight interior kz planes by 2.
    w = jnp.ones(n // 2 + 1).at[1:n // 2 + (n % 2)].set(2.0)
    # handle Nyquist plane correctly for even n (it is not duplicated)
    if n % 2 == 0:
        w = w.at[-1].set(1.0)
    spec = jnp.sum(jnp.abs(state.uhat) ** 2 * w, axis=(0, 1, 2, 3))
    return 0.5 * spec / (n ** 6)


@partial(jax.jit, static_argnums=0)
def enstrophy(cfg: NSConfig, state: NSState):
    kx, ky, kz, _ = _wavenumbers(cfg.n)
    what = jnp.stack([
        1j * (ky * state.uhat[2] - kz * state.uhat[1]),
        1j * (kz * state.uhat[0] - kx * state.uhat[2]),
        1j * (kx * state.uhat[1] - ky * state.uhat[0]),
    ])
    n = cfg.n
    w = jnp.ones(n // 2 + 1).at[1:n // 2 + (n % 2)].set(2.0)
    if n % 2 == 0:
        w = w.at[-1].set(1.0)
    return 0.5 * jnp.sum(jnp.abs(what) ** 2 * w) / (n ** 6)


@partial(jax.jit, static_argnums=0)
def max_divergence(cfg: NSConfig, state: NSState):
    kx, ky, kz, _ = _wavenumbers(cfg.n)
    div = 1j * (kx * state.uhat[0] + ky * state.uhat[1] + kz * state.uhat[2])
    d = jnp.fft.irfftn(div, s=cfg.shape, axes=(-3, -2, -1))
    return jnp.max(jnp.abs(d))


@partial(jax.jit, static_argnums=0)
def snapshot(cfg: NSConfig, state: NSState) -> jax.Array:
    """Instantaneous (p, u, v, w) on the grid, flattened to [4, n³].

    This is exactly what each PHASTA rank streams to the database every
    (other) time step.  Pressure solves ``∇²p = -∂ᵢ∂ⱼ(uᵢuⱼ)`` spectrally.
    """
    kx, ky, kz, k2 = _wavenumbers(cfg.n)
    u = jnp.fft.irfftn(state.uhat, s=cfg.shape, axes=(-3, -2, -1))
    k = (kx, ky, kz)
    acc = jnp.zeros_like(state.uhat[0])
    for i in range(3):
        for j in range(3):
            uij_hat = jnp.fft.rfftn(u[i] * u[j], axes=(-3, -2, -1))
            acc = acc + k[i] * k[j] * uij_hat
    k2s = jnp.where(k2 == 0, 1.0, k2)
    phat = -acc / k2s
    phat = phat.at[0, 0, 0].set(0.0)          # zero-mean pressure gauge
    p = jnp.fft.irfftn(phat, s=cfg.shape, axes=(-3, -2, -1))
    fields = jnp.stack([p, u[0], u[1], u[2]])
    return fields.reshape(4, cfg.n_points)


def partition_snapshot(fields: jax.Array, n_ranks: int) -> jax.Array:
    """Domain-decompose a [4, N] snapshot into [n_ranks, 4, N/n_ranks]
    contiguous slabs — each "rank"'s contribution, sent with its own key."""
    c, npts = fields.shape
    if npts % n_ranks:
        raise ValueError(f"{npts} points not divisible by {n_ranks} ranks")
    per = npts // n_ranks
    return fields.reshape(c, n_ranks, per).transpose(1, 0, 2)
