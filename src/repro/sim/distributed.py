"""Domain-decomposed finite-difference incompressible solver (2-D).

The PHASTA-shaped producer the paper couples to: a structured-grid
Navier–Stokes solver whose state is decomposed over a ``space`` mesh axis
and advanced *inside one* ``shard_map`` — each step touches only its own
subdomain rows plus a width-1/width-2 halo moved by
:func:`~.halo.halo_exchange` (``lax.ppermute``), never a global
collective.  Feeding the in-situ data plane, its snapshots are emitted
**shard-local** too: the producer's ``elem_sharding`` carries the
``space`` axis through ``core.store.capture_scan`` so the put is a local
slab update on every shard (the ``capture_scan_sharded`` tier of
``insitu.plan``).

Numerics — Chorin projection on a periodic ``n x n`` collocated grid
(``h = 2*pi/n``), rows (dim 0) decomposed over the mesh:

1. explicit advection + diffusion with central differences →
   ``(u*, v*)``;
2. pressure Poisson ``L phi = div(u*, v*) / dt`` solved by
   ``jacobi_iters`` Jacobi sweeps of the *wide* Laplacian
   ``L = Dx Dx + Dy Dy`` (the operator consistent with the
   central-difference divergence, so the projection annihilates exactly
   the divergence the corrector measures);
3. correction ``u = u* - dt * Dx phi`` (central gradient).

The discrete Taylor–Green vortex is an exact eigenfunction of this
scheme: its central-difference advection term is an exact discrete
gradient (projected away completely), leaving pure diffusive decay at
the *discrete* rate ``g = 1 - 2 nu dt lambda_h`` per step with
``lambda_h = 4 sin^2(h/2) / h^2`` — the analytic validation the tests
pin to fp32 tightness, alongside the continuum ``exp(-4 nu t)`` rate the
paper-level comparison against ``sim.spectral`` uses.

The sharded and single-device paths share one stencil kernel
(:func:`_advance`), parameterized only by the exchange function — the
reference pads the global array (:func:`~.halo.pad_reference`), the
sharded step pads each block via ppermute — so their outputs agree to
fp32 roundoff at any shard count.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .halo import halo_exchange, pad_reference

__all__ = ["FDConfig", "FDState", "taylor_green", "decaying_turbulence",
           "make_step", "make_producer", "shard_state",
           "taylor_green_factor", "energy", "max_divergence", "snapshot"]


@dataclass(frozen=True)
class FDConfig:
    """Static solver configuration (grid, fluid, time step, Poisson)."""

    n: int = 32               # grid points per side (periodic box 2*pi)
    nu: float = 0.01          # kinematic viscosity
    dt: float = 2e-3          # explicit Euler time step
    jacobi_iters: int = 64    # pressure Poisson sweeps per step

    def __post_init__(self):
        if self.n < 4:
            raise ValueError("n must be >= 4")
        if self.nu <= 0:
            raise ValueError("nu must be > 0")
        if self.dt <= 0:
            raise ValueError("dt must be > 0")
        if self.jacobi_iters < 1:
            raise ValueError("jacobi_iters must be >= 1")

    @property
    def h(self) -> float:
        return 2.0 * np.pi / self.n

    @property
    def shape(self) -> tuple[int, int]:
        return (self.n, self.n)

    def validate_shards(self, n_shards: int, axis: str = "space") -> None:
        """Fail fast on a grid/mesh mismatch: a non-dividing decomposition
        would otherwise surface deep inside ``shard_map`` as an opaque
        sharding error."""
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.n % n_shards != 0:
            raise ValueError(
                f"grid rows n={self.n} do not divide over the "
                f"{n_shards}-shard {axis!r} mesh axis: each shard must own "
                f"an equal n/{n_shards} row block — pick n a multiple of "
                f"the shard count (e.g. n={self.n - self.n % n_shards or n_shards * 4})")


class FDState(NamedTuple):
    """Solver state: velocity fields plus the clock (a pytree)."""

    u: jax.Array      # [n, n] x-velocity
    v: jax.Array      # [n, n] y-velocity
    t: jax.Array      # f32 scalar: physical time
    step: jax.Array   # i32 scalar: step count


# ---------------------------------------------------------------------------
# Initializers (built on the full grid; shard with jax.device_put after)
# ---------------------------------------------------------------------------

def _grid(cfg: FDConfig):
    x = jnp.arange(cfg.n, dtype=jnp.float32) * cfg.h
    return jnp.meshgrid(x, x, indexing="ij")


def taylor_green(cfg: FDConfig) -> FDState:
    """The 2-D Taylor–Green vortex ``u = cos x sin y, v = -sin x cos y``
    — exactly divergence-free under central differences, and the scheme's
    analytic decay benchmark (see module docstring)."""
    X, Y = _grid(cfg)
    return FDState(u=jnp.cos(X) * jnp.sin(Y), v=-jnp.sin(X) * jnp.cos(Y),
                   t=jnp.zeros((), jnp.float32),
                   step=jnp.zeros((), jnp.int32))


def decaying_turbulence(cfg: FDConfig, key, e0: float = 0.5,
                        k_peak: float = 4.0) -> FDState:
    """Decaying-HIT initial condition: a random band-limited
    streamfunction ``psi`` with energy peaked near ``k_peak``, velocities
    ``u = Dy psi, v = -Dx psi`` via the same central differences the
    solver uses — so the field is *exactly* discretely divergence-free —
    normalized to kinetic energy ``e0``."""
    kx = jnp.fft.fftfreq(cfg.n, d=1.0 / cfg.n)
    k2 = kx[:, None] ** 2 + kx[None, :] ** 2
    k = jnp.sqrt(k2)
    # band-limited von-Karman-ish spectrum; cut above n/4 to keep the
    # collocated projection's resolvable band (the wide Laplacian is
    # blind to the Nyquist checkerboard)
    amp = (k ** 2) * jnp.exp(-((k / k_peak) ** 2))
    amp = jnp.where((k > 0) & (k <= cfg.n / 4), amp, 0.0)
    noise = jax.random.normal(key, (cfg.n, cfg.n))
    psi = jnp.real(jnp.fft.ifft2(jnp.fft.fft2(noise) * amp)
                   ).astype(jnp.float32)
    h = cfg.h
    u = (jnp.roll(psi, -1, 1) - jnp.roll(psi, 1, 1)) / (2 * h)
    v = -(jnp.roll(psi, -1, 0) - jnp.roll(psi, 1, 0)) / (2 * h)
    e = 0.5 * jnp.mean(u * u + v * v)
    scale = jnp.sqrt(e0 / jnp.maximum(e, 1e-30))
    return FDState(u=u * scale, v=v * scale,
                   t=jnp.zeros((), jnp.float32),
                   step=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# The shared stencil kernel (exchange-parameterized)
# ---------------------------------------------------------------------------

def _advance(cfg: FDConfig, state: FDState, exchange) -> FDState:
    """One Chorin-projection step.  ``exchange(f, width)`` pads ``f``
    with ``width`` halo rows along dim 0 — the ONLY place shard topology
    enters; columns (dim 1) are whole on every shard, so their taps are
    local rolls."""
    h, dt, nu = cfg.h, cfg.dt, cfg.nu
    u, v = state.u, state.v

    def derivs(f):
        fp = exchange(f, 1)
        fx = (fp[2:] - fp[:-2]) / (2 * h)
        fxx = (fp[2:] - 2.0 * f + fp[:-2]) / (h * h)
        fy = (jnp.roll(f, -1, 1) - jnp.roll(f, 1, 1)) / (2 * h)
        fyy = (jnp.roll(f, -1, 1) - 2.0 * f + jnp.roll(f, 1, 1)) / (h * h)
        return fx, fy, fxx, fyy

    ux, uy, uxx, uyy = derivs(u)
    vx, vy, vxx, vyy = derivs(v)
    us = u + dt * (-(u * ux + v * uy) + nu * (uxx + uyy))
    vs = v + dt * (-(u * vx + v * vy) + nu * (vxx + vyy))

    # divergence of the provisional field (central differences)
    usp = exchange(us, 1)
    div = (usp[2:] - usp[:-2]) / (2 * h) \
        + (jnp.roll(vs, -1, 1) - jnp.roll(vs, 1, 1)) / (2 * h)
    rhs = div / dt

    # Jacobi on the wide Laplacian Dx Dx + Dy Dy (diagonal -1/h^2):
    # phi <- (phi_{i+2} + phi_{i-2} + phi_{j+2} + phi_{j-2}) / 4 - h^2 rhs
    def sweep(_, phi):
        pp = exchange(phi, 2)
        px = pp[4:] + pp[:-4]
        py = jnp.roll(phi, -2, 1) + jnp.roll(phi, 2, 1)
        return (px + py) * 0.25 - (h * h) * rhs

    phi = lax.fori_loop(0, cfg.jacobi_iters, sweep, jnp.zeros_like(us))

    pp = exchange(phi, 1)
    u_new = us - dt * (pp[2:] - pp[:-2]) / (2 * h)
    v_new = vs - dt * (jnp.roll(phi, -1, 1) - jnp.roll(phi, 1, 1)) / (2 * h)
    return FDState(u=u_new, v=v_new, t=state.t + dt, step=state.step + 1)


def make_step(cfg: FDConfig, mesh: Mesh | None = None,
              axis: str = "space"):
    """Build the jitted step ``state -> state``.

    ``mesh=None``: the single-device reference (global-array periodic
    padding).  With a mesh, the step runs inside ONE ``shard_map`` with
    rows partitioned over ``axis`` and every stencil tap fed by
    :func:`~.halo.halo_exchange` — after validating the grid divides the
    mesh (the fail-fast half of the sharding contract)."""
    if mesh is None:
        def exchange(f, width):
            return pad_reference(f, width=width, dim=0)

        return jax.jit(lambda state: _advance(cfg, state, exchange))

    cfg.validate_shards(int(mesh.shape[axis]), axis)
    from jax.experimental.shard_map import shard_map

    def exchange(f, width):
        return halo_exchange(f, axis=axis, width=width, dim=0,
                             boundary="periodic")

    specs = FDState(u=P(axis, None), v=P(axis, None), t=P(), step=P())
    body = shard_map(lambda state: _advance(cfg, state, exchange),
                     mesh=mesh, in_specs=(specs,), out_specs=specs,
                     check_rep=False)
    return jax.jit(body)


def shard_state(state: FDState, mesh: Mesh, axis: str = "space") -> FDState:
    """Place a full-grid state row-decomposed over ``axis`` (fields
    sharded, clock replicated)."""
    field = NamedSharding(mesh, P(axis, None))
    scalar = NamedSharding(mesh, P())
    return FDState(u=jax.device_put(state.u, field),
                   v=jax.device_put(state.v, field),
                   t=jax.device_put(state.t, scalar),
                   step=jax.device_put(state.step, scalar))


def make_producer(cfg: FDConfig, mesh: Mesh | None = None,
                  axis: str = "space", init: str = "taylor_green",
                  key=None):
    """Wire the solver into the in-situ data plane.

    Returns ``(step_fn, state0, elem_sharding)`` for a declarative
    ``insitu.Producer``: ``step_fn(carry, rank, t)`` advances one step
    and emits the stacked ``[2, n, n]`` velocity snapshot under a
    ``(rank 0, t)`` key; ``elem_sharding`` (``None`` off-mesh) carries
    the ``space`` axis into ``capture_scan`` so the emitted element is
    put shard-local — the ``capture_scan_sharded`` tier."""
    from ..core.store import make_key

    step = make_step(cfg, mesh, axis=axis)
    if init == "taylor_green":
        state0 = taylor_green(cfg)
    elif init == "decaying_turbulence":
        state0 = decaying_turbulence(
            cfg, key if key is not None else jax.random.key(0))
    else:
        raise ValueError(f"unknown init {init!r} (have "
                         f"('taylor_green', 'decaying_turbulence'))")
    elem_sharding = None
    if mesh is not None:
        state0 = shard_state(state0, mesh, axis)
        elem_sharding = NamedSharding(mesh, P(None, axis, None))

    def step_fn(carry, rank, t):
        nxt = step(carry)
        return nxt, make_key(0, t), jnp.stack([nxt.u, nxt.v])

    return step_fn, state0, elem_sharding


# ---------------------------------------------------------------------------
# Diagnostics
# ---------------------------------------------------------------------------

def taylor_green_factor(cfg: FDConfig) -> float:
    """Per-step velocity decay factor of the discrete Taylor–Green mode:
    ``1 - 2 nu dt lambda_h`` (energy decays as its square).  Approaches
    the continuum ``exp(-2 nu dt)`` as ``h -> 0`` (``lambda_h =
    (1 - h^2/12 + ...)``)."""
    lam = 4.0 * np.sin(cfg.h / 2.0) ** 2 / cfg.h ** 2
    return float(1.0 - 2.0 * cfg.nu * cfg.dt * lam)


@jax.jit
def energy(state: FDState) -> jax.Array:
    """Mean kinetic energy ``0.5 <u^2 + v^2>``."""
    return 0.5 * jnp.mean(state.u ** 2 + state.v ** 2)


@jax.jit
def snapshot(state: FDState) -> jax.Array:
    """The emitted table element: stacked ``[2, n, n]`` velocities."""
    return jnp.stack([state.u, state.v])


def max_divergence(cfg: FDConfig, state: FDState) -> jax.Array:
    """Max |central-difference divergence| — the invariant the projection
    maintains (down to the Jacobi residual)."""
    h = cfg.h
    div = (jnp.roll(state.u, -1, 0) - jnp.roll(state.u, 1, 0)) / (2 * h) \
        + (jnp.roll(state.v, -1, 1) - jnp.roll(state.v, 1, 1)) / (2 * h)
    return jnp.max(jnp.abs(div))
