"""Data-producer substrate: pseudo-spectral NS DNS (PHASTA analogue),
synthetic flat-plate boundary-layer snapshots, and the Fortran-reproducer
analogue that drives the scaling benchmarks."""

from . import flatplate, reproducer, spectral
from .flatplate import FlatPlateConfig
from .reproducer import ReproducerConfig
from .spectral import NSConfig, NSState

__all__ = ["flatplate", "reproducer", "spectral", "FlatPlateConfig",
           "ReproducerConfig", "NSConfig", "NSState"]
