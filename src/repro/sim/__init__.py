"""Data-producer substrate: pseudo-spectral NS DNS (PHASTA analogue),
the domain-decomposed finite-difference solver (``distributed`` +
``halo`` — the sharded producer of the ``capture_scan_sharded`` tier),
synthetic flat-plate boundary-layer snapshots, and the
Fortran-reproducer analogue that drives the scaling benchmarks."""

from . import distributed, flatplate, halo, reproducer, spectral
from .distributed import (FDConfig, FDState, decaying_turbulence,
                          make_producer, make_step, shard_state,
                          taylor_green, taylor_green_factor)
from .flatplate import FlatPlateConfig
from .halo import WALL_MODES, halo_exchange, halo_exchange_nd, pad_reference
from .reproducer import ReproducerConfig
from .spectral import NSConfig, NSState, partition_snapshot

__all__ = [
    "distributed", "flatplate", "halo", "reproducer", "spectral",
    "FDConfig", "FDState", "decaying_turbulence", "make_producer",
    "make_step", "shard_state", "taylor_green", "taylor_green_factor",
    "WALL_MODES", "halo_exchange", "halo_exchange_nd", "pad_reference",
    "FlatPlateConfig", "ReproducerConfig", "NSConfig", "NSState",
    "partition_snapshot",
]
