"""Synthetic turbulent flat-plate boundary-layer snapshots (paper §4 data).

The paper trains the QuadConv autoencoder on DNS of a flat-plate turbulent
boundary layer at Re_θ = 1000 on a 36M-element *non-uniform* grid (wall-normal
stretching).  A DNS of that flow is out of scope for a CPU container, so this
module synthesizes statistically-plausible boundary-layer snapshots on a
non-uniform structured grid:

* mean streamwise profile from the composite law of the wall
  (viscous sublayer u⁺ = y⁺ blended into the log law u⁺ = ln(y⁺)/κ + B);
* divergence-suppressed velocity fluctuations from a sum of random Fourier
  modes with a k⁻⁵ᐟ³-shaped amplitude spectrum, modulated by a wall-damped
  intensity profile (peak near y⁺≈15, vanishing at the wall);
* pressure fluctuations correlated with the fluctuation field;
* wall-normal grid geometrically stretched (the non-uniform quadrature
  points QuadConv is built for).

Snapshots evolve smoothly in a ``step`` parameter (frozen-turbulence
convection of the mode phases), so consecutive "time steps" are correlated
like real DNS output.  Everything is deterministic given (key, step).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["FlatPlateConfig", "grid_coords", "snapshot", "snapshot_batch"]

KAPPA = 0.41
B_LOG = 5.2


@dataclass(frozen=True)
class FlatPlateConfig:
    nx: int = 16
    ny: int = 16                # wall-normal (stretched)
    nz: int = 8
    n_modes: int = 32           # random Fourier modes
    re_tau: float = 400.0       # friction Reynolds number
    stretch: float = 2.5        # wall-normal geometric stretching strength
    lx: float = 6.0
    lz: float = 3.0
    u_conv: float = 0.5         # frozen-turbulence convection speed

    @property
    def n_points(self) -> int:
        return self.nx * self.ny * self.nz

    @property
    def channels(self) -> int:
        return 4                 # (p, u, v, w)


def grid_coords(cfg: FlatPlateConfig) -> jax.Array:
    """Non-uniform grid coordinates, shape [n_points, 3] (x, y, z).

    y uses tanh clustering toward the wall (y=0) — the canonical BL grid.
    """
    x = jnp.linspace(0.0, cfg.lx, cfg.nx, endpoint=False)
    eta = jnp.linspace(0.0, 1.0, cfg.ny)
    y = 1.0 - jnp.tanh(cfg.stretch * (1.0 - eta)) / jnp.tanh(cfg.stretch)
    z = jnp.linspace(0.0, cfg.lz, cfg.nz, endpoint=False)
    X, Y, Z = jnp.meshgrid(x, y, z, indexing="ij")
    return jnp.stack([X.ravel(), Y.ravel(), Z.ravel()], axis=-1)


def _mean_profile(cfg: FlatPlateConfig, y: jax.Array) -> jax.Array:
    """Composite law-of-the-wall mean streamwise velocity (in u_τ units)."""
    yplus = jnp.maximum(y * cfg.re_tau, 1e-6)
    visc = yplus
    log = jnp.log(yplus) / KAPPA + B_LOG
    # Reichardt-style smooth blend
    blend = 1.0 - jnp.exp(-yplus / 11.0)
    return (1 - blend) * visc + blend * jnp.minimum(log, visc + 20.0)


def _intensity(y: jax.Array, re_tau: float) -> jax.Array:
    """Wall-damped turbulence intensity, peaking near y⁺ ≈ 15."""
    yplus = jnp.maximum(y * re_tau, 0.0)
    return (yplus / 15.0) * jnp.exp(1.0 - yplus / 15.0) * 2.0 + 0.1 * (
        jnp.exp(-y)
    )


@partial(jax.jit, static_argnums=0)
def snapshot(cfg: FlatPlateConfig, key, step) -> jax.Array:
    """One (p,u,v,w) snapshot, shape [4, n_points] on the stretched grid."""
    coords = grid_coords(cfg)                       # [N,3]
    x, y, z = coords[:, 0], coords[:, 1], coords[:, 2]

    km = jax.random.split(key, 4)
    # random mode wavevectors (streamwise/spanwise periodic, wall-normal free)
    kvec = jax.random.normal(km[0], (cfg.n_modes, 3)) * jnp.array([4.0, 8.0, 4.0])
    phase0 = jax.random.uniform(km[1], (cfg.n_modes,), maxval=2 * jnp.pi)
    # Kolmogorov-ish amplitude decay |k|^{-5/6} per component (energy k^-5/3)
    kmag = jnp.linalg.norm(kvec, axis=-1) + 1e-3
    amp = kmag ** (-5.0 / 6.0)
    amp = amp / jnp.sqrt(jnp.sum(amp ** 2))
    # random unit polarization ⊥ k  (suppresses divergence mode-by-mode)
    raw = jax.random.normal(km[2], (cfg.n_modes, 3))
    pol = raw - kvec * jnp.sum(raw * kvec, -1, keepdims=True) / (kmag[:, None] ** 2)
    pol = pol / (jnp.linalg.norm(pol, axis=-1, keepdims=True) + 1e-8)

    t = jnp.asarray(step, jnp.float32)
    # frozen turbulence: phases convect downstream with u_conv
    phases = (coords @ kvec.T) + phase0[None, :] - cfg.u_conv * t * kvec[None, :, 0]
    waves = jnp.sin(phases)                          # [N, M]
    fluct = (waves * amp[None, :]) @ pol             # [N, 3]
    fluct = fluct * _intensity(y, cfg.re_tau)[:, None]

    u = _mean_profile(cfg, y) + fluct[:, 0] * 2.0
    v = fluct[:, 1]
    w = fluct[:, 2]
    # pressure fluctuations: low-pass-ish combination of the same modes
    p_amp = amp * (kmag ** (-1.0 / 3.0))
    p = (jnp.cos(phases) * p_amp[None, :]).sum(-1) * _intensity(y, cfg.re_tau)
    return jnp.stack([p, u, v, w]).astype(jnp.float32)


@partial(jax.jit, static_argnums=(0, 3))
def snapshot_batch(cfg: FlatPlateConfig, key, step0, n: int) -> jax.Array:
    """``n`` consecutive steps, shape [n, 4, n_points]."""
    steps = jnp.asarray(step0) + jnp.arange(n)
    return jax.vmap(lambda s: snapshot(cfg, key, s))(steps)
