"""Simulation reproducer (paper §3): the Fortran send/retrieve driver.

The paper's scaling study does not run PHASTA; it runs a Fortran
"reproducer" that (1) initializes a SmartRedis client per rank, (2) loops
over time steps, sleeping to emulate PDE integration, (3) sends its data
contribution with a rank/step key, and (4) retrieves it back.  For the
inference benchmarks the reproducer also loads a model and evaluates it in
each iteration.

This module is that reproducer, rank-for-rank: it drives every scaling
benchmark (Figs 3-8).  ``run_transfer`` does the send/retrieve loop;
``run_inference`` does the send/run_model/retrieve loop.  Both return the
per-component ``Timers`` (mean/std across iterations) the figures plot.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..core.client import Client
from ..core.server import StoreServer
from ..core.store import TableSpec, make_key
from ..core.telemetry import Timers

__all__ = ["ReproducerConfig", "run_transfer", "run_inference"]


@dataclass(frozen=True)
class ReproducerConfig:
    n_ranks: int = 24            # simulation ranks (paper: 24/node)
    bytes_per_rank: int = 256 * 1024   # paper default message size
    iterations: int = 40         # paper: 40 timed iterations
    warmup: int = 2              # paper: 2 discarded warmup iterations
    compute_s: float = 0.0       # sleep emulating PDE integration
    dtype: str = "float32"

    @property
    def elems_per_rank(self) -> int:
        return self.bytes_per_rank // jnp.dtype(self.dtype).itemsize

    def table_spec(self, capacity: int | None = None) -> TableSpec:
        # One slab row per rank, ring-buffered over a window of steps.
        return TableSpec(
            name="repro",
            shape=(self.elems_per_rank,),
            dtype=self.dtype,
            capacity=capacity or max(2 * self.n_ranks, 8),
            engine="ring",
        )


def _payload(cfg: ReproducerConfig, seed: int = 0) -> jax.Array:
    """All ranks' contributions for one step: [n_ranks, elems]."""
    key = jax.random.key(seed)
    return jax.random.normal(
        key, (cfg.n_ranks, cfg.elems_per_rank), dtype=cfg.dtype
    )


def run_transfer(cfg: ReproducerConfig, server: StoreServer,
                 vectorized: bool = True) -> Timers:
    """The paper's data-transfer loop: sleep, send, retrieve, repeat.

    ``vectorized=True`` sends all ranks' tensors in one ``put_many`` (one
    dispatch per step — how a sharded producer actually behaves on a TPU
    mesh: every chip writes its shard of the same step concurrently).
    ``vectorized=False`` issues one put per rank (per-client requests, the
    Polaris picture) — used to study request-count contention.
    """
    if "repro" not in server.tables():
        server.create_table(cfg.table_spec())
    client = Client(server)
    data = _payload(cfg)
    jax.block_until_ready(data)
    timers = client.timers

    for it in range(cfg.warmup + cfg.iterations):
        if it == cfg.warmup:
            timers = Timers()
            client.timers = timers
        if cfg.compute_s:
            time.sleep(cfg.compute_s)
        step = it
        if vectorized:
            client.send_batch("repro", step, data)
            keys = make_key(jnp.arange(cfg.n_ranks), jnp.full(cfg.n_ranks, step))
            with timers.time("retrieve") as box:
                vals, founds = server.get_many("repro", keys)
                box[0] = vals
        else:
            for rank in range(cfg.n_ranks):
                rc = Client(server, rank=rank, timers=timers)
                rc.send_step("repro", step, data[rank])
            for rank in range(cfg.n_ranks):
                rc = Client(server, rank=rank, timers=timers)
                rc.retrieve_step("repro", rank, step)
    return timers


def run_inference(cfg: ReproducerConfig, server: StoreServer, model_key: str,
                  batch: jax.Array, fused: bool = False) -> Timers:
    """The paper's inference loop: send → run_model → retrieve each step.

    ``batch`` is the per-step inference input (e.g. ResNet50 images
    [n,3,224,224]).  The model must already be registered on the server.
    ``fused=True`` uses the single-dispatch fast path instead of the
    three-step protocol (the beyond-paper optimization benchmarked against
    the faithful path in Fig. 7's harness).
    """
    client = Client(server)
    # Output spec discovered once via eval_shape on the registered model.
    fn, params = server._models[model_key]
    out_shape = jax.eval_shape(fn, params, batch)
    if "infer_in" not in server.tables():
        server.create_table(TableSpec("infer_in", shape=batch.shape,
                                      dtype=batch.dtype, capacity=2,
                                      engine="hash"))
        server.create_table(TableSpec("infer_out", shape=out_shape.shape,
                                      dtype=out_shape.dtype, capacity=2,
                                      engine="hash"))
    jax.block_until_ready(batch)
    timers = client.timers

    for it in range(cfg.warmup + cfg.iterations):
        if it == cfg.warmup:
            timers = Timers()
            client.timers = timers
        if cfg.compute_s:
            time.sleep(cfg.compute_s)
        if fused:
            y = client.infer(model_key, batch)
            jax.block_until_ready(y)
            continue
        client.put_tensor("x", batch, table="infer_in")
        client.run_model(model_key, inputs=["x"], outputs=["y"],
                         table="infer_in", out_table="infer_out")
        y, found = client.get_tensor("y", table="infer_out")
        jax.block_until_ready(y)
    return timers
