"""Width-w halo exchange over a replica grid (the Swirl-LM shape).

The paper's producer is PHASTA: an MPI-decomposed solver whose ranks
advance a structured-mesh stencil and communicate only their subdomain
*faces* each step.  Swirl-LM (Wang et al., PAPERS.md) shows the TPU-native
form of that pattern — every replica holds one subdomain block and a
per-step ``lax.ppermute`` moves the boundary faces between neighbors —
which is what scales a finite-difference solver to a pod without any
global collective.

This module is that exchange, factored out of any particular solver:

* :func:`halo_exchange` — pad a shard-local block with ``width`` rows of
  neighbor data along one array dim, communicating over one named mesh
  axis *inside a* ``shard_map``.  The only collective it emits is the
  pair of ``lax.ppermute`` ops (one per direction) — the compiled-HLO
  claim ``insitu.plan`` makes for the sharded producer tier.
* :func:`halo_exchange_nd` — the 1-D/2-D replica-grid form: sequential
  per-axis application; the second axis exchanges the already-padded
  faces, so corner halos fill consistently without extra messages.
* :func:`pad_reference` — the single-device ground truth (global-array
  padding with the same boundary semantics), used by the parity tests
  and the un-sharded reference solver.

Boundary conditions:

* ``boundary="periodic"`` — cyclic neighbor permutation (shard ``n-1``
  feeds shard ``0``); the whole exchange is two ppermutes, nothing else.
* ``boundary="wall"`` — the permutation is non-cyclic (``ppermute``
  zero-fills the edge shards' missing neighbor), and the edge shards
  overwrite their outer halo with a wall fill computed from *local*
  data: ``wall="zero"`` (Dirichlet-0 ghost), ``"reflect"`` (mirrored
  interior rows — symmetry / slip wall), or ``"reflect_neg"`` (negated
  mirror — no-slip wall for the tangential velocity).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

__all__ = ["WALL_MODES", "halo_exchange", "halo_exchange_nd",
           "pad_reference"]

WALL_MODES = ("zero", "reflect", "reflect_neg")


def _shift_perm(n: int, shift: int, cyclic: bool) -> list[tuple[int, int]]:
    """(source, dest) pairs moving each shard's face ``shift`` replicas
    over a 1-D axis of ``n`` shards.  Non-cyclic perms omit the wrap pair;
    ``ppermute`` zero-fills destinations no source names."""
    if cyclic:
        return [(i, (i + shift) % n) for i in range(n)]
    return [(i, i + shift) for i in range(n) if 0 <= i + shift < n]


def _wall_fill(x, dim: int, width: int, side: str, wall: str):
    """Ghost rows for a wall boundary, from the block's own edge rows."""
    if wall == "zero":
        shape = list(x.shape)
        shape[dim] = width
        return jnp.zeros(shape, x.dtype)
    n = x.shape[dim]
    if side == "low":
        face = lax.slice_in_dim(x, 0, width, axis=dim)
    else:
        face = lax.slice_in_dim(x, n - width, n, axis=dim)
    face = jnp.flip(face, axis=dim)
    return -face if wall == "reflect_neg" else face


def _check(x, axis, width, dim, boundary, wall) -> None:
    if boundary not in ("periodic", "wall"):
        raise ValueError(f"unknown boundary {boundary!r} "
                         f"(have ('periodic', 'wall'))")
    if wall not in WALL_MODES:
        raise ValueError(f"unknown wall mode {wall!r} (have {WALL_MODES})")
    if width < 1:
        raise ValueError("halo width must be >= 1")
    if width > x.shape[dim]:
        raise ValueError(
            f"halo width {width} exceeds the local block extent "
            f"{x.shape[dim]} along dim {dim} (axis {axis!r}): each shard "
            f"must own at least `width` rows to fill its neighbor's halo")


def halo_exchange(x, *, axis: str, width: int = 1, dim: int = 0,
                  boundary: str = "periodic", wall: str = "zero"):
    """Pad ``x`` with ``width`` halo rows of neighbor data on both sides
    of array dim ``dim``, exchanged over mesh axis ``axis``.

    Call *inside* a ``shard_map`` whose in-spec partitions ``dim`` over
    ``axis``; returns the local block grown by ``2 * width`` along
    ``dim`` (``inplace``-style: the caller slices stencil taps out of the
    padded block, never reassembles a global array).  The send is the
    block's own edge faces, so chained stencil applications re-exchange
    rather than trusting stale halos.
    """
    _check(x, axis, width, dim, boundary, wall)
    # psum of a Python scalar over a named axis folds to the static axis
    # size (jax has no lax.axis_size) — the perm lists below must be
    # static.
    n = int(lax.psum(1, axis))
    cyclic = boundary == "periodic"
    lo_face = lax.slice_in_dim(x, 0, width, axis=dim)
    hi_face = lax.slice_in_dim(x, x.shape[dim] - width, x.shape[dim],
                               axis=dim)
    # the +1 shift carries each shard's high face into its upper
    # neighbor's LOW halo, and vice versa
    recv_lo = lax.ppermute(hi_face, axis, _shift_perm(n, +1, cyclic))
    recv_hi = lax.ppermute(lo_face, axis, _shift_perm(n, -1, cyclic))
    if not cyclic:
        idx = lax.axis_index(axis)
        recv_lo = jnp.where(idx == 0,
                            _wall_fill(x, dim, width, "low", wall), recv_lo)
        recv_hi = jnp.where(idx == n - 1,
                            _wall_fill(x, dim, width, "high", wall),
                            recv_hi)
    return jnp.concatenate([recv_lo, x, recv_hi], axis=dim)


def halo_exchange_nd(x, *, axes, width: int = 1, boundary: str = "periodic",
                     wall: str = "zero"):
    """Halo exchange over a 1-D/2-D replica grid: ``axes`` is a sequence
    of ``(mesh_axis, array_dim)`` pairs, applied sequentially.

    Each pass exchanges the block as padded by the previous passes, so
    after the second axis the corner halos hold the diagonal neighbor's
    data — the standard two-message corner trick (no explicit diagonal
    ppermute needed)."""
    for axis, dim in axes:
        x = halo_exchange(x, axis=axis, width=width, dim=dim,
                          boundary=boundary, wall=wall)
    return x


def pad_reference(x, *, width: int = 1, dim: int = 0,
                  boundary: str = "periodic", wall: str = "zero"):
    """Single-device ground truth: pad the *global* array with the same
    boundary semantics :func:`halo_exchange` gives the shard at each
    domain edge.  A stencil applied to this padded array equals the
    gathered shard-local stencils — the parity the tests assert."""
    _check(x, "<global>", width, dim, boundary, wall)
    n = x.shape[dim]
    if boundary == "periodic":
        lo = lax.slice_in_dim(x, n - width, n, axis=dim)
        hi = lax.slice_in_dim(x, 0, width, axis=dim)
    else:
        lo = _wall_fill(x, dim, width, "low", wall)
        hi = _wall_fill(x, dim, width, "high", wall)
    return jnp.concatenate([lo, x, hi], axis=dim)
