"""TensorStore: a device-resident, sharded, in-memory key-value tensor store.

This is the TPU-native analogue of the SmartSim-deployed Redis/KeyDB database
of Balin et al. (2023).  On Polaris the database is an OS process holding
tensors in node-local DRAM, addressed by string keys over TCP.  On a TPU pod
there is no node-local service to talk to; instead the store is *state*:

  * each **table** is a fixed-capacity slab ``[capacity, *elem_shape]`` living
    in device HBM, plus per-slot metadata (``keys``, ``version``) and scalar
    cursors (``ptr``, ``count``);
  * all operations (``put`` / ``get`` / ``sample`` / ``poll`` / ``delete``)
    are pure jit-compatible functions ``state -> state`` so they can run
    standalone (the loosely-coupled paper path, dispatched by host threads)
    **or fused into a producer/consumer step** (in-situ capture with zero
    dispatch overhead — a beyond-paper optimization);
  * the slab is sharded across the mesh.  With the **co-located** deployment
    the element dims carry the *same* PartitionSpec as the producer's output,
    so a put lowers to a pure local dynamic-update-slice: **zero collective
    bytes**, the structural equivalent of the paper's "all data transfer is
    contained within each node".  (Asserted from compiled HLO in tests and
    reported in the roofline.)

Two storage **engines** mirror the paper's Redis-vs-KeyDB comparison:

  * ``ring``  — slots assigned by a monotone write pointer, oldest snapshot
    overwritten first.  Natural for streaming solution states ("unique key
    per rank and step" in the paper, with an explicit finite-memory window).
  * ``hash``  — slot = key mod capacity; idempotent same-key overwrite.
    Natural for named tensors, metadata and model buffers.

Versions are strictly increasing per-table write stamps (``count``+1), giving
consumers a total order: ``latest``/``sample`` implement the paper's
data-loader that "gathers tensors at random" or takes the freshest ones, and
the scalar ``count`` doubles as the watermark used for epoch gating.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TableSpec",
    "TableState",
    "make_key",
    "name_key",
    "init_table",
    "put",
    "put_many",
    "get",
    "get_many",
    "sample",
    "latest",
    "poll",
    "delete",
    "valid_count",
    "table_bytes",
]

KEY_DTYPE = jnp.uint32
EMPTY_KEY = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Keys.  SmartRedis addresses tensors with strings like "x.rank_3.step_120";
# device-side we need integers.  Host code hashes names (crc32) or packs
# (rank, step) into the 32-bit key space.
# ---------------------------------------------------------------------------

def name_key(name: str) -> int:
    """Stable 32-bit key for a string tensor name (crc32, never EMPTY_KEY)."""
    k = zlib.crc32(name.encode()) & 0xFFFFFFFE  # keep EMPTY_KEY reserved
    return int(k)


def make_key(rank, step) -> Any:
    """Pack (rank, step) into a uint32 key; works on ints or traced arrays.

    rank in [0, 2^12), step in [0, 2^19) -> key = 1<<31 | step<<12 | rank.
    The top bit keeps packed keys disjoint from crc32 name keys' typical
    range and away from EMPTY_KEY (which has all bits set).
    """
    rank = jnp.asarray(rank, dtype=KEY_DTYPE)
    step = jnp.asarray(step, dtype=KEY_DTYPE)
    key = (jnp.uint32(1) << 31) | ((step & jnp.uint32(0x7FFFF)) << 12) | (
        rank & jnp.uint32(0xFFF)
    )
    # Avoid the reserved EMPTY_KEY bit pattern.
    return jnp.where(key == EMPTY_KEY, jnp.uint32(0x7FFFFFFF), key)


# ---------------------------------------------------------------------------
# Table spec + state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TableSpec:
    """Static description of one store table."""

    name: str
    shape: tuple[int, ...]          # element shape
    dtype: Any = jnp.float32
    capacity: int = 16
    engine: str = "ring"            # "ring" | "hash"

    def __post_init__(self):
        if self.engine not in ("ring", "hash"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")

    @property
    def elem_bytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * jnp.dtype(self.dtype).itemsize

    @property
    def slab_bytes(self) -> int:
        return self.capacity * self.elem_bytes


class TableState(NamedTuple):
    """Device-resident state of one table (a pytree)."""

    slab: jax.Array      # [capacity, *shape]
    keys: jax.Array      # uint32[capacity]; EMPTY_KEY where never written
    version: jax.Array   # int32[capacity]; 0 where empty, else write stamp
    ptr: jax.Array       # int32 scalar: next ring slot
    count: jax.Array     # int32 scalar: total successful puts (watermark)


def init_table(spec: TableSpec, slab_sharding=None) -> TableState:
    """Allocate an empty table, optionally with an explicit slab sharding.

    When the slab lives on a mesh, the per-slot metadata (keys/version) and
    cursors are replicated on the *same* mesh so every store op is a single
    SPMD computation."""
    slab = jnp.zeros((spec.capacity, *spec.shape), dtype=spec.dtype)
    meta_sharding = None
    if slab_sharding is not None:
        slab = jax.device_put(slab, slab_sharding)
        from jax.sharding import NamedSharding, PartitionSpec
        if hasattr(slab_sharding, "mesh"):
            meta_sharding = NamedSharding(slab_sharding.mesh,
                                          PartitionSpec())

    def _meta(x):
        return jax.device_put(x, meta_sharding) if meta_sharding is not None \
            else x

    return TableState(
        slab=slab,
        keys=_meta(jnp.full((spec.capacity,), EMPTY_KEY, dtype=KEY_DTYPE)),
        version=_meta(jnp.zeros((spec.capacity,), dtype=jnp.int32)),
        ptr=_meta(jnp.zeros((), dtype=jnp.int32)),
        count=_meta(jnp.zeros((), dtype=jnp.int32)),
    )


def table_bytes(spec: TableSpec) -> int:
    """HBM footprint of the table (slab + metadata)."""
    return spec.slab_bytes + spec.capacity * (4 + 4) + 8


# ---------------------------------------------------------------------------
# Slot resolution
# ---------------------------------------------------------------------------

def _slot_for_put(spec: TableSpec, state: TableState, key) -> jax.Array:
    if spec.engine == "ring":
        return state.ptr
    # hash engine: reuse an existing slot holding this key (idempotent
    # overwrite), else key mod capacity.
    homed = jnp.asarray(key, KEY_DTYPE) % jnp.uint32(spec.capacity)
    match = (state.keys == jnp.asarray(key, KEY_DTYPE)) & (state.version > 0)
    existing = jnp.argmax(match).astype(jnp.int32)
    return jnp.where(jnp.any(match), existing, homed.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Core ops (all pure, jit-compatible; spec is static)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnums=0, donate_argnums=1)
def put(spec: TableSpec, state: TableState, key, value) -> TableState:
    """Insert/overwrite one element.  O(1) slab dynamic-update-slice."""
    value = jnp.asarray(value, dtype=spec.dtype)
    if value.shape != spec.shape:
        raise ValueError(
            f"put into table {spec.name!r}: value shape {value.shape} != "
            f"element shape {spec.shape}"
        )
    slot = _slot_for_put(spec, state, key)
    stamp = state.count + 1
    new_ptr = (state.ptr + 1) % spec.capacity if spec.engine == "ring" else state.ptr
    return TableState(
        slab=jax.lax.dynamic_update_index_in_dim(state.slab, value, slot, 0),
        keys=state.keys.at[slot].set(jnp.asarray(key, KEY_DTYPE)),
        version=state.version.at[slot].set(stamp),
        ptr=new_ptr,
        count=stamp,
    )


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def put_many(spec: TableSpec, state: TableState, keys, values) -> TableState:
    """Vectorized put of n elements (one producer step sending all ranks).

    ``ring``: consecutive slots from the write pointer.
    ``hash``: slot = key mod capacity — caller must ensure keys are distinct
    mod capacity within one batch (the Client's rank/step packing guarantees
    this for rank-partitioned sends).
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    values = jnp.asarray(values, dtype=spec.dtype)
    n = keys.shape[0]
    if values.shape != (n, *spec.shape):
        raise ValueError(
            f"put_many into {spec.name!r}: values {values.shape} != "
            f"({n}, *{spec.shape})"
        )
    if spec.engine == "ring":
        slots = (state.ptr + jnp.arange(n, dtype=jnp.int32)) % spec.capacity
        new_ptr = (state.ptr + n) % spec.capacity
    else:
        slots = (keys % jnp.uint32(spec.capacity)).astype(jnp.int32)
        new_ptr = state.ptr
    stamps = state.count + 1 + jnp.arange(n, dtype=jnp.int32)
    return TableState(
        slab=state.slab.at[slots].set(values),
        keys=state.keys.at[slots].set(keys),
        version=state.version.at[slots].set(stamps),
        ptr=new_ptr,
        count=state.count + n,
    )


@partial(jax.jit, static_argnums=0)
def get(spec: TableSpec, state: TableState, key):
    """Fetch by key.  Returns ``(value, found)``; value is zeros if absent."""
    match = (state.keys == jnp.asarray(key, KEY_DTYPE)) & (state.version > 0)
    found = jnp.any(match)
    idx = jnp.argmax(match).astype(jnp.int32)
    value = jax.lax.dynamic_index_in_dim(state.slab, idx, 0, keepdims=False)
    value = jnp.where(found, value, jnp.zeros_like(value))
    return value, found


@partial(jax.jit, static_argnums=0)
def get_many(spec: TableSpec, state: TableState, keys):
    """Vectorized get.  Returns ``(values [n,*shape], founds [n])``."""
    keys = jnp.asarray(keys, KEY_DTYPE)
    match = (state.keys[None, :] == keys[:, None]) & (state.version > 0)[None, :]
    founds = jnp.any(match, axis=1)
    idx = jnp.argmax(match, axis=1)
    values = state.slab[idx]
    values = jnp.where(
        founds.reshape((-1,) + (1,) * len(spec.shape)), values, 0
    ).astype(spec.dtype)
    return values, founds


@partial(jax.jit, static_argnums=(0, 3))
def sample(spec: TableSpec, state: TableState, rng, n: int):
    """Uniformly sample ``n`` valid elements (with replacement).

    This is the in-situ data loader: the paper's ML ranks "retrieve multiple
    tensors from the database at random" before each epoch.
    Returns ``(values [n,*shape], keys [n], ok)`` where ``ok`` is False if
    the table is empty (values are zeros then).
    """
    valid = state.version > 0
    nvalid = jnp.sum(valid)
    ok = nvalid > 0
    # Uniform over valid slots; empty table falls back to slot 0 + ok=False.
    logits = jnp.where(valid, 0.0, -jnp.inf)
    logits = jnp.where(ok, logits, jnp.zeros_like(logits))
    slots = jax.random.categorical(rng, logits, shape=(n,))
    values = jnp.where(ok, state.slab[slots],
                       jnp.zeros((n, *spec.shape), spec.dtype))
    return values, state.keys[slots], ok


@partial(jax.jit, static_argnums=(0, 2))
def latest(spec: TableSpec, state: TableState, n: int):
    """The ``n`` most recently written elements (newest first).

    Returns ``(values [n,*shape], keys [n], valid [n])``.
    """
    _, slots = jax.lax.top_k(state.version, n)
    vals = state.slab[slots]
    return vals, state.keys[slots], state.version[slots] > 0


@partial(jax.jit, static_argnums=0)
def poll(spec: TableSpec, state: TableState, key) -> jax.Array:
    """Does ``key`` exist?  (SmartRedis ``poll_tensor`` single check.)"""
    return jnp.any((state.keys == jnp.asarray(key, KEY_DTYPE))
                   & (state.version > 0))


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def delete(spec: TableSpec, state: TableState, key) -> TableState:
    """Tombstone every slot holding ``key`` (slab data left in place)."""
    match = (state.keys == jnp.asarray(key, KEY_DTYPE))
    return state._replace(
        version=jnp.where(match, 0, state.version),
        keys=jnp.where(match, EMPTY_KEY, state.keys),
    )


@partial(jax.jit, static_argnums=0)
def valid_count(spec: TableSpec, state: TableState) -> jax.Array:
    return jnp.sum(state.version > 0)


# Non-jit convenience: functional update preserving NamedTuple type.
def _replace_state(state: TableState, **kw) -> TableState:
    return state._replace(**kw)
