"""TensorStore: a device-resident, sharded, in-memory key-value tensor store.

This is the TPU-native analogue of the SmartSim-deployed Redis/KeyDB database
of Balin et al. (2023).  On Polaris the database is an OS process holding
tensors in node-local DRAM, addressed by string keys over TCP.  On a TPU pod
there is no node-local service to talk to; instead the store is *state*:

  * each **table** is a fixed-capacity slab ``[capacity, *elem_shape]`` living
    in device HBM, plus per-slot metadata (``keys``, ``version``) and scalar
    cursors (``ptr``, ``count``);
  * all operations (``put`` / ``get`` / ``sample`` / ``poll`` / ``delete``)
    are pure jit-compatible functions ``state -> state`` so they can run
    standalone (the loosely-coupled paper path, dispatched by host threads)
    **or fused into a producer/consumer step** (in-situ capture with zero
    dispatch overhead — a beyond-paper optimization);
  * the slab is sharded across the mesh.  With the **co-located** deployment
    the element dims carry the *same* PartitionSpec as the producer's output,
    so a put lowers to a pure local dynamic-update-slice: **zero collective
    bytes**, the structural equivalent of the paper's "all data transfer is
    contained within each node".  (Asserted from compiled HLO in tests and
    reported in the roofline.)

Two storage **engines** mirror the paper's Redis-vs-KeyDB comparison:

  * ``ring``  — slots assigned by a monotone write pointer, oldest snapshot
    overwritten first.  Natural for streaming solution states ("unique key
    per rank and step" in the paper, with an explicit finite-memory window).
  * ``hash``  — slot = key mod capacity; idempotent same-key overwrite.
    Natural for named tensors, metadata and model buffers.

Versions are strictly increasing per-table write stamps (``count``+1), giving
consumers a total order: ``latest``/``sample`` implement the paper's
data-loader that "gathers tensors at random" or takes the freshest ones, and
the scalar ``count`` doubles as the watermark used for epoch gating.

Fused in-situ pipeline (the hot path)
-------------------------------------

Two access tiers share these ops:

* **Per-verb** (paper-fidelity): every client verb is one host dispatch —
  flexible, measurable component-by-component, but the driver pays one
  dispatch plus one lock round-trip per verb.  Use it for control-plane
  traffic, irregular access, and paper-comparison benchmarks.
* **Fused** (beyond-paper): ``capture_scan`` folds ``k`` producer steps and
  their ring puts into a single ``jax.lax.scan`` dispatch
  (``capture_scan_multi`` is the R-rank form: per-rank ``t0`` clocks, all
  ranks' snapshots interleaved into the ring each emitting step);
  ``put_stream`` batches a whole trajectory of sends into one ``put_many``;
  ``sample_and_step`` runs the consumer's gather *and* its training
  microstep inside one jit.  One epoch of ``ml.trainer.insitu_train``
  costs O(1) dispatches instead of O(gather·batches).  Use it whenever the
  producer/consumer step is itself jit-traceable (the common case).

Everywhere a fused op batches writes, slot collisions keep the per-verb
semantics: **last-writer-wins** in trace order, with every overwrite still
bumping ``count`` — a fused trajectory is byte-identical to replaying its
verbs one dispatch at a time.

The gather-side verbs (``get_many`` / ``sample``) route through the Pallas
package ``repro.kernels.store`` (probe / sample / gather kernels on TPU,
pure-jnp oracle elsewhere); neither tier materializes an ``[n, capacity]``
match matrix.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.store import ops as _kops

__all__ = [
    "TableSpec",
    "TableState",
    "make_key",
    "name_key",
    "init_table",
    "put",
    "put_many",
    "put_masked",
    "put_stream",
    "get",
    "get_many",
    "serve_batch",
    "sample",
    "sample_sharded_impl",
    "latest",
    "poll",
    "delete",
    "valid_count",
    "table_bytes",
    "capture_scan",
    "capture_scan_multi",
    "capture_scan_collect",
    "capture_scan_collect_multi",
    "capture_rows",
    "capture_emit_count",
    "capture_emit_count_multi",
    "bucket_length",
    "MIN_BUCKET",
    "sample_and_step",
    "make_clustered_gather",
]

KEY_DTYPE = jnp.uint32
EMPTY_KEY = np.uint32(0xFFFFFFFF)


# ---------------------------------------------------------------------------
# Keys.  SmartRedis addresses tensors with strings like "x.rank_3.step_120";
# device-side we need integers.  Host code hashes names (crc32) or packs
# (rank, step) into the 32-bit key space.
# ---------------------------------------------------------------------------

def name_key(name: str) -> int:
    """Stable 32-bit key for a string tensor name (crc32, never EMPTY_KEY)."""
    k = zlib.crc32(name.encode()) & 0xFFFFFFFE  # keep EMPTY_KEY reserved
    return int(k)


def make_key(rank, step) -> Any:
    """Pack (rank, step) into a uint32 key; works on ints or traced arrays.

    rank in [0, 2^12), step in [0, 2^19) -> key = 1<<31 | step<<12 | rank.
    The top bit keeps packed keys disjoint from crc32 name keys' typical
    range and away from EMPTY_KEY (which has all bits set).
    """
    rank = jnp.asarray(rank, dtype=KEY_DTYPE)
    step = jnp.asarray(step, dtype=KEY_DTYPE)
    key = (jnp.uint32(1) << 31) | ((step & jnp.uint32(0x7FFFF)) << 12) | (
        rank & jnp.uint32(0xFFF)
    )
    # Avoid the reserved EMPTY_KEY bit pattern.
    return jnp.where(key == EMPTY_KEY, jnp.uint32(0x7FFFFFFF), key)


# ---------------------------------------------------------------------------
# Table spec + state
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TableSpec:
    """Static description of one store table."""

    name: str
    shape: tuple[int, ...]          # element shape
    dtype: Any = jnp.float32
    capacity: int = 16
    engine: str = "ring"            # "ring" | "hash"

    def __post_init__(self):
        if self.engine not in ("ring", "hash"):
            raise ValueError(f"unknown engine {self.engine!r}")
        if self.capacity < 1:
            raise ValueError("capacity must be >= 1")

    @property
    def elem_bytes(self) -> int:
        return int(np.prod(self.shape, dtype=np.int64)) * jnp.dtype(self.dtype).itemsize

    @property
    def slab_bytes(self) -> int:
        return self.capacity * self.elem_bytes


class TableState(NamedTuple):
    """Device-resident state of one table (a pytree)."""

    slab: jax.Array      # [capacity, *shape]
    keys: jax.Array      # uint32[capacity]; EMPTY_KEY where never written
    version: jax.Array   # int32[capacity]; 0 where empty, else write stamp
    ptr: jax.Array       # int32 scalar: next ring slot
    count: jax.Array     # int32 scalar: total successful puts (watermark)


def init_table(spec: TableSpec, slab_sharding=None) -> TableState:
    """Allocate an empty table, optionally with an explicit slab sharding.

    When the slab lives on a mesh, the per-slot metadata (keys/version) and
    cursors are replicated on the *same* mesh so every store op is a single
    SPMD computation."""
    slab = jnp.zeros((spec.capacity, *spec.shape), dtype=spec.dtype)
    meta_sharding = None
    if slab_sharding is not None:
        slab = jax.device_put(slab, slab_sharding)
        from jax.sharding import NamedSharding, PartitionSpec
        if hasattr(slab_sharding, "mesh"):
            meta_sharding = NamedSharding(slab_sharding.mesh,
                                          PartitionSpec())

    def _meta(x):
        return jax.device_put(x, meta_sharding) if meta_sharding is not None \
            else x

    return TableState(
        slab=slab,
        keys=_meta(jnp.full((spec.capacity,), EMPTY_KEY, dtype=KEY_DTYPE)),
        version=_meta(jnp.zeros((spec.capacity,), dtype=jnp.int32)),
        ptr=_meta(jnp.zeros((), dtype=jnp.int32)),
        count=_meta(jnp.zeros((), dtype=jnp.int32)),
    )


def table_bytes(spec: TableSpec) -> int:
    """HBM footprint of the table (slab + metadata)."""
    return spec.slab_bytes + spec.capacity * (4 + 4) + 8


# ---------------------------------------------------------------------------
# Slot resolution
# ---------------------------------------------------------------------------

def _slot_for_put(spec: TableSpec, state: TableState, key) -> jax.Array:
    if spec.engine == "ring":
        return state.ptr
    # hash engine: reuse an existing slot holding this key (idempotent
    # overwrite), else key mod capacity.
    homed = jnp.asarray(key, KEY_DTYPE) % jnp.uint32(spec.capacity)
    match = (state.keys == jnp.asarray(key, KEY_DTYPE)) & (state.version > 0)
    existing = jnp.argmax(match).astype(jnp.int32)
    return jnp.where(jnp.any(match), existing, homed.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Core ops.  Each op has a raw ``*_impl`` (traceable inside larger fused
# computations — capture_scan, the trainer's fused epoch) and a jitted
# public wrapper (the per-verb dispatch path).  ``spec`` is always static.
# ---------------------------------------------------------------------------

def put_impl(spec: TableSpec, state: TableState, key, value) -> TableState:
    """Insert/overwrite one element.  O(1) slab dynamic-update-slice."""
    value = jnp.asarray(value, dtype=spec.dtype)
    if value.shape != spec.shape:
        raise ValueError(
            f"put into table {spec.name!r}: value shape {value.shape} != "
            f"element shape {spec.shape}"
        )
    slot = _slot_for_put(spec, state, key)
    stamp = state.count + 1
    new_ptr = (state.ptr + 1) % spec.capacity if spec.engine == "ring" else state.ptr
    return TableState(
        slab=jax.lax.dynamic_update_index_in_dim(state.slab, value, slot, 0),
        keys=state.keys.at[slot].set(jnp.asarray(key, KEY_DTYPE)),
        version=state.version.at[slot].set(stamp),
        ptr=new_ptr,
        count=stamp,
    )


put = partial(jax.jit, static_argnums=0, donate_argnums=1)(put_impl)


def put_many_impl(spec: TableSpec, state: TableState, keys, values) -> TableState:
    """Vectorized put of n elements (one producer step sending all ranks).

    ``ring``: consecutive slots from the write pointer.
    ``hash``: slot = key mod capacity (the batched path probes the homed
    slot only — unlike single ``put`` it does not relocate onto an existing
    slot holding the same key elsewhere).

    Slot collisions within one batch (hash keys equal mod capacity, or a
    ring batch longer than ``capacity``) resolve deterministically
    **last-writer-wins**, exactly matching a sequence of single ``put``s;
    every element still bumps ``count`` (a collision is an overwrite, not a
    dropped write).
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    values = jnp.asarray(values, dtype=spec.dtype)
    n = keys.shape[0]
    if values.shape != (n, *spec.shape):
        raise ValueError(
            f"put_many into {spec.name!r}: values {values.shape} != "
            f"({n}, *{spec.shape})"
        )
    if spec.engine == "ring":
        slots = (state.ptr + jnp.arange(n, dtype=jnp.int32)) % spec.capacity
        new_ptr = (state.ptr + n) % spec.capacity
    else:
        slots = (keys % jnp.uint32(spec.capacity)).astype(jnp.int32)
        new_ptr = state.ptr
    stamps = state.count + 1 + jnp.arange(n, dtype=jnp.int32)
    if n > 1:
        # Deterministic last-writer-wins: redirect all but the last write to
        # each slot out of bounds (mode="drop").
        i = jnp.arange(n, dtype=jnp.int32)
        if spec.engine == "ring":
            # Ring slots are consecutive mod capacity: element i collides
            # only with i + capacity, i + 2·capacity, …  → O(n).
            is_last = i + spec.capacity >= n
        else:
            # Hash batches are per-step rank sends (small n); the [n, n]
            # mask is over the *batch*, never over capacity.
            later_dup = (slots[None, :] == slots[:, None]) \
                & (i[None, :] > i[:, None])
            is_last = ~jnp.any(later_dup, axis=1)
        slots = jnp.where(is_last, slots, spec.capacity)
    return TableState(
        slab=state.slab.at[slots].set(values, mode="drop"),
        keys=state.keys.at[slots].set(keys, mode="drop"),
        version=state.version.at[slots].set(stamps, mode="drop"),
        ptr=new_ptr,
        count=state.count + n,
    )


put_many = partial(jax.jit, static_argnums=0, donate_argnums=1)(put_many_impl)


def put_masked_impl(spec: TableSpec, state: TableState, keys, values,
                    mask) -> TableState:
    """Vectorized put of the *masked subset* of a chunk, in chunk order.

    ``keys [n]`` / ``values [n, *shape]`` / ``mask [n]`` — exactly the
    elements with ``mask`` set are inserted, equivalent to replaying their
    single ``put`` verbs in order (ring slot assignment, version stamps,
    ``count`` bumps and **last-writer-wins** collisions all match the
    sequential reference; unmasked elements advance nothing).

    This is the db-mesh half of the clustered fused put: a
    :func:`capture_scan_collect` chunk — whose emit mask may be traced
    (bucketed tails, ``emit_every`` gating against a traced ``t0``) — is
    staged across the interconnect once and inserted in ONE dispatch.

    Replay safety (``core.faults``): last-writer-wins does NOT make this
    op idempotent — ``ptr``/``count`` advance on every apply, so applying
    the same chunk twice corrupts the ring bookkeeping.  Exactly-once
    delivery therefore lives a level up: the server deduplicates repeated
    chunk ids (``StoreServer.apply_chunk``) and its restart recovery
    *replays* the write-ahead log — the same chunks, in the same order,
    against the same snapshot base.  Because this op is a pure function of
    ``(state, chunk)``, that replay reproduces the pre-crash table
    byte-identically: determinism, not idempotence, carries the proof.
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    values = jnp.asarray(values, dtype=spec.dtype)
    mask = jnp.asarray(mask, bool)
    n = keys.shape[0]
    if values.shape != (n, *spec.shape):
        raise ValueError(
            f"put_masked into {spec.name!r}: values {values.shape} != "
            f"({n}, *{spec.shape})"
        )
    r = jnp.cumsum(mask.astype(jnp.int32)) - 1   # emission rank (masked)
    total = jnp.sum(mask.astype(jnp.int32))
    if spec.engine == "ring":
        slots = (state.ptr + r) % spec.capacity
        new_ptr = (state.ptr + total) % spec.capacity
        # Masked elements occupy consecutive ring positions: rank r is
        # overwritten only by rank r + capacity, r + 2·capacity, … → O(n).
        is_last = r + spec.capacity >= total
    else:
        slots = (keys % jnp.uint32(spec.capacity)).astype(jnp.int32)
        new_ptr = state.ptr
        i = jnp.arange(n, dtype=jnp.int32)
        # Last masked writer per slot via scatter-max — O(n + capacity),
        # not the [n, n] pairwise mask (n here is a whole fused chunk,
        # not one step's rank batch).  Unmasked elements dump into the
        # extra bucket at index `capacity`.
        dump = jnp.where(mask, slots, spec.capacity)
        last = jnp.full((spec.capacity + 1,), -1, jnp.int32).at[dump].max(i)
        is_last = last[dump] == i
    stamps = state.count + 1 + r
    slots = jnp.where(mask & is_last, slots, spec.capacity)
    return TableState(
        slab=state.slab.at[slots].set(values, mode="drop"),
        keys=state.keys.at[slots].set(keys, mode="drop"),
        version=state.version.at[slots].set(stamps, mode="drop"),
        ptr=new_ptr,
        count=state.count + total,
    )


put_masked = partial(jax.jit, static_argnums=0, donate_argnums=1)(
    put_masked_impl)


def put_stream_impl(spec: TableSpec, state: TableState, keys, values
                    ) -> TableState:
    """Fold a whole trajectory of sends into one dispatch.

    ``keys [T]`` / ``values [T, *shape]`` — T single-element steps — or
    ``keys [T, R]`` / ``values [T, R, *shape]`` — T steps of R ranks each.
    Equivalent to the corresponding sequence of ``put``/``put_many`` calls
    (time-major order; last-writer-wins on slot collisions), in a single
    device dispatch instead of T.
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    values = jnp.asarray(values, dtype=spec.dtype)
    if keys.ndim == 2:
        t, r = keys.shape
        keys = keys.reshape(t * r)
        values = values.reshape(t * r, *values.shape[2:])
    return put_many_impl(spec, state, keys, values)


put_stream = partial(jax.jit, static_argnums=0, donate_argnums=1)(
    put_stream_impl)


@partial(jax.jit, static_argnums=0)
def get(spec: TableSpec, state: TableState, key):
    """Fetch by key.  Returns ``(value, found)``; value is zeros if absent.

    ``EMPTY_KEY`` is reserved (never found) — same contract as the
    batched probe path.
    """
    key = jnp.asarray(key, KEY_DTYPE)
    match = (state.keys == key) & (state.version > 0)
    found = jnp.any(match) & (key != EMPTY_KEY)
    idx = jnp.argmax(match).astype(jnp.int32)
    value = jax.lax.dynamic_index_in_dim(state.slab, idx, 0, keepdims=False)
    value = jnp.where(found, value, jnp.zeros_like(value))
    return value, found


def get_many_impl(spec: TableSpec, state: TableState, keys,
                  mode: str | None = None):
    """Vectorized get.  Returns ``(values [n,*shape], founds [n])``.

    Routed through the fused probe+gather kernels (``repro.kernels.store``):
    a blocked pass over slot metadata resolves each key to its first valid
    slot, then a row gather fetches the slab — no ``[n, capacity]`` match
    matrix is ever materialized.  Duplicate keys resolve to the lowest slot
    (the historical behavior).
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    idx, founds = _kops.probe_slots(state.keys, state.version, keys, mode)
    safe = jnp.minimum(idx, spec.capacity - 1)
    values = _kops.gather_rows(state.slab, safe, mode)
    values = jnp.where(
        founds.reshape((-1,) + (1,) * len(spec.shape)), values, 0
    ).astype(spec.dtype)
    return values, founds


get_many = partial(jax.jit, static_argnums=(0, 3))(get_many_impl)


def serve_batch_impl(req_spec: TableSpec, res_spec: TableSpec, apply_fn,
                     req_state: TableState, res_state: TableState,
                     params, keys, mask):
    """Fused serving dispatch: gather requests → model → scatter results.

    One traced program covers a whole drained serving batch — the batched
    probe+gather over the request table, a ``vmap`` of the single-element
    ``apply_fn(params, x)`` registry function, and the masked insert into
    the results table — so each batch costs O(1) host dispatches regardless
    of how many ring slots are active.

    ``mask`` is the host-known active-slot mask; insertion uses it directly
    (not ``found & mask``) so a WAL replay of ``(keys, ys, mask)`` via the
    ``put_masked`` path reproduces the insert byte-identically.  Returns
    ``(new_res_state, found & mask, ys)`` — the second element flags slots
    whose request key was actually present.
    """
    keys = jnp.asarray(keys, KEY_DTYPE)
    mask = jnp.asarray(mask, bool)
    xs, found = get_many_impl(req_spec, req_state, keys)
    ys = jnp.asarray(
        jax.vmap(lambda x: apply_fn(params, x))(xs), res_spec.dtype)
    new_res = put_masked_impl(res_spec, res_state, keys, ys, mask)
    return new_res, found & mask, ys


serve_batch = partial(jax.jit, static_argnums=(0, 1, 2),
                      donate_argnums=4)(serve_batch_impl)


def sample_impl(spec: TableSpec, state: TableState, rng, n: int,
                mode: str | None = None):
    """Uniformly sample ``n`` valid elements (with replacement).

    This is the in-situ data loader: the paper's ML ranks "retrieve multiple
    tensors from the database at random" before each epoch.
    Returns ``(values [n,*shape], keys [n], ok)`` where ``ok`` is False if
    the table is empty (values are zeros then).

    A single pass over slot metadata (cumulative valid count + blocked
    rank-to-slot search in ``repro.kernels.store``) replaces the former
    ``-inf``-logits ``categorical``, which materialized an
    ``[n, capacity]`` Gumbel matrix.
    """
    nvalid = jnp.sum((state.version > 0).astype(jnp.int32))
    ok = nvalid > 0
    ranks = jax.random.randint(rng, (n,), 0, jnp.maximum(nvalid, 1))
    slots = _kops.sample_slots(state.version, ranks, mode)
    slots = jnp.minimum(slots, spec.capacity - 1)
    values = _kops.gather_rows(state.slab, slots, mode)
    values = jnp.where(ok, values,
                       jnp.zeros((n, *spec.shape), spec.dtype))
    return values.astype(spec.dtype), state.keys[slots], ok


sample = partial(jax.jit, static_argnums=(0, 3, 4))(sample_impl)


def sample_sharded_impl(spec: TableSpec, state: TableState, rng, n: int,
                        axis: str, mode: str | None = None):
    """Slab-sharded form of :func:`sample_impl`, for use *inside* a
    ``shard_map`` whose in-spec partitions the slab's slot axis over mesh
    axis ``axis`` (``parallel.sharding.slab_sharding`` placement).

    ``state.slab`` here is the rank's LOCAL shard ``[capacity/D, *shape]``
    while the per-slot metadata (``keys``/``version``) and cursors stay
    replicated, so slot selection is identical replicated compute on every
    rank.  Each rank then gathers only the slots it owns
    (``kernels.store.gather_rows_sharded`` — zeros elsewhere) and one
    ``lax.psum`` over ``axis`` reassembles the batch: the cross-rank
    mini-batch assembly becomes an explicit, HLO-countable collective
    instead of an implicit replicated slab read, and per-device slab
    memory drops from O(capacity) to O(capacity/D).  Every slot has
    exactly one owner, so the psum adds zeros to the owned row —
    bit-identical to the replicated gather.

    Returns ``(values [n,*shape], keys [n], ok)`` like ``sample_impl``.
    """
    local_cap = state.slab.shape[0]
    nvalid = jnp.sum((state.version > 0).astype(jnp.int32))
    ok = nvalid > 0
    ranks = jax.random.randint(rng, (n,), 0, jnp.maximum(nvalid, 1))
    slots = _kops.sample_slots(state.version, ranks, mode)
    slots = jnp.minimum(slots, spec.capacity - 1)
    offset = jax.lax.axis_index(axis) * local_cap
    local = _kops.gather_rows_sharded(state.slab, slots, offset, mode)
    values = jax.lax.psum(local, axis)
    values = jnp.where(ok, values,
                       jnp.zeros((n, *spec.shape), spec.dtype))
    return values.astype(spec.dtype), state.keys[slots], ok


@partial(jax.jit, static_argnums=(0, 2))
def latest(spec: TableSpec, state: TableState, n: int):
    """The ``n`` most recently written elements (newest first).

    Returns ``(values [n,*shape], keys [n], valid [n])``.
    """
    _, slots = jax.lax.top_k(state.version, n)
    vals = state.slab[slots]
    return vals, state.keys[slots], state.version[slots] > 0


@partial(jax.jit, static_argnums=0)
def poll(spec: TableSpec, state: TableState, key) -> jax.Array:
    """Does ``key`` exist?  (SmartRedis ``poll_tensor`` single check.)
    ``EMPTY_KEY`` is reserved — never reported present."""
    key = jnp.asarray(key, KEY_DTYPE)
    return jnp.any((state.keys == key) & (state.version > 0)) \
        & (key != EMPTY_KEY)


@partial(jax.jit, static_argnums=0, donate_argnums=1)
def delete(spec: TableSpec, state: TableState, key) -> TableState:
    """Tombstone every slot holding ``key`` (slab data left in place)."""
    match = (state.keys == jnp.asarray(key, KEY_DTYPE))
    return state._replace(
        version=jnp.where(match, 0, state.version),
        keys=jnp.where(match, EMPTY_KEY, state.keys),
    )


@partial(jax.jit, static_argnums=0)
def valid_count(spec: TableSpec, state: TableState) -> jax.Array:
    return jnp.sum(state.version > 0)


# ---------------------------------------------------------------------------
# Fused producer/consumer steps (the in-situ capture fast path)
# ---------------------------------------------------------------------------

#: The data plane's bucket floor: the smallest power-of-two bucket a fused
#: chunk pads to.  THE single source — the plan's ``default_chunk`` /
#: autotuner derive their floors from this constant instead of re-deriving
#: an ``8`` of their own, so predicted compile-cache hits cannot drift
#: from actual bucketing.
MIN_BUCKET = 8


def bucket_length(length: int, min_bucket: int = MIN_BUCKET) -> int:
    """Round a chunk length up to the next power-of-two bucket.

    Chunked ``capture_scan`` drivers compile one executable per distinct
    static ``length``; a run whose tail chunk differs from the body chunk
    therefore compiles twice (and sweeps over ``sim_steps`` compile once per
    distinct tail).  Bucketing pads the tail to the nearest power of two
    ``>= min_bucket`` and masks the padded steps with a traced ``valid``
    count, so each (table, bucket) pair compiles exactly once.
    """
    if length < 1:
        raise ValueError("length must be >= 1")
    n = max(length, min_bucket)
    return 1 << (n - 1).bit_length()


def _constrain_elem(value, elem_sharding, lead: int = 0):
    """Pin an emitted element (with ``lead`` stacked leading axes) to the
    producer's element sharding, so a sharded solver's put stays a
    shard-local slab update instead of funneling through one device.
    ``elem_sharding`` is a ``NamedSharding`` over the element dims only;
    ``None`` is the un-sharded fast path (no constraint inserted)."""
    if elem_sharding is None:
        return value
    from jax.sharding import NamedSharding, PartitionSpec
    ns = NamedSharding(elem_sharding.mesh,
                       PartitionSpec(*([None] * lead), *elem_sharding.spec))
    return jax.lax.with_sharding_constraint(value, ns)


def capture_scan_impl(spec: TableSpec, state: TableState,
                      step_fn: Callable, carry, length: int,
                      emit_every: int = 1, t0=0, valid=None,
                      elem_sharding=None):
    """Fold ``length`` producer steps and their puts into ONE dispatch.

    ``step_fn(carry, t) -> (carry, key, value)`` is the producer's
    jit-traceable step (solver advance + snapshot).  Steps where
    ``t % emit_every == 0`` put their value into the table; ``t`` runs over
    ``t0 .. t0+length-1`` (``t0`` may be a traced array, so chunked drivers
    reuse one compiled executable across chunks).

    ``valid`` (traced, defaults to ``length``) gates chunk-length bucketing:
    scan iterations ``i >= valid`` are complete no-ops — neither the carry
    nor the table advances — so a tail of any length can run under the
    executable compiled for its power-of-two bucket (``bucket_length``).

    Emitted puts land in ring order exactly as the equivalent sequence of
    single ``put`` verbs would; if more than ``capacity`` steps emit within
    one call, slot collisions resolve **last-writer-wins** (the overwrite
    still bumps ``count``), identical to the sequential reference.

    The multi-rank form is :func:`capture_scan_multi`.

    Returns ``(state, carry)``.  The number of puts is static given the
    *valid* length — use ``capture_emit_count`` to bump the server's cached
    watermark on commit.
    """
    def step(sc, t):
        st, c = sc
        c, key, value = step_fn(c, t)
        value = _constrain_elem(value, elem_sharding)
        st = jax.lax.cond(
            t % emit_every == 0,
            lambda s: put_impl(spec, s, key, value),
            lambda s: s,
            st,
        )
        return st, c

    ts = jnp.asarray(t0, jnp.int32) + jnp.arange(length, dtype=jnp.int32)
    if valid is None:
        def body(sc, t):
            return step(sc, t), None
        xs = ts
    else:
        valid = jnp.asarray(valid, jnp.int32)

        def body(sc, it):
            i, t = it
            return jax.lax.cond(i < valid, step, lambda sc, _t: sc, sc, t), \
                None
        xs = (jnp.arange(length, dtype=jnp.int32), ts)
    (state, carry), _ = jax.lax.scan(body, (state, carry), xs)
    return state, carry


capture_scan = partial(jax.jit, static_argnums=(0, 2, 4, 5),
                       static_argnames=("elem_sharding",),
                       donate_argnums=1)(capture_scan_impl)


def capture_emit_count(length: int, emit_every: int = 1, t0: int = 0) -> int:
    """Host-side count of puts a ``capture_scan`` call will perform."""
    return sum(1 for t in range(t0, t0 + length) if t % emit_every == 0)


def capture_scan_multi_impl(spec: TableSpec, state: TableState,
                            step_fn: Callable, carry, length: int,
                            n_ranks: int, emit_every: int = 1, t0=0,
                            valid=None, elem_sharding=None):
    """Multi-producer :func:`capture_scan`: ``n_ranks`` producers advance in
    lockstep for ``length`` steps inside ONE dispatch.

    ``step_fn(carry_r, rank, t) -> (carry_r, key, value)`` is a *single
    rank's* jit-traceable step; it is ``vmap``-ped over the leading ``[R]``
    axis of ``carry`` (every leaf of the carry pytree stacks the per-rank
    solver states).

    ``t0`` may be a scalar or a per-rank ``[R]`` array: each rank's clock
    runs over ``t0_r .. t0_r+length-1``, so restarted or staggered ranks
    interleave their keys into the same ring.  Emission is gated on rank
    0's clock (``(t0_0 + i) % emit_every == 0``): the paper's simulation
    ranks send each sampled step together, so staggered ``t0`` offsets
    shift the *keys*, never the cadence.

    Each emitting step writes all ``n_ranks`` snapshots with one
    ``put_many`` — rank-major within the step, byte-identical to ``R``
    sequential per-verb ``put`` calls (including ring wrap-around and
    last-writer-wins slot collisions when ``R`` exceeds ``capacity``).

    ``valid`` gates chunk-length bucketing exactly as in
    :func:`capture_scan_impl`: iterations ``i >= valid`` advance nothing.

    Returns ``(state, carry)``.  The put count is static given the valid
    length — commit with ``puts=capture_emit_count_multi(...)`` to keep the
    server's cached watermark exact.
    """
    ranks = jnp.arange(n_ranks, dtype=jnp.int32)
    t0_arr = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (n_ranks,))

    def step(sc, i):
        st, c = sc
        ts = t0_arr + i
        c, keys, values = jax.vmap(step_fn, in_axes=(0, 0, 0))(c, ranks, ts)
        values = _constrain_elem(values, elem_sharding, lead=1)
        st = jax.lax.cond(
            ts[0] % emit_every == 0,
            lambda s: put_many_impl(spec, s, keys, values),
            lambda s: s,
            st,
        )
        return st, c

    steps = jnp.arange(length, dtype=jnp.int32)
    if valid is None:
        def body(sc, i):
            return step(sc, i), None
    else:
        valid = jnp.asarray(valid, jnp.int32)

        def body(sc, i):
            return jax.lax.cond(i < valid, step, lambda sc, _i: sc, sc, i), \
                None
    (state, carry), _ = jax.lax.scan(body, (state, carry), steps)
    return state, carry


capture_scan_multi = partial(jax.jit, static_argnums=(0, 2, 4, 5, 6),
                             static_argnames=("elem_sharding",),
                             donate_argnums=1)(capture_scan_multi_impl)


def capture_emit_count_multi(n_ranks: int, length: int, emit_every: int = 1,
                             t0: int = 0) -> int:
    """Host-side count of puts a ``capture_scan_multi`` call will perform.

    ``t0`` is rank 0's start offset (the emission gate's clock)."""
    return n_ranks * capture_emit_count(length, emit_every, t0)


def capture_rows(length: int, emit_every: int = 1) -> int:
    """Static bound on the emissions of one collect chunk: the most
    multiples of ``emit_every`` any ``length``-step window can contain
    (the ``t0`` phase decides floor vs ceil; the buffer takes the ceil)."""
    return -(-length // emit_every)


def capture_scan_collect_impl(spec: TableSpec, step_fn: Callable, carry,
                              length: int, emit_every: int = 1, t0=0,
                              valid=None, elem_sharding=None):
    """Producer half of the *clustered* fused put: run ``length`` steps in
    ONE dispatch and **collect** the would-be puts instead of applying
    them.

    Same step/emission/bucketing semantics as :func:`capture_scan_impl`,
    but no table state is touched — emitting steps accumulate their
    ``(key, value)`` into a compact ``rows = capture_rows(length,
    emit_every)`` buffer rides in the scan carry, so the staged payload
    scales with the *emissions*, not the raw step count (a sparse
    ``emit_every`` never ships zero rows across the interconnect).  The
    caller then moves the chunk across in ONE staged transfer
    (``Deployment.stage_chunk``) and inserts it with ONE
    :func:`put_masked` dispatch on the store mesh — so a clustered fused
    producer costs one cross-mesh hop per chunk, not one per element.

    Returns ``(carry, keys [rows], values [rows, *shape], mask [rows])``
    — ``mask`` is the filled prefix; replaying the masked elements in
    order is byte-identical to the equivalent :func:`capture_scan`.
    """
    rows = capture_rows(length, emit_every)

    def live(st, i, t):
        c, keys_buf, vals_buf, cursor = st
        c, key, value = step_fn(c, t)
        value = _constrain_elem(jnp.asarray(value, spec.dtype),
                                elem_sharding)
        if value.shape != spec.shape:
            raise ValueError(
                f"capture into table {spec.name!r}: value shape "
                f"{value.shape} != element shape {spec.shape}")
        emit = t % emit_every == 0
        idx = jnp.where(emit, cursor, rows)      # non-emitting: dropped
        keys_buf = keys_buf.at[idx].set(jnp.asarray(key, KEY_DTYPE),
                                        mode="drop")
        vals_buf = vals_buf.at[idx].set(value, mode="drop")
        return c, keys_buf, vals_buf, cursor + emit.astype(jnp.int32)

    def dead(st, i, t):
        return st

    ts = jnp.asarray(t0, jnp.int32) + jnp.arange(length, dtype=jnp.int32)
    its = (jnp.arange(length, dtype=jnp.int32), ts)
    if valid is None:
        def body(st, it):
            return live(st, *it), None
    else:
        valid = jnp.asarray(valid, jnp.int32)

        def body(st, it):
            i, t = it
            return jax.lax.cond(i < valid, live, dead, st, i, t), None
    st0 = (carry, jnp.zeros((rows,), KEY_DTYPE),
           _constrain_elem(jnp.zeros((rows, *spec.shape), spec.dtype),
                           elem_sharding, lead=1),
           jnp.zeros((), jnp.int32))
    (carry, keys, values, cursor), _ = jax.lax.scan(body, st0, its)
    return carry, keys, values, jnp.arange(rows, dtype=jnp.int32) < cursor


capture_scan_collect = partial(jax.jit, static_argnums=(0, 1, 3, 4),
                               static_argnames=("elem_sharding",))(
    capture_scan_collect_impl)


def capture_scan_collect_multi_impl(spec: TableSpec, step_fn: Callable,
                                    carry, length: int, n_ranks: int,
                                    emit_every: int = 1, t0=0, valid=None,
                                    elem_sharding=None):
    """Multi-producer :func:`capture_scan_collect`: ``n_ranks`` producers
    advance in lockstep, collecting instead of putting (the clustered
    form of :func:`capture_scan_multi_impl` — same vmapped step, per-rank
    ``t0`` clocks, rank-0-gated emission, same compact
    ``rows = capture_rows(length, emit_every)`` buffering).

    Returns ``(carry, keys [rows·R], values [rows·R, *shape],
    mask [rows·R])`` flattened **rank-major within each emitting step**,
    so the masked replay is byte-identical to the in-scan ``put_many``
    path.
    """
    rows = capture_rows(length, emit_every)
    ranks = jnp.arange(n_ranks, dtype=jnp.int32)
    t0_arr = jnp.broadcast_to(jnp.asarray(t0, jnp.int32), (n_ranks,))

    def live(st, i):
        c, keys_buf, vals_buf, cursor = st
        ts = t0_arr + i
        c, keys, values = jax.vmap(step_fn, in_axes=(0, 0, 0))(c, ranks, ts)
        values = _constrain_elem(jnp.asarray(values, spec.dtype),
                                 elem_sharding, lead=1)
        if values.shape != (n_ranks, *spec.shape):
            raise ValueError(
                f"capture into table {spec.name!r}: rank values "
                f"{values.shape} != ({n_ranks}, *{spec.shape})")
        emit = ts[0] % emit_every == 0
        idx = jnp.where(emit, cursor, rows)      # non-emitting: dropped
        keys_buf = keys_buf.at[idx].set(jnp.asarray(keys, KEY_DTYPE),
                                        mode="drop")
        vals_buf = vals_buf.at[idx].set(values, mode="drop")
        return c, keys_buf, vals_buf, cursor + emit.astype(jnp.int32)

    def dead(st, i):
        return st

    steps = jnp.arange(length, dtype=jnp.int32)
    if valid is None:
        def body(st, i):
            return live(st, i), None
    else:
        valid = jnp.asarray(valid, jnp.int32)

        def body(st, i):
            return jax.lax.cond(i < valid, live, dead, st, i), None
    st0 = (carry, jnp.zeros((rows, n_ranks), KEY_DTYPE),
           _constrain_elem(jnp.zeros((rows, n_ranks, *spec.shape),
                                     spec.dtype), elem_sharding, lead=2),
           jnp.zeros((), jnp.int32))
    (carry, keys, values, cursor), _ = jax.lax.scan(body, st0, steps)
    mask = jnp.arange(rows, dtype=jnp.int32) < cursor
    return (carry, keys.reshape(rows * n_ranks),
            values.reshape(rows * n_ranks, *spec.shape),
            jnp.repeat(mask, n_ranks))


capture_scan_collect_multi = partial(jax.jit, static_argnums=(0, 1, 3, 4, 5),
                                     static_argnames=("elem_sharding",))(
    capture_scan_collect_multi_impl)


def make_clustered_gather(spec: TableSpec, n: int, db_mesh=None,
                          axis: str | None = None, shards: int = 1,
                          mode: str | None = None):
    """The db-mesh half of the clustered read path: ONE dispatch sampling
    ``n`` elements from the table on its own mesh.

    With ``shards > 1`` the slab is slot-partitioned over db-mesh axis
    ``axis`` and the gather runs shard-local with one explicit ``psum``
    (:func:`sample_sharded_impl` inside a ``shard_map`` over the db mesh
    — the same structure as the co-located slab-sharded tier, except the
    psum's reassembled batch then leaves the mesh: the cross-mesh staged
    transfer the caller performs and counts).  Otherwise the plain
    :func:`sample_impl` against the (possibly element-sharded) slab.

    Returns a jitted ``fn(state, rng) -> (values [n,*shape], ok)``.
    """
    if shards > 1:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        specs = TableState(slab=P(axis), keys=P(), version=P(),
                           ptr=P(), count=P())

        def sharded_body(state, rng):
            vals, _, ok = sample_sharded_impl(spec, state, rng, n, axis,
                                              mode)
            return vals, ok

        return jax.jit(shard_map(sharded_body, mesh=db_mesh,
                                 in_specs=(specs, P()),
                                 out_specs=(P(), P()),
                                 check_rep=False))

    def body(state, rng):
        vals, _, ok = sample_impl(spec, state, rng, n, mode)
        return vals, ok

    return jax.jit(body)


def sample_and_step_impl(spec: TableSpec, state: TableState, rng, n: int,
                         step_fn: Callable, carry, mode: str | None = None):
    """Fused consumer step: gather ``n`` random elements AND run the
    training microstep ``step_fn(carry, values) -> (carry, aux)`` in one
    dispatch.  Returns ``(carry, aux, ok)``.

    The table state is only read — call under the table's capture/lock so
    the dispatch is ordered against donating producer puts.
    """
    values, _, ok = sample_impl(spec, state, rng, n, mode)
    carry, aux = step_fn(carry, values)
    return carry, aux, ok


sample_and_step = partial(jax.jit, static_argnums=(0, 3, 4, 6))(
    sample_and_step_impl)


# Non-jit convenience: functional update preserving NamedTuple type.
def _replace_state(state: TableState, **kw) -> TableState:
    return state._replace(**kw)
