"""Component timers for the in-situ framework.

The paper reports, for every framework component (client initialization,
metadata transfer, training-data send, training-data retrieve, model
evaluation), the mean and standard deviation of the time spent across ranks
(Tables 1-2).  ``Timers`` reproduces that accounting: named accumulators that
record per-call wall time, with helpers to emit the paper-style summary
table.

All timing helpers call ``jax.block_until_ready`` on the payload (when given)
so async-dispatched device work is charged to the component that issued it.
"""

from __future__ import annotations

import math
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any

import jax

__all__ = ["Timers", "TimerStats", "poll_backoff"]


def poll_backoff(timeout: float, interval: float, max_interval: float):
    """Drive a deadline-bounded polling loop: yields once per probe,
    sleeping with exponential backoff (``interval`` doubling up to
    ``max_interval``) between probes, each sleep clamped to the time
    remaining so the loop never overshoots ``timeout`` by a backoff
    step.  Shared by every store poller (``Client.poll_tensor``,
    ``StoreServer.wait_watermark``) so the clamp rule stays in lockstep.

        for _ in poll_backoff(timeout, interval, max_interval):
            if condition():
                return True
        return condition()   # one last look at the deadline
    """
    deadline = time.perf_counter() + timeout
    while True:
        yield
        remaining = deadline - time.perf_counter()
        if remaining <= 0:
            return
        time.sleep(min(interval, remaining))
        interval = min(interval * 2.0, max_interval)


@dataclass
class TimerStats:
    """Online mean/variance accumulator (Welford)."""

    count: int = 0
    total: float = 0.0
    _mean: float = 0.0
    _m2: float = 0.0
    min: float = math.inf
    max: float = 0.0

    def add(self, dt: float) -> None:
        self.count += 1
        self.total += dt
        delta = dt - self._mean
        self._mean += delta / self.count
        self._m2 += delta * (dt - self._mean)
        self.min = min(self.min, dt)
        self.max = max(self.max, dt)

    @property
    def mean(self) -> float:
        return self._mean

    @property
    def std(self) -> float:
        if self.count < 2:
            return 0.0
        return math.sqrt(self._m2 / (self.count - 1))


class Timers:
    """Named wall-clock accumulators, paper-Tables-1/2 style."""

    def __init__(self) -> None:
        self._stats: dict[str, TimerStats] = {}

    def stats(self, name: str) -> TimerStats:
        if name not in self._stats:
            self._stats[name] = TimerStats()
        return self._stats[name]

    @contextmanager
    def time(self, name: str, payload: Any = None):
        """Time a block; if ``payload`` is set, block on it before stopping.

        The payload can also be supplied late by assigning to ``box[0]``
        of the yielded one-element list (useful when the timed block
        produces the arrays to block on).
        """
        box = [payload]
        t0 = time.perf_counter()
        try:
            yield box
        finally:
            if box[0] is not None:
                jax.block_until_ready(box[0])
            self.stats(name).add(time.perf_counter() - t0)

    def record(self, name: str, dt: float) -> None:
        self.stats(name).add(dt)

    def total(self, name: str) -> float:
        return self._stats[name].total if name in self._stats else 0.0

    def mean(self, name: str) -> float:
        return self._stats[name].mean if name in self._stats else 0.0

    def merge(self, other: "Timers") -> None:
        """Merge per-rank timers (used to average across worker threads)."""
        for name, st in other._stats.items():
            mine = self.stats(name)
            # Merge by replaying summary statistics (exact for mean/total,
            # approximate pooled variance).
            if st.count == 0:
                continue
            n1, n2 = mine.count, st.count
            if n1 == 0:
                self._stats[name] = TimerStats(
                    count=st.count, total=st.total, _mean=st._mean, _m2=st._m2,
                    min=st.min, max=st.max,
                )
                continue
            delta = st._mean - mine._mean
            tot = n1 + n2
            mine._m2 = mine._m2 + st._m2 + delta * delta * n1 * n2 / tot
            mine._mean = (n1 * mine._mean + n2 * st._mean) / tot
            mine.count = tot
            mine.total += st.total
            mine.min = min(mine.min, st.min)
            mine.max = max(mine.max, st.max)

    def summary(self) -> dict[str, dict[str, float]]:
        return {
            name: {
                "count": st.count,
                "total_s": st.total,
                "mean_s": st.mean,
                "std_s": st.std,
                "min_s": st.min if st.count else 0.0,
                "max_s": st.max,
            }
            for name, st in sorted(self._stats.items())
        }

    def table(self, title: str = "") -> str:
        """Render the paper-style component table."""
        lines = []
        if title:
            lines.append(title)
        lines.append(f"{'Component':<28} {'Total [s]':>12} {'Mean [s]':>12} "
                     f"{'Std [s]':>12} {'Count':>8}")
        for name, st in sorted(self._stats.items()):
            lines.append(
                f"{name:<28} {st.total:>12.6f} {st.mean:>12.6f} "
                f"{st.std:>12.6f} {st.count:>8d}"
            )
        return "\n".join(lines)
