"""Deterministic fault injection, retry, and retry-cost prediction.

Real deployments of the paper's workflow (SmartSim's ocean-climate
ensembles run for *days* against one store) see ranks die, interconnect
transfers drop, and the database restart.  This module makes every one of
those failures a *declared, seeded event* so the whole recovery path is
testable and its cost is predictable:

* a typed failure taxonomy (``StoreError`` and friends) replaces the
  silent-``False`` timeouts and bare ``RuntimeError``s of the early store;
* :class:`RetryPolicy` / :func:`call_with_retry` give every client verb
  bounded exponential backoff with deterministic jitter, deadline-clamped
  exactly like ``telemetry.poll_backoff``;
* :class:`FaultPlan` declares *which* faults fire *where* — dropped or
  duplicated chunk transfers at the staging boundary, transient
  ``StoreUnavailable`` windows on client verbs, producer/consumer crashes
  at a declared step/epoch, store snapshots and restarts at a declared
  commit — all keyed by deterministic attempt indices, never wall clock;
* :class:`FaultInjector` is the single runtime arbiter: the ``Client``
  consults it at every verb attempt, the ``StoreServer`` at every chunk
  staging attempt and every table commit;
* :func:`simulate_overhead` *re-runs the same injector* against a
  session's static component schedule, so the plan-time prediction of
  retry dispatches, re-staged transfers, replay ops, restarts and
  recoveries agrees with the measured ``StoreServer.stats()`` exactly —
  by construction, not by parallel bookkeeping.

Exactly-once, in one paragraph: ``store.put_masked`` is last-writer-wins
but NOT idempotent (``ptr``/``count`` advance on every apply), so a
duplicated delivery must be deduplicated, not re-applied.  The server
keys every fused chunk by a stable ``(rank, seq)`` chunk id: a dropped
transfer is retried *under the same id*, a duplicated transfer hits the
acknowledged-id set and becomes a no-op, and the table converges to the
byte-identical state of the fault-free run.  Replay after a store restart
is safe for the dual reason: the write-ahead log re-applies the *same*
chunks in the *same* order from the snapshot state, and the store ops are
pure functions of (state, chunk) — determinism, not idempotence, carries
the proof.
"""

from __future__ import annotations

import random as _random
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Iterator

__all__ = [
    "StoreError", "StoreTimeout", "WatermarkTimeout", "StoreUnavailable",
    "TransferDropped", "InjectedCrash",
    "RetryPolicy", "call_with_retry",
    "FaultEvent", "FaultPlan", "FaultInjector",
    "Overhead", "simulate_overhead",
]


# ---------------------------------------------------------------------------
# Typed failure taxonomy
# ---------------------------------------------------------------------------

class StoreError(RuntimeError):
    """Base class of every store-side failure."""


class StoreTimeout(StoreError):
    """A store wait expired.  Carries what was awaited and the deadline
    context so callers (and ``ComponentResult.error``) see *which* wait on
    *what* object timed out, not a bare ``False``."""

    def __init__(self, what: str, name: str, timeout: float,
                 detail: str = ""):
        self.what, self.name, self.timeout = what, name, timeout
        msg = f"{what} {name!r} timed out after {timeout:.3g}s"
        super().__init__(msg + (f" ({detail})" if detail else ""))


class WatermarkTimeout(StoreTimeout):
    """``wait_watermark`` expired: the table never reached the minimum."""

    def __init__(self, table: str, minimum: int, watermark: int,
                 timeout: float):
        self.table, self.minimum, self.watermark = table, minimum, watermark
        super().__init__("watermark of table", table, timeout,
                         f"wanted >= {minimum}, have {watermark}")


class StoreUnavailable(StoreError):
    """Transient store unavailability — the retryable class: client verbs
    wrapped in :func:`call_with_retry` absorb it up to the policy bound."""


class TransferDropped(StoreUnavailable):
    """A staged chunk transfer was lost in flight (the clustered
    deployment's dropped-TCP-message analogue).  Retryable: the client
    re-stages the chunk under the same chunk id."""


class InjectedCrash(StoreError):
    """A declared component crash.  NOT retryable at the verb level — it
    propagates to the component's restart loop (producer: resume from the
    table watermark; trainer: resume from ``MemoryCheckpoint``)."""

    def __init__(self, component: str, at: int):
        self.component, self.at = component, at
        super().__init__(f"injected crash of {component!r} at index {at}")


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    The sleep schedule mirrors ``telemetry.poll_backoff``: ``interval``
    doubling up to ``max_interval``, every sleep clamped to the time
    remaining before ``timeout`` so a retry loop never overshoots its
    deadline by a backoff step.  ``jitter`` scales each sleep by a factor
    drawn from ``random.Random(seed)`` — seeded, so two runs of the same
    plan sleep identically (fault determinism is the whole point)."""

    max_attempts: int = 6
    interval: float = 0.001
    max_interval: float = 0.05
    timeout: float = 30.0
    jitter: float = 0.25
    seed: int = 0

    def sleeps(self) -> Iterator[float]:
        """Yield the bounded, jittered, deadline-clamped sleep durations
        between attempts (``max_attempts - 1`` of them at most)."""
        rng = _random.Random(self.seed)
        deadline = time.perf_counter() + self.timeout
        interval = self.interval
        for _ in range(max(0, self.max_attempts - 1)):
            remaining = deadline - time.perf_counter()
            if remaining <= 0:
                return
            scale = 1.0 + self.jitter * rng.random()
            yield min(interval * scale, remaining)
            interval = min(interval * 2.0, self.max_interval)


def call_with_retry(fn, policy: RetryPolicy, on_retry=None):
    """Call ``fn()``; on :class:`StoreUnavailable` retry per ``policy``.

    ``on_retry`` (if given) runs once per retry — the hook the client and
    server use to keep their retry counters exact.  The last failure is
    re-raised when attempts or the deadline run out.  Non-transient
    exceptions (anything not ``StoreUnavailable``) propagate immediately.
    """
    sleeps = policy.sleeps()
    while True:
        try:
            return fn()
        except StoreUnavailable:
            sleep_s = next(sleeps, None)
            if sleep_s is None:
                raise
            if on_retry is not None:
                on_retry()
            time.sleep(sleep_s)


# ---------------------------------------------------------------------------
# Fault plans
# ---------------------------------------------------------------------------

#: event kinds and the index space their ``at`` lives in
FAULT_KINDS = {
    "drop_chunk":  "table staging-attempt index",
    "dup_chunk":   "table staging-attempt index",
    "unavailable": "per-verb attempt index (``count`` consecutive raises)",
    "snapshot":    "table commit index (1-based, fires after that commit)",
    "restart":     "table commit index (1-based, fires after that commit)",
    "crash":       "component step/chunk/epoch index",
}


@dataclass(frozen=True)
class FaultEvent:
    """One declared fault.  ``at`` indexes deterministic progress counters
    (attempt/commit/step indices — see :data:`FAULT_KINDS`), never wall
    time, so a plan replays identically on any machine."""

    kind: str
    table: str | None = None      # chunk/commit kinds; optional verb filter
    verb: str | None = None       # "unavailable": which client verb
    at: int = 0
    count: int = 1                # "unavailable": consecutive failures
    component: str | None = None  # "crash": which component

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(have {sorted(FAULT_KINDS)})")
        if self.kind == "unavailable" and self.verb is None:
            raise ValueError("'unavailable' needs a verb")
        if self.kind == "crash" and self.component is None:
            raise ValueError("'crash' needs a component name")
        if self.kind in ("drop_chunk", "dup_chunk", "snapshot", "restart") \
                and self.table is None:
            raise ValueError(f"{self.kind!r} needs a table")


@dataclass(frozen=True)
class FaultPlan:
    """A seeded, declarative set of faults plus the retry policy that
    absorbs the transient ones.  Declared on a ``Deployment`` or an
    ``InSituSession``; an *empty* plan (no events) still arms the
    exactly-once machinery (chunk ids, write-ahead log, checkpoints), so
    the chaos tests' fault-free baseline takes the identical code path."""

    events: tuple[FaultEvent, ...] = ()
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    seed: int = 0

    @classmethod
    def random(cls, seed: int, *, tables=("field",), verbs=("put", "sample",
                                                            "capture"),
               components=("producer", "trainer"), n_events: int = 3,
               max_index: int = 8, retry: RetryPolicy | None = None
               ) -> "FaultPlan":
        """A seeded random plan over the given index bounds — the chaos
        grid's generator.  Same seed, same plan, on every machine."""
        rng = _random.Random(seed)
        events = []
        for _ in range(n_events):
            kind = rng.choice(sorted(FAULT_KINDS))
            at = rng.randrange(max(1, max_index))
            if kind == "unavailable":
                events.append(FaultEvent(kind, verb=rng.choice(list(verbs)),
                                         at=at, count=rng.randint(1, 2)))
            elif kind == "crash":
                events.append(FaultEvent(
                    kind, component=rng.choice(list(components)), at=at))
            else:
                events.append(FaultEvent(
                    kind, table=rng.choice(list(tables)),
                    at=at + (1 if kind in ("snapshot", "restart") else 0)))
        return cls(events=tuple(events),
                   retry=retry or RetryPolicy(seed=seed), seed=seed)


class FaultInjector:
    """The runtime (and plan-time) arbiter of a :class:`FaultPlan`.

    Keeps the deterministic progress counters the events key on — per-verb
    attempt counts, per-table staging-attempt counts, per-table commit
    counts, per-component crash-point indices — and raises/returns the
    declared fault when a counter crosses an event.  The server owns one
    injector; every client of that server consults it, so the counters are
    global and (in sequential runs) fully deterministic.  The plan-time
    simulator (:func:`simulate_overhead`) drives a *fresh* injector with
    the same call sequence, which is what makes predictions exact."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.retry = plan.retry
        self.faults_injected = 0
        self._verb_attempts: dict[str, int] = defaultdict(int)
        self._stage_attempts: dict[str, int] = defaultdict(int)
        self._commits: dict[str, int] = defaultdict(int)
        self._consumed: set[int] = set()
        self._verb_events = [e for e in plan.events
                             if e.kind == "unavailable"]
        self._chunk_events = {(e.table, e.at): e.kind for e in plan.events
                              if e.kind in ("drop_chunk", "dup_chunk")}
        self._commit_events: dict[tuple, list[str]] = defaultdict(list)
        for e in plan.events:
            if e.kind in ("snapshot", "restart"):
                self._commit_events[(e.table, e.at)].append(e.kind)
        for acts in self._commit_events.values():
            acts.sort(reverse=True)      # snapshot before restart
        self._crash_events = [(i, e) for i, e in enumerate(plan.events)
                              if e.kind == "crash"]

    # -- injection points ---------------------------------------------------

    def on_verb(self, verb: str, table: str | None = None) -> None:
        """One client verb attempt (retries included).  Raises
        :class:`StoreUnavailable` when a declared window covers it."""
        i = self._verb_attempts[verb]
        self._verb_attempts[verb] = i + 1
        for e in self._verb_events:
            if e.verb == verb and (e.table is None or e.table == table) \
                    and e.at <= i < e.at + e.count:
                self.faults_injected += 1
                raise StoreUnavailable(
                    f"injected: store unavailable for {verb!r} attempt {i}")

    def on_stage(self, table: str) -> bool:
        """One chunk staging attempt on ``table`` (retries included).
        Raises :class:`TransferDropped` on a declared drop; returns True
        when a *duplicate* delivery of this chunk should follow (the
        caller pays the extra hop; the ack set deduplicates it)."""
        i = self._stage_attempts[table]
        self._stage_attempts[table] = i + 1
        kind = self._chunk_events.get((table, i))
        if kind == "drop_chunk":
            self.faults_injected += 1
            raise TransferDropped(
                f"injected: chunk transfer to {table!r} dropped "
                f"(staging attempt {i})")
        if kind == "dup_chunk":
            self.faults_injected += 1
            return True
        return False

    def on_commit(self, table: str) -> tuple[str, ...]:
        """One committed mutation of ``table``.  Returns the declared
        operator actions at this commit index: ``"snapshot"`` and/or
        ``"restart"`` (snapshot always first)."""
        self._commits[table] += 1
        acts = tuple(self._commit_events.get((table, self._commits[table]),
                                             ()))
        self.faults_injected += sum(1 for a in acts if a == "restart")
        return acts

    def maybe_crash(self, component: str, at: int) -> None:
        """One crash point (producer: before step/chunk ``at``; trainer:
        top of epoch ``at``).  Each declared crash fires exactly once —
        the restarted component passes the same index unharmed."""
        for i, e in self._crash_events:
            if i not in self._consumed and e.component == component \
                    and e.at == at:
                self._consumed.add(i)
                self.faults_injected += 1
                raise InjectedCrash(component, at)


# ---------------------------------------------------------------------------
# Plan-time cost prediction
# ---------------------------------------------------------------------------

@dataclass
class Overhead:
    """Per-component fault overhead: extra store dispatches (WAL replay
    after a store restart, plus the drain-on-restage flush of the overlap
    pipeline's surviving slot), extra staged transfers (dropped/
    duplicated chunk deliveries), verb retries, and component restarts."""

    extra_ops: int = 0
    extra_staged: int = 0
    retries: int = 0
    restarts: int = 0

    @property
    def empty(self) -> bool:
        return not (self.extra_ops or self.extra_staged or self.retries
                    or self.restarts)


def simulate_overhead(plan: FaultPlan, schedule, crosses_mesh: bool
                      ) -> tuple[dict[str, Overhead], dict[str, int]]:
    """Walk a session's component ``schedule`` through a fresh
    :class:`FaultInjector` and tally what the faults will cost.

    ``schedule`` is a list of dicts (declaration order — the sequential
    execution order the exactness claim covers), one per plan entry:

    * producer per-verb: ``{kind, name, tier: "per_verb", table, steps,
      emit_every, ranks}``
    * producer fused: ``{kind, name, tier, table, n_chunks, overlap}``
      (``overlap`` walks the two-slot staging pipeline: each chunk
      commits one capture late, a drop flushes the surviving slot, the
      final drain commits the last chunk)
    * trainer: ``{kind, name, tier, table, epochs, bootstrap}``
    * inference: ``{kind, name, tier, steps}``
    * serving clients: ``{kind, name, tier, table, results, requests,
      submit, collect}``
    * serving consumer: ``{kind, name, tier, table, results, requests,
      n_batches}``

    The walk mirrors the runtime control flow *exactly* — every
    ``on_verb`` / ``on_stage`` / ``on_commit`` / ``maybe_crash`` call the
    live components make, in the same order, driving the same injector
    class — so predicted retries/replays/restages equal the measured
    counters, not approximately but identically.  Returns
    ``(per_component_overhead, totals)`` where totals carries the
    ``faults_injected`` / ``retries`` / ``recoveries`` the server's
    ``stats()`` will report."""
    inj = FaultInjector(plan)
    wal_len: dict[str, int] = defaultdict(int)
    wal_base: dict[str, int] = defaultdict(int)
    recoveries = [0]
    per: dict[str, Overhead] = {}

    def _verb(o: Overhead, verb: str, table: str | None) -> None:
        while True:
            try:
                inj.on_verb(verb, table)
                return
            except StoreUnavailable:
                o.retries += 1

    def _commit(o: Overhead, table: str) -> None:
        wal_len[table] += 1
        for act in inj.on_commit(table):
            if act == "snapshot":
                for t in list(wal_len):
                    wal_base[t] = wal_len[t]
            else:  # restart: replay every table's WAL tail, one op each
                o.extra_ops += sum(wal_len[t] - wal_base[t]
                                   for t in wal_len)
                recoveries[0] += 1

    def _logged_capture(o: Overhead, table: str) -> None:
        # mirrors Client.capture_scan's WAL path: verb attempt, staging
        # attempt (hop paid before the drop check), dup pays one more hop
        while True:
            try:
                inj.on_verb("capture", table)
            except StoreUnavailable:
                o.retries += 1
                continue
            try:
                dup = inj.on_stage(table)
            except TransferDropped:
                o.retries += 1
                if crosses_mesh:
                    o.extra_staged += 1
                continue
            if dup and crosses_mesh:
                o.extra_staged += 1
            break
        _commit(o, table)

    def _overlap_capture(o: Overhead, table: str, pending: bool) -> bool:
        # mirrors the two-slot pipeline in Client.capture_scan: verb
        # attempt, then THIS chunk's staging attempt (hop paid before the
        # drop check, dup pays one more).  A drop triggers the drain-on-
        # restage flush — the surviving in-flight slot commits in its own
        # recovery dispatch — before the retry re-collects and re-stages.
        # A successful stage swaps slots: the PREVIOUS chunk commits in
        # this capture, the new chunk becomes the in-flight slot.
        while True:
            try:
                inj.on_verb("capture", table)
            except StoreUnavailable:
                o.retries += 1
                continue
            try:
                dup = inj.on_stage(table)
            except TransferDropped:
                o.retries += 1
                if crosses_mesh:
                    o.extra_staged += 1
                if pending:
                    o.extra_ops += 1      # the drain-on-restage dispatch
                    _commit(o, table)
                    pending = False
                continue
            if dup and crosses_mesh:
                o.extra_staged += 1
            if pending:
                _commit(o, table)
            return True

    def _serve_chunk(o: Overhead, table: str) -> None:
        # mirrors Client.serve_batch: verb attempt, then the injector's
        # stage hook on the results table (a drop retries the whole fused
        # dispatch under the same chunk id; the serve dispatch never
        # crosses the interconnect, so no hops are counted either way),
        # then the commit boundary
        while True:
            try:
                inj.on_verb("serve", table)
            except StoreUnavailable:
                o.retries += 1
                continue
            try:
                inj.on_stage(table)
            except TransferDropped:
                o.retries += 1
                continue
            break
        _commit(o, table)

    def _crash_point(o: Overhead, name: str, at: int) -> None:
        while True:
            try:
                inj.maybe_crash(name, at)
                return
            except InjectedCrash:
                o.restarts += 1

    for comp in schedule:
        o = per.setdefault(comp["name"], Overhead())
        kind, tier = comp["kind"], comp["tier"]
        if kind == "producer" and tier == "per_verb":
            for t in range(comp["steps"]):
                _crash_point(o, comp["name"], t)
                if t % comp["emit_every"] == 0:
                    for _ in range(comp["ranks"]):
                        _verb(o, "put", comp["table"])
                        _commit(o, comp["table"])
        elif kind == "producer":
            if comp.get("overlap"):
                pending = False
                for i in range(comp["n_chunks"]):
                    _crash_point(o, comp["name"], i)
                    pending = _overlap_capture(o, comp["table"], pending)
                if pending:
                    # the capture-end drain: its dispatch is part of the
                    # base plan (("drain", 1)), only its commit walks here
                    _commit(o, comp["table"])
            else:
                for i in range(comp["n_chunks"]):
                    _crash_point(o, comp["name"], i)
                    _logged_capture(o, comp["table"])
        elif kind == "trainer":
            if comp["bootstrap"]:
                _verb(o, "sample", comp["table"])
            for e in range(comp["epochs"]):
                _crash_point(o, comp["name"], e)
                if tier == "per_verb":
                    _verb(o, "sample", comp["table"])
                elif tier == "slab_sharded_clustered":
                    _verb(o, "sample_staged", comp["table"])
                else:           # fused tiers: a read-only capture
                    _verb(o, "capture", comp["table"])
        elif kind == "inference" and tier == "three_step":
            tin, tout = f"{comp['name']}_in", f"{comp['name']}_out"
            for _ in range(comp["steps"]):
                _verb(o, "put", tin)
                _commit(o, tin)       # put_tensor of the input
                _commit(o, tout)      # run_model's prediction put
        elif kind == "clients":
            if comp["submit"]:
                for r in range(comp["requests"]):
                    _crash_point(o, comp["name"], r)
                    _verb(o, "put", comp["table"])
                    _commit(o, comp["table"])
            if comp["collect"]:
                # response gets ride the fault boundary but never commit
                for _ in range(comp["requests"]):
                    _verb(o, "get", comp["results"])
        elif kind == "serving" and tier == "three_step":
            for r in range(comp["requests"]):
                _crash_point(o, comp["name"], r)
                _verb(o, "get", comp["table"])
                _verb(o, "put", comp["results"])
                _commit(o, comp["results"])
        elif kind == "serving":
            # continuous batching: crash index = batch index (recovery
            # re-cursors from the results watermark and retries the SAME
            # batch, so the drained-batch count is crash-invariant)
            for i in range(comp["n_batches"]):
                _crash_point(o, comp["name"], i)
                _serve_chunk(o, comp["results"])
        # fused_registry inference never touches the store: nothing to walk

    totals = {
        "faults_injected": inj.faults_injected,
        "retries": sum(o.retries for o in per.values()),
        "recoveries": recoveries[0],
    }
    return per, totals
