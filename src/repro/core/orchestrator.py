"""InSituDriver: the SmartSim "driver program" (paper §2.2).

The paper's driver is a Python script using the SmartSim infrastructure
library to launch the database, the CFD simulation and the distributed
training job, and to wire them together.  Here the driver:

  * builds the ``StoreServer`` with the chosen deployment (co-located or
    clustered),
  * creates the tables the workflow declares,
  * runs the producer and consumer loops on concurrent host threads
    (loose coupling: they interact only with the store, never with each
    other),
  * enforces wall-clock / step budgets and the straggler policy,
  * collects per-component timers from every rank and merges them into the
    paper's Tables-1/2 style report.

Fault-tolerance hooks: a component raising is recorded, the other side keeps
running until its own budget expires (the paper's loose coupling means one
side's failure never deadlocks the other), and ``InSituDriver.run`` returns
a structured result with per-component status so callers (tests, the
launcher) can decide to restart from the in-store checkpoint.
"""

from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from . import store as S
from .client import Client
from .deployment import Deployment
from .faults import FaultPlan
from .server import StoreServer
from .telemetry import Timers

__all__ = ["InSituDriver", "ComponentResult", "RunResult", "StragglerPolicy"]


@dataclass
class StragglerPolicy:
    """Deadline-based mitigation for slow components.

    ``consumer_wait_s``: how long the consumer waits for fresh data before
    training on what it has (never blocks indefinitely on a slow producer).
    ``producer_send_async``: producer sends are enqueue-only (JAX async
    dispatch); the producer never waits for the consumer at all.
    ``max_step_s``: if a single producer/consumer step exceeds this, the
    driver logs a straggler event (on real fleets this triggers rescheduling;
    here it feeds the telemetry used by tests).
    """

    consumer_wait_s: float = 30.0
    producer_send_async: bool = True
    max_step_s: float = float("inf")


@dataclass
class ComponentResult:
    name: str
    steps: int = 0
    error: str | None = None
    #: the exception class name behind ``error`` — the typed taxonomy
    #: (``WatermarkTimeout``, ``InjectedCrash``, …) survives formatting.
    error_type: str | None = None
    straggler_events: int = 0
    #: transient-fault verb retries this component's client absorbed.
    retries: int = 0
    #: crash-recovery restarts this component survived (producer: resumed
    #: from the table watermark; trainer: from ``MemoryCheckpoint``).
    restarts: int = 0
    wall_s: float = 0.0
    #: whatever the component callable returned (an int is also recorded as
    #: ``steps``; richer objects — e.g. the trainer's final state — ride
    #: here so session callers can get results back without side channels).
    output: Any = None
    #: store dispatches attributable to this component (sequential runs
    #: only — concurrent components interleave on one op counter).
    op_delta: int | None = None
    #: cross-mesh staged transfers attributable to this component
    #: (sequential runs only; always 0 off a clustered deployment).
    staged_delta: int | None = None

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass
class RunResult:
    components: dict[str, ComponentResult]
    timers: Timers
    wall_s: float
    #: which component's failure triggered the shutdown (``None`` when the
    #: run completed or ``stop_on_error`` was off).
    failed: str | None = None

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.components.values())

    @property
    def outputs(self) -> dict[str, Any]:
        """Per-component return values (``None`` for bare-int returns)."""
        return {name: c.output for name, c in self.components.items()}


class InSituDriver:
    """Launch producer/consumer component loops against one store."""

    def __init__(self, deployment: Deployment | None = None,
                 tables: Sequence[S.TableSpec] = (),
                 straggler: StragglerPolicy | None = None,
                 table_shardings: dict[str, Any] | None = None,
                 faults: FaultPlan | None = None):
        self.server = StoreServer(deployment, faults=faults)
        self.straggler = straggler or StragglerPolicy()
        table_shardings = table_shardings or {}
        for spec in tables:
            self.server.create_table(
                spec, slab_sharding=table_shardings.get(spec.name))

    def client(self, rank: int = 0) -> Client:
        return Client(self.server, rank=rank)

    def run(self, components: dict[str, Callable[[Client, "threading.Event"], int]],
            max_wall_s: float = 300.0, ranks: dict[str, int] | None = None,
            sequential: bool = False, stop_on_error: bool = True
            ) -> RunResult:
        """Run each component loop on its own thread.

        A component is ``fn(client, stop_event) -> steps_completed`` (or a
        richer output object carrying a ``steps`` attribute — it lands in
        ``ComponentResult.output``); it should poll ``stop_event`` between
        steps.  ``ranks`` assigns each component a client rank (default:
        enumeration order).

        ``sequential=True`` runs the components one after another in
        declaration order instead of concurrently — deterministic store-op
        attribution (``ComponentResult.op_delta``) for benchmarks and the
        plan-parity tests, and the natural mode for producer-then-train
        offline workflows.  The wall budget covers the whole sequence.

        ``stop_on_error`` (default on): the first component failure fires
        the stop event immediately, so siblings drain and exit instead of
        burning the rest of ``max_wall_s``; the triggering component lands
        in ``RunResult.failed``.  Pass ``stop_on_error=False`` to keep the
        old fully-loose coupling (siblings run to their own budgets —
        e.g. a consumer deliberately finishing on stale data after its
        producer died).
        """
        ranks = ranks or {}
        stop = threading.Event()
        results: dict[str, ComponentResult] = {}
        clients: dict[str, Client] = {}
        threads = []
        failed: list[str] = []

        def _wrap(name: str, fn):
            def _run():
                res = results[name]
                cl = clients[name]
                t0 = time.perf_counter()
                ops0 = self.server.op_count
                staged0 = self.server.staged_transfers
                try:
                    out = fn(cl, stop)
                    res.output = out
                    if isinstance(out, (int, type(None))):
                        res.steps = int(out or 0)
                        res.output = None
                    else:
                        res.steps = int(getattr(out, "steps", 0) or 0)
                except Exception as exc:  # noqa: BLE001 — component isolation
                    res.error = traceback.format_exc()
                    res.error_type = type(exc).__name__
                    if stop_on_error:
                        # prompt shutdown: siblings see the stop event now,
                        # not when their own wall budget expires
                        if not failed:
                            failed.append(name)
                        stop.set()
                finally:
                    res.wall_s = time.perf_counter() - t0
                    res.retries = cl.retries
                    res.restarts = cl.restarts
                    res.straggler_events = cl.straggler_events
                    if sequential:
                        res.op_delta = self.server.op_count - ops0
                        res.staged_delta = \
                            self.server.staged_transfers - staged0
            return _run

        for i, (name, fn) in enumerate(components.items()):
            results[name] = ComponentResult(name=name)
            clients[name] = Client(self.server, rank=ranks.get(name, i))
            threads.append(threading.Thread(target=_wrap(name, fn),
                                            name=f"insitu-{name}", daemon=True))

        t0 = time.perf_counter()
        deadline = t0 + max_wall_s
        if sequential:
            for th in threads:
                th.start()
                th.join(max(0.0, deadline - time.perf_counter()))
                if th.is_alive():        # budget exhausted: stop the rest
                    stop.set()
                    th.join(timeout=30.0)
        else:
            for th in threads:
                th.start()
            for th in threads:
                th.join(max(0.0, deadline - time.perf_counter()))
            stop.set()
            for th in threads:
                th.join(timeout=30.0)

        timers = Timers()
        for name, cl in clients.items():
            timers.merge(cl.timers)
        return RunResult(components=results, timers=timers,
                         wall_s=time.perf_counter() - t0,
                         failed=failed[0] if failed else None)
