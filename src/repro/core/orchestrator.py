"""InSituDriver: the SmartSim "driver program" (paper §2.2).

The paper's driver is a Python script using the SmartSim infrastructure
library to launch the database, the CFD simulation and the distributed
training job, and to wire them together.  Here the driver:

  * builds the ``StoreServer`` with the chosen deployment (co-located or
    clustered),
  * creates the tables the workflow declares,
  * runs the producer and consumer loops on concurrent host threads
    (loose coupling: they interact only with the store, never with each
    other),
  * enforces wall-clock / step budgets and the straggler policy,
  * collects per-component timers from every rank and merges them into the
    paper's Tables-1/2 style report.

Fault-tolerance hooks: a component raising is recorded, the other side keeps
running until its own budget expires (the paper's loose coupling means one
side's failure never deadlocks the other), and ``InSituDriver.run`` returns
a structured result with per-component status so callers (tests, the
launcher) can decide to restart from the in-store checkpoint.
"""

from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

from . import store as S
from .client import Client
from .deployment import Deployment
from .server import StoreServer
from .telemetry import Timers

__all__ = ["InSituDriver", "ComponentResult", "RunResult", "StragglerPolicy"]


@dataclass
class StragglerPolicy:
    """Deadline-based mitigation for slow components.

    ``consumer_wait_s``: how long the consumer waits for fresh data before
    training on what it has (never blocks indefinitely on a slow producer).
    ``producer_send_async``: producer sends are enqueue-only (JAX async
    dispatch); the producer never waits for the consumer at all.
    ``max_step_s``: if a single producer/consumer step exceeds this, the
    driver logs a straggler event (on real fleets this triggers rescheduling;
    here it feeds the telemetry used by tests).
    """

    consumer_wait_s: float = 30.0
    producer_send_async: bool = True
    max_step_s: float = float("inf")


@dataclass
class ComponentResult:
    name: str
    steps: int = 0
    error: str | None = None
    straggler_events: int = 0
    wall_s: float = 0.0

    @property
    def ok(self) -> bool:
        return self.error is None


@dataclass
class RunResult:
    components: dict[str, ComponentResult]
    timers: Timers
    wall_s: float

    @property
    def ok(self) -> bool:
        return all(c.ok for c in self.components.values())


class InSituDriver:
    """Launch producer/consumer component loops against one store."""

    def __init__(self, deployment: Deployment | None = None,
                 tables: Sequence[S.TableSpec] = (),
                 straggler: StragglerPolicy | None = None):
        self.server = StoreServer(deployment)
        self.straggler = straggler or StragglerPolicy()
        for spec in tables:
            self.server.create_table(spec)

    def client(self, rank: int = 0) -> Client:
        return Client(self.server, rank=rank)

    def run(self, components: dict[str, Callable[[Client, "threading.Event"], int]],
            max_wall_s: float = 300.0, ranks: dict[str, int] | None = None
            ) -> RunResult:
        """Run each component loop on its own thread.

        A component is ``fn(client, stop_event) -> steps_completed``; it
        should poll ``stop_event`` between steps.  ``ranks`` assigns each
        component a client rank (default: enumeration order).
        """
        ranks = ranks or {}
        stop = threading.Event()
        results: dict[str, ComponentResult] = {}
        clients: dict[str, Client] = {}
        threads = []

        def _wrap(name: str, fn):
            def _run():
                res = results[name]
                t0 = time.perf_counter()
                try:
                    res.steps = int(fn(clients[name], stop) or 0)
                except Exception:  # noqa: BLE001 — component isolation
                    res.error = traceback.format_exc()
                finally:
                    res.wall_s = time.perf_counter() - t0
            return _run

        for i, (name, fn) in enumerate(components.items()):
            results[name] = ComponentResult(name=name)
            clients[name] = Client(self.server, rank=ranks.get(name, i))
            threads.append(threading.Thread(target=_wrap(name, fn),
                                            name=f"insitu-{name}", daemon=True))

        t0 = time.perf_counter()
        for th in threads:
            th.start()
        deadline = t0 + max_wall_s
        for th in threads:
            th.join(max(0.0, deadline - time.perf_counter()))
        stop.set()
        for th in threads:
            th.join(timeout=30.0)

        timers = Timers()
        for name, cl in clients.items():
            timers.merge(cl.timers)
        return RunResult(components=results, timers=timers,
                         wall_s=time.perf_counter() - t0)
