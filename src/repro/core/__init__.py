"""Core in-situ coupling layer (the paper's contribution).

Components (paper Fig. 1): data producer and data consumer couple only
through the in-memory ``TensorStore`` (``store`` + ``server``) using the
SmartRedis-verb ``Client``; ``deployment`` chooses co-located vs clustered
placement; ``orchestrator`` is the SmartSim-driver analogue.
"""

from . import store
from .client import Client
from .deployment import (Clustered, Colocated, Deployment,
                         make_clustered_1d, make_clustered_2d,
                         make_colocated_1d, split_devices)
from .faults import (FaultEvent, FaultPlan, InjectedCrash, RetryPolicy,
                     StoreError, StoreTimeout, StoreUnavailable,
                     TransferDropped, WatermarkTimeout)
from .orchestrator import InSituDriver, RunResult, StragglerPolicy
from .server import StoreServer
from .store import TableSpec, TableState, make_key, name_key
from .telemetry import Timers

__all__ = [
    "store",
    "Client",
    "Clustered",
    "Colocated",
    "Deployment",
    "make_clustered_1d",
    "make_clustered_2d",
    "make_colocated_1d",
    "split_devices",
    "FaultEvent",
    "FaultPlan",
    "InjectedCrash",
    "RetryPolicy",
    "StoreError",
    "StoreTimeout",
    "StoreUnavailable",
    "TransferDropped",
    "WatermarkTimeout",
    "InSituDriver",
    "RunResult",
    "StragglerPolicy",
    "StoreServer",
    "TableSpec",
    "TableState",
    "make_key",
    "name_key",
    "Timers",
]
