"""StoreServer: the host-side owner of TensorStore state.

The Redis process of the paper becomes a lock-guarded holder of immutable
JAX store state.  Host threads (producer / consumer / driver) call the
server's verbs; each verb dispatches a jitted pure store op and swaps the
state reference.  JAX's async dispatch gives the loose coupling: a ``put``
returns as soon as the update is enqueued on the device stream, so the
producer (like the paper's PHASTA ranks) is blocked only for the enqueue,
not for the ML consumer.

Concurrency model (fused-pipeline rework):

* **Per-table locks.** Every table owns its own ``RLock``; a producer
  streaming into one table never serializes against a consumer reading a
  different table.  The server-wide lock only guards the registries
  (table/model/metadata maps), taken briefly and never while dispatching
  table ops.
* **Lock-free cached watermark.** A host-side monotonic counter per table
  is bumped at *dispatch* time (put +1, put_many +n, commit +puts), so
  ``watermark()`` / ``wait_watermark()`` read a Python int instead of
  dispatching a device reduction per poll — the consumer's 5 ms spin loop
  becomes a free memory read with exponential backoff.
* **Capture transactions.** ``capture(table)`` hands the caller the live
  ``TableState`` under the table lock; the caller dispatches one *fused*
  op (``store.capture_scan`` / a fused training epoch) and commits the
  updated state + put count.  One lock round-trip and one dispatch replace
  O(steps) verb calls.

Donation safety: ``put``/``put_many``/fused captures donate the previous
table state, which marks its buffers deleted *at dispatch time*.  Every
read of the same table therefore dispatches while holding that table's
lock — the lock orders dispatches, and the device stream executes them in
dispatch order, so a read enqueued before a donating put always sees live
buffers.  (Blocking host-side ``.item()``/print on results happens outside
the lock; returned arrays are fresh outputs, not aliases.)
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from . import store as S
from .deployment import Colocated, Deployment
from .faults import (FaultInjector, FaultPlan, StoreTimeout,
                     WatermarkTimeout)
from .telemetry import Timers, poll_backoff

__all__ = ["StoreServer", "CaptureTxn", "PendingChunk"]


class PendingChunk:
    """An in-flight slot of the overlap staging pipeline.

    The chunk's cross-mesh ``stage_chunk`` transfer has been dispatched
    (and its wire crossing counted), but its masked insert has not run
    yet — ``keys``/``values``/``mask`` are the *staged* (db-placed)
    arrays, so the deferred :meth:`StoreServer.insert_chunk` is a pure
    db-mesh dispatch with no further interconnect traffic.
    """

    __slots__ = ("chunk_id", "keys", "values", "mask", "puts")

    def __init__(self, chunk_id: tuple, keys, values, mask, puts: int):
        self.chunk_id = chunk_id
        self.keys = keys
        self.values = values
        self.mask = mask
        self.puts = puts


class CaptureTxn:
    """One fused-capture transaction on a single table.

    ``state`` holds the checked-out ``TableState``; assign the updated
    state back to commit.  Set ``puts`` to the number of put operations
    the fused dispatch performed so the cached watermark stays exact
    (``store.capture_emit_count`` computes it for ``capture_scan``).
    Read-only captures (consumers) simply leave ``state`` untouched.
    """

    __slots__ = ("spec", "state", "puts", "_orig")

    def __init__(self, spec: S.TableSpec, state: S.TableState):
        self.spec = spec
        self.state = state
        self.puts = 0
        self._orig = state


class StoreServer:
    """Thread-safe owner of a set of store tables plus the model registry."""

    def __init__(self, deployment: Deployment | None = None,
                 timers: Timers | None = None,
                 faults: FaultPlan | None = None):
        self.deployment = deployment
        self.timers = timers or Timers()
        self._lock = threading.RLock()           # registries + metadata only
        self._table_locks: dict[str, threading.RLock] = {}
        self._specs: dict[str, S.TableSpec] = {}
        self._state: dict[str, S.TableState] = {}
        self._counts: dict[str, int] = {}        # cached watermarks
        self._placements: dict[str, Any] = {}    # slab shardings (recovery)
        self._models: dict[str, tuple[Callable, Any]] = {}
        self._model_raw: dict[str, Callable] = {}  # unjitted apply fns
        self._model_versions: dict[str, int] = {}  # hot-swap generations
        self.model_swaps = 0                     # serving weight adoptions
        self._meta: dict[str, Any] = {}          # tiny host-side metadata KV
        self._meta_event = threading.Condition(self._lock)
        self._ops_lock = threading.Lock()
        self.op_count = 0                        # dispatched store ops
        self.staged_transfers = 0                # cross-mesh staging hops
        self._gathers: dict[tuple, Callable] = {}  # clustered gather cache
        # -- fault/recovery machinery (armed by a declared FaultPlan, even
        # an empty one — the fault-free chaos baseline takes this path too)
        plan = faults if faults is not None \
            else getattr(deployment, "faults", None)
        self.faults = FaultInjector(plan) if plan is not None else None
        self.wal_enabled = plan is not None
        self.retries = 0                         # verb retries (clients')
        self.recoveries = 0                      # completed store restarts
        self._wal: dict[str, list] = {}          # per-table write-ahead log
        self._wal_base: dict[str, int] = {}      # replay floor (snapshot)
        self._acked: set = set()                 # applied chunk ids
        self._recovery: dict[str, S.TableState] | None = None

    def _bump_ops(self, n: int = 1) -> None:
        with self._ops_lock:
            self.op_count += n

    def _bump_staged(self, n: int = 1) -> None:
        with self._ops_lock:
            self.staged_transfers += n

    def _bump_retry(self, n: int = 1) -> None:
        with self._ops_lock:
            self.retries += n

    # -- table management ---------------------------------------------------

    def create_table(self, spec: S.TableSpec,
                     deployment: Deployment | None = None,
                     slab_sharding=None) -> S.TableSpec:
        """Register + allocate a table.  ``slab_sharding`` explicitly
        places the slab (e.g. the slab-sharded trainer tier partitioning
        the slot axis over its data mesh via
        ``parallel.sharding.slab_sharding``); when ``None`` the
        deployment's placement rule applies."""
        dep = deployment or self.deployment
        if slab_sharding is None and dep is not None:
            slab_sharding = dep.slab_sharding(spec)
        with self._lock:
            if spec.name in self._specs:
                raise ValueError(f"table {spec.name!r} already exists")
            self._specs[spec.name] = spec
            self._state[spec.name] = S.init_table(spec, slab_sharding)
            self._table_locks[spec.name] = threading.RLock()
            self._counts[spec.name] = 0
            self._placements[spec.name] = slab_sharding
            self._wal[spec.name] = []
            self._wal_base[spec.name] = 0
        return spec

    def placement(self, table: str) -> Any:
        """The slab sharding ``table`` was created with (``None`` = default
        placement) — what a recovering restart re-allocates against."""
        return self._placements[table]

    def spec(self, table: str) -> S.TableSpec:
        return self._specs[table]

    def tables(self) -> list[str]:
        return list(self._specs)

    def hbm_bytes(self) -> int:
        return sum(S.table_bytes(sp) for sp in self._specs.values())

    def table_lock(self, table: str) -> threading.RLock:
        """The per-table lock (dispatch ordering for fused captures)."""
        return self._table_locks[table]

    # -- fused-capture fast path ---------------------------------------------

    def checkout(self, table: str) -> S.TableState:
        with self._table_locks[table]:
            return self._state[table]

    def commit(self, table: str, new_state: S.TableState,
               puts: int = 0) -> None:
        """Swap in a state produced by a fused dispatch.

        ``puts``: how many put ops the dispatch performed — keeps the
        cached watermark exact without a device read.
        """
        with self._table_locks[table]:
            self._state[table] = new_state
            self._counts[table] += puts
        self._bump_ops()

    @contextlib.contextmanager
    def capture(self, table: str):
        """Checkout → fused dispatch → commit, atomically under the table
        lock.  Yields a :class:`CaptureTxn`; the body must only *dispatch*
        (async) device work — block on results after the ``with`` exits.

        An assigned ``txn.state`` commits even if the body then raises:
        fused ops donate the checked-out state at dispatch time, so
        rolling back to it would leave the table pointing at deleted
        buffers.  A body that raises *without* assigning leaves the table
        untouched.  (Assign the fused op's result to ``txn.state`` in the
        same statement as the dispatch.)
        """
        committed = False
        with self._table_locks[table]:
            txn = CaptureTxn(self._specs[table], self._state[table])
            try:
                yield txn
            finally:
                if txn.state is not txn._orig:
                    self._state[table] = txn.state
                    self._counts[table] += txn.puts
                    committed = True
        # One capture == one fused dispatch (read-only captures included).
        self._bump_ops()
        if committed:
            self._after_commit(table)

    # -- verbs ---------------------------------------------------------------

    def _staged(self, value, spec: S.TableSpec | None = None):
        """Stage one element onto the store placement (per-verb path).

        Threads the table's real ``TableSpec`` through to the deployment
        so spec-dependent element layouts hold, and counts one staged
        transfer whenever the deployment actually crosses meshes."""
        dep = self.deployment
        if dep is None:
            return value
        if dep.crosses_mesh:
            self._bump_staged()
        return dep.stage(value, spec)

    def _staged_batch(self, values, spec: S.TableSpec | None = None):
        """Stage a ``[n, *shape]`` batch in ONE transfer (batched verbs)."""
        dep = self.deployment
        if dep is None:
            return values
        if dep.crosses_mesh:
            self._bump_staged()
        return dep.stage_batch(values, spec)

    def stage_chunk(self, table: str, keys, values, mask):
        """Stage a whole fused-capture chunk (keys + values + emit mask)
        onto the store placement as ONE cross-mesh transfer — the
        clustered fused put's only interconnect hop per dispatch.  A
        no-op (and not counted) for deployments that never cross meshes.
        """
        dep = self.deployment
        if dep is None or not dep.crosses_mesh:
            return keys, values, mask
        self._bump_staged()
        return dep.stage_chunk(keys, values, mask, self._specs[table])

    # lint: holds-lock — runs inside the caller's capture txn (table lock)
    def apply_chunk(self, table: str, chunk_id: tuple, txn: CaptureTxn,
                    keys, values, mask, puts: int) -> None:
        """Exactly-once insert of one collected chunk (the WAL-logged form
        of ``stage_chunk`` + ``put_masked``, used whenever a ``FaultPlan``
        is armed).

        ``chunk_id`` is the client's stable ``(rank, seq)`` — the SAME id
        on every retry of the same chunk, a NEW id per new chunk.  The
        acknowledged-id set gives exactly-once semantics on an at-least-
        once transport: ``store.put_masked`` is last-writer-wins but not
        idempotent (ring pointer and count advance per apply), so a
        duplicated delivery is *deduplicated* here rather than re-applied,
        and a dropped delivery is retried by the client under the same id.
        The staging hop is counted (and the injector consulted) *before*
        the transfer: a dropped chunk still paid its interconnect hop, a
        duplicated chunk pays one extra.
        """
        spec = self._specs[table]
        dep = self.deployment
        crossing = dep is not None and dep.crosses_mesh
        if crossing:
            self._bump_staged()
        # may raise TransferDropped (hop already paid, nothing applied);
        # dup=True means a second copy of this chunk arrives right after
        dup = self.faults.on_stage(table) if self.faults is not None \
            else False
        if chunk_id not in self._acked:
            if crossing:
                keys, values, mask = dep.stage_chunk(keys, values, mask,
                                                     spec)
            txn.state = S.put_masked(spec, txn.state, keys, values, mask)
            txn.puts = puts
            self._acked.add(chunk_id)
            if self.wal_enabled:
                self._wal[table].append(("chunk", (keys, values, mask),
                                         puts))
        if dup:
            # the duplicate delivery: one more hop, then the ack set makes
            # it a no-op — the table state never sees the second apply
            if crossing:
                self._bump_staged()
            assert chunk_id in self._acked

    def stage_chunk_logged(self, table: str, chunk_id: tuple,
                           keys, values, mask, puts: int) -> PendingChunk:
        """First half of the overlapped exactly-once apply —
        :meth:`apply_chunk` split at the wire: pay the crossing, consult
        the injector, start the async cross-mesh transfer (donating the
        client-side collect buffers), and hand back the in-flight
        :class:`PendingChunk` for the client's two-slot pipeline.

        Staged-transfer accounting is identical to the serial path and
        counts once per *wire crossing*, at stage time: a dropped
        transfer already paid its hop (the restage after the drain-on-
        restage flush pays again, because the chunk crosses again), a
        duplicated delivery pays one extra, and the deferred insert —
        however many capture dispatches later it lands — never counts.
        That is what keeps ``predicted == stats()`` exact with two slots
        in flight.
        """
        spec = self._specs[table]
        dep = self.deployment
        crossing = dep is not None and dep.crosses_mesh
        if crossing:
            self._bump_staged()
        # may raise TransferDropped (hop already paid, nothing in flight)
        dup = self.faults.on_stage(table) if self.faults is not None \
            else False
        if crossing:
            keys, values, mask = dep.stage_chunk(keys, values, mask, spec,
                                                 donate=True)
        if dup and crossing:
            self._bump_staged()
        return PendingChunk(chunk_id, keys, values, mask, puts)

    # lint: holds-lock — runs inside the caller's capture txn (table lock)
    def insert_chunk(self, table: str, txn: CaptureTxn,
                     pending: PendingChunk) -> None:
        """Second half of the overlapped apply: the masked insert of an
        in-flight staged chunk, inside the caller's capture txn.
        Deduplicated by the ack set exactly like :meth:`apply_chunk`
        (``put_masked`` is last-writer-wins but not idempotent), and
        WAL-logged with the staged arrays so a restart replays it
        byte-identically."""
        if pending.chunk_id in self._acked:
            return
        spec = self._specs[table]
        txn.state = S.put_masked(spec, txn.state, pending.keys,
                                 pending.values, pending.mask)
        txn.puts += pending.puts
        self._acked.add(pending.chunk_id)
        if self.wal_enabled:
            self._wal[table].append(("chunk", (pending.keys, pending.values,
                                               pending.mask), pending.puts))

    def _after_commit(self, table: str) -> None:
        """Injected-operator actions at a commit boundary: a declared
        ``snapshot`` parks a recovery image (and truncates the replay
        tail), a declared ``restart`` kills and rebuilds the store."""
        if self.faults is None:
            return
        for act in self.faults.on_commit(table):
            if act == "snapshot":
                self._take_recovery_snapshot()
            else:
                self._restart_and_recover()

    def put(self, table: str, key, value) -> None:
        spec = self._specs[table]
        value = self._staged(value, spec)
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._table_locks[table]:
            self._state[table] = S.put(spec, self._state[table], key, value)
            self._counts[table] += 1
            if self.wal_enabled:
                self._wal[table].append(("put", (key, value), 1))
        self._bump_ops()
        self._after_commit(table)

    def put_many(self, table: str, keys, values) -> None:
        spec = self._specs[table]
        values = self._staged_batch(values, spec)
        keys = jax.numpy.asarray(keys, S.KEY_DTYPE)
        with self._table_locks[table]:
            self._state[table] = S.put_many(spec, self._state[table], keys,
                                            values)
            self._counts[table] += int(keys.shape[0])
            if self.wal_enabled:
                self._wal[table].append(("put_many", (keys, values),
                                         int(keys.shape[0])))
        self._bump_ops()
        self._after_commit(table)

    def put_stream(self, table: str, keys, values) -> None:
        """One dispatch for a whole trajectory of sends (fused pipeline)."""
        spec = self._specs[table]
        values = self._staged_batch(values, spec)
        keys = jax.numpy.asarray(keys, S.KEY_DTYPE)
        n = int(keys.shape[0]) * (int(keys.shape[1]) if keys.ndim == 2 else 1)
        with self._table_locks[table]:
            self._state[table] = S.put_stream(spec, self._state[table], keys,
                                              values)
            self._counts[table] += n
            if self.wal_enabled:
                self._wal[table].append(("put_stream", (keys, values), n))
        self._bump_ops()
        self._after_commit(table)

    def get(self, table: str, key):
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._table_locks[table]:
            out = S.get(spec, self._state[table], key)
        self._bump_ops()
        return out

    def get_many(self, table: str, keys):
        spec = self._specs[table]
        with self._table_locks[table]:
            out = S.get_many(spec, self._state[table], keys)
        self._bump_ops()
        return out

    def serve_batch(self, req_table: str, res_table: str, keys, mask,
                    apply_fn, params, chunk_id: tuple | None = None):
        """Drain one continuous-batching batch in ONE fused dispatch:
        gather the active requests from ``req_table``, apply the bound
        model, scatter the responses into ``res_table``
        (``store.serve_batch``).

        Requests, model params and responses all live on the store
        placement, so the dispatch never crosses the interconnect — no
        staged transfers are counted — but the injector's stage hook on
        ``res_table`` is still consulted so drop/dup chaos events exercise
        the serving path.  Under an armed ``FaultPlan`` the batch is
        WAL-logged as a ``put_masked`` chunk (host-known ``mask``, so a
        restart replays the insert byte-identically) and deduplicated by
        ``chunk_id`` exactly like :meth:`apply_chunk`.  Returns the
        per-slot found-and-served flags.
        """
        req_spec = self._specs[req_table]
        res_spec = self._specs[res_table]
        keys = jnp.asarray(keys, S.KEY_DTYPE)
        mask_dev = jnp.asarray(mask, bool)
        puts = int(mask_dev.sum())
        first, second = sorted((req_table, res_table))
        with self._table_locks[first], self._table_locks[second]:
            dup = self.faults.on_stage(res_table) \
                if self.faults is not None else False
            if chunk_id is None or chunk_id not in self._acked:
                new_res, ok, ys = S.serve_batch(
                    req_spec, res_spec, apply_fn,
                    self._state[req_table], self._state[res_table],
                    params, keys, mask_dev)
                self._state[res_table] = new_res
                self._counts[res_table] += puts
                if chunk_id is not None:
                    self._acked.add(chunk_id)
                if self.wal_enabled:
                    self._wal[res_table].append(
                        ("chunk", (keys, ys, mask_dev), puts))
            else:
                ok = mask_dev
        self._bump_ops()
        self._after_commit(res_table)
        return ok

    def sample(self, table: str, rng, n: int):
        spec = self._specs[table]
        with self._table_locks[table]:
            out = S.sample(spec, self._state[table], rng, n)
        self._bump_ops()
        return out

    def _clustered_gather(self, table: str, n: int):
        """Cached db-mesh gather executable for ``sample_staged`` (one per
        (table, batch size); see ``store.make_clustered_gather``)."""
        key = (table, n)
        fn = self._gathers.get(key)
        if fn is None:
            spec = self._specs[table]
            dep = self.deployment
            db_mesh = getattr(dep, "db_mesh", None)
            axis = getattr(dep, "slab_axis", None)
            shards = dep.gather_shards(spec) \
                if hasattr(dep, "gather_shards") else 1
            fn = S.make_clustered_gather(spec, n, db_mesh=db_mesh,
                                         axis=axis, shards=shards)
            with self._lock:
                self._gathers[key] = fn
        return fn

    def sample_staged(self, table: str, rng, n: int):
        """Clustered read verb: sample ``n`` elements ON the store mesh
        (shard-local gather + explicit psum when the slab is
        slot-partitioned), then move the assembled batch back onto the
        clients in ONE counted cross-mesh transfer.

        One store dispatch (like ``sample``) plus one staged transfer —
        the read-side mirror of the fused clustered put.  Degrades to a
        plain sample (no staging, nothing counted) under co-located /
        local deployments.  Returns ``(values [n, *shape], ok)``.
        """
        gather = self._clustered_gather(table, n)
        with self._table_locks[table]:
            values, ok = gather(self._state[table], rng)
        dep = self.deployment
        if dep is not None and dep.crosses_mesh:
            values, ok = dep.stage_to_clients((values, ok))
            self._bump_staged()
        self._bump_ops()
        return values, ok

    def latest(self, table: str, n: int):
        spec = self._specs[table]
        with self._table_locks[table]:
            out = S.latest(spec, self._state[table], n)
        self._bump_ops()
        return out

    def poll(self, table: str, key) -> bool:
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._table_locks[table]:
            hit = S.poll(spec, self._state[table], key)
        self._bump_ops()
        return bool(hit)

    def delete(self, table: str, key) -> None:
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._table_locks[table]:
            self._state[table] = S.delete(spec, self._state[table], key)
            if self.wal_enabled:
                # Tombstones must replay too: a restart that re-runs the
                # put log but skips deletes resurrects dead keys.
                self._wal[table].append(("delete", (key,), 0))
        self._bump_ops()
        self._after_commit(table)

    def stats(self) -> dict:
        """Telemetry snapshot: dispatched-op count, cross-mesh staged
        transfers, plus every table's cached watermark.  ``op_count``
        counts host→device dispatches (one per verb, one per fused
        capture) — the benchmarks' O(k)-vs-O(1) dispatch claims are
        measured from deltas of this dict.  ``staged_transfers`` counts
        interconnect hops of a clustered deployment (one per staged verb
        element/batch, one per fused chunk, one per staged gather) — the
        Fig.-5 clustered traffic, measured."""
        with self._lock:
            marks = dict(self._counts)
        return {"op_count": self.op_count,
                "staged_transfers": self.staged_transfers,
                "faults_injected": self.faults.faults_injected
                if self.faults is not None else 0,
                "retries": self.retries,
                "recoveries": self.recoveries,
                "model_swaps": self.model_swaps,
                "watermarks": marks}

    def watermark(self, table: str) -> int:
        """Total writes so far — the consumer's freshness signal.

        Lock-free: reads the host-side cached counter (updated at dispatch
        time), so polling never dispatches a device op and never contends
        with the producer.
        """
        return self._counts[table]

    def watermark_device(self, table: str) -> int:
        """Ground-truth watermark from device state (blocking read; tests
        assert it always equals the cached ``watermark``)."""
        with self._table_locks[table]:
            count = jax.numpy.asarray(self._state[table].count).copy()
        return int(count)

    def valid_count(self, table: str) -> int:
        spec = self._specs[table]
        with self._table_locks[table]:
            n = S.valid_count(spec, self._state[table])
        self._bump_ops()
        return int(n)

    def wait_watermark(self, table: str, minimum: int, timeout: float = 60.0,
                       interval: float = 0.001,
                       max_interval: float = 0.05,
                       strict: bool = True) -> bool:
        """Block until ``watermark >= minimum`` (paper: ML ranks poll the DB
        while waiting for the first snapshot).  On timeout raises
        :class:`~repro.core.faults.WatermarkTimeout` carrying the table,
        the wanted/actual watermarks and the deadline — or, with
        ``strict=False`` (straggler mitigation: proceed on stale data),
        returns False instead.

        Polls the lock-free cached watermark with deadline-clamped
        exponential backoff (``telemetry.poll_backoff``) — zero device
        dispatches and zero producer contention while spinning, and the
        call never overshoots ``timeout`` by a backoff step.
        """
        for _ in poll_backoff(timeout, interval, max_interval):
            if self._counts[table] >= minimum:
                return True
        if self._counts[table] >= minimum:
            return True
        if strict:
            raise WatermarkTimeout(table, minimum, self._counts[table],
                                   timeout)
        return False

    # -- metadata (host KV, paper's "useful metadata") ------------------------

    def put_meta(self, name: str, value) -> None:
        with self._meta_event:
            self._meta[name] = value
            self._meta_event.notify_all()

    def get_meta(self, name: str, default=None):
        with self._lock:
            return self._meta.get(name, default)

    def wait_meta(self, name: str, timeout: float = 60.0,
                  strict: bool = True):
        """Block until metadata ``name`` exists.  On timeout raises
        :class:`~repro.core.faults.StoreTimeout` (``strict=False``: returns
        None — the polling form inference consumers loop on)."""
        with self._meta_event:
            ok = self._meta_event.wait_for(lambda: name in self._meta,
                                           timeout=timeout)
            if ok:
                return self._meta.get(name)
        if strict:
            raise StoreTimeout("metadata", name, timeout)
        return None

    # -- model registry (RedisAI analogue) ------------------------------------

    def set_model(self, key: str, apply_fn: Callable, params,
                  jit_compile: bool = True) -> None:
        """Store a model "in the database": params pinned to the store
        placement, apply jitted.  The producer only ever sees ``key``."""
        dep = self.deployment
        if dep is not None and not isinstance(dep, Colocated):
            params = jax.tree.map(dep.stage, params)
        fn = jax.jit(apply_fn) if jit_compile else apply_fn
        with self._lock:
            self._models[key] = (fn, params)
            # keep the UNJITTED fn too: the fused serving dispatch takes
            # it as a static jit arg, and a fresh jax.jit wrapper per
            # publish would miss its compile cache on every hot-swap
            self._model_raw[key] = apply_fn
            self._model_versions[key] = \
                self._model_versions.get(key, 0) + 1

    def has_model(self, key: str) -> bool:
        with self._lock:
            return key in self._models

    def run_model(self, key: str, *inputs):
        with self._lock:
            fn, params = self._models[key]
        return fn(params, *inputs)

    def model_keys(self) -> list[str]:
        with self._lock:
            return list(self._models)

    def model_version(self, key: str) -> int:
        """Monotonic publication counter for ``key`` (0 = never published).
        Each ``set_model`` bumps it — the serving consumer's hot-swap
        watermark, polled for free like the table watermarks."""
        with self._lock:
            return self._model_versions.get(key, 0)

    def bind_model(self, key: str, have: int | None = None):
        """Atomically adopt the current weights for ``key`` if they are
        newer than generation ``have``.

        Returns ``(apply_fn, params, version)`` on adoption — including the
        very first bind (``have=None``) — or ``None`` when nothing newer is
        published.  ``apply_fn`` is the publisher's raw (unjitted)
        function, identity-stable across re-publishes of the same
        callable, so the fused serving dispatch's compile cache survives
        hot-swaps.  Version read and registry read happen under one lock,
        so a concurrent ``set_model`` can never hand out torn
        (old-params, new-version) pairs; every adoption bumps
        ``model_swaps`` in :meth:`stats`.
        """
        with self._lock:
            version = self._model_versions.get(key, 0)
            if version == 0 or version == have:
                return None
            fn = self._model_raw[key]
            params = self._models[key][1]
        with self._ops_lock:
            self.model_swaps += 1
        return fn, params, version

    # -- in-memory checkpointing hook -----------------------------------------

    def snapshot(self) -> dict[str, S.TableState]:
        """Deep snapshot of all table state.  Copies the buffers: later
        ``put``s donate (invalidate) the live state, so a zero-copy
        snapshot would dangle.  Tables are snapshotted one at a time under
        their own locks (per-table consistency)."""
        snap = {}
        with self._lock:
            names = list(self._specs)
        for name in names:
            with self._table_locks[name]:
                snap[name] = jax.tree.map(jax.numpy.copy, self._state[name])
        return snap

    def restore(self, snap: dict[str, S.TableState]) -> None:
        for name, st in snap.items():
            if name in self._specs:
                with self._table_locks[name]:
                    self._state[name] = st
                    # Re-derive the cached watermark from device truth.
                    self._counts[name] = int(jax.numpy.asarray(st.count))

    # -- injected store restart + recovery -------------------------------------

    def _take_recovery_snapshot(self) -> None:
        """Park a recovery image (a declared ``snapshot`` fault event):
        deep-copies every table and marks the current WAL length as the
        replay floor — commits before this point never replay again (the
        snapshot truncates the log, which is also what keeps the WAL from
        growing without bound in a long-running session)."""
        snap = self.snapshot()
        # The image and the replay floor are registry state: publish them
        # under the registry lock so a concurrent restart never sees the
        # new snapshot paired with the old floor (or vice versa).
        with self._lock:
            self._recovery = snap
            for t in self._wal:
                self._wal_base[t] = len(self._wal[t])

    def _replay_entry(self, spec: S.TableSpec, state: S.TableState,
                      kind: str, payload) -> S.TableState:
        if kind == "put":
            return S.put(spec, state, *payload)
        if kind == "put_many":
            return S.put_many(spec, state, *payload)
        if kind == "put_stream":
            return S.put_stream(spec, state, *payload)
        if kind == "delete":
            return S.delete(spec, state, *payload)
        return S.put_masked(spec, state, *payload)       # "chunk"

    def _restart_and_recover(self) -> None:
        """A declared ``restart`` fault: the store process dies and comes
        back.  The device slab is lost; each table is rebuilt from the
        last recovery snapshot (or re-initialised empty if none was taken)
        and the WAL tail since that snapshot is replayed — the same puts,
        in the same commit order, against the same base state, so the
        recovered table is byte-identical to the pre-crash one (the store
        ops are pure functions of (state, chunk): determinism carries the
        exactly-once argument through a restart).  The snapshot is
        restored as a *copy* — later puts donate the live state, and the
        parked image must survive a second restart.  Each replayed entry
        is one real dispatch, counted in ``op_count`` (and predicted by
        ``faults.simulate_overhead``)."""
        with self._lock:
            names = list(self._specs)
        for name in names:
            spec = self._specs[name]
            with self._table_locks[name]:
                if self._recovery is not None and name in self._recovery:
                    st = jax.tree.map(jax.numpy.copy, self._recovery[name])
                else:
                    st = S.init_table(spec, self._placements[name])
                for kind, payload, _puts in \
                        self._wal[name][self._wal_base[name]:]:
                    st = self._replay_entry(spec, st, kind, payload)
                    self._bump_ops()
                self._state[name] = st
                self._counts[name] = int(jax.numpy.asarray(st.count))
        with self._ops_lock:
            self.recoveries += 1
