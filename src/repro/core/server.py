"""StoreServer: the host-side owner of TensorStore state.

The Redis process of the paper becomes a lock-guarded holder of immutable
JAX store state.  Host threads (producer / consumer / driver) call the
server's verbs; each verb dispatches a jitted pure store op and swaps the
state reference.  JAX's async dispatch gives the loose coupling: a ``put``
returns as soon as the update is enqueued on the device stream, so the
producer (like the paper's PHASTA ranks) is blocked only for the enqueue,
not for the ML consumer.

For *fused in-situ capture* (beyond-paper fast path) a producer step can own
a table's state directly inside its jit: ``checkout()`` hands the state out,
``commit()`` swaps the updated state back in.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from . import store as S
from .deployment import Colocated, Deployment
from .telemetry import Timers

__all__ = ["StoreServer"]


class StoreServer:
    """Thread-safe owner of a set of store tables plus the model registry."""

    def __init__(self, deployment: Deployment | None = None,
                 timers: Timers | None = None):
        self.deployment = deployment
        self.timers = timers or Timers()
        self._lock = threading.RLock()
        self._specs: dict[str, S.TableSpec] = {}
        self._state: dict[str, S.TableState] = {}
        self._models: dict[str, tuple[Callable, Any]] = {}
        self._meta: dict[str, Any] = {}          # tiny host-side metadata KV
        self._meta_event = threading.Condition(self._lock)

    # -- table management ---------------------------------------------------

    def create_table(self, spec: S.TableSpec, deployment: Deployment | None = None):
        dep = deployment or self.deployment
        slab_sharding = dep.slab_sharding(spec) if dep is not None else None
        with self._lock:
            if spec.name in self._specs:
                raise ValueError(f"table {spec.name!r} already exists")
            self._specs[spec.name] = spec
            self._state[spec.name] = S.init_table(spec, slab_sharding)
        return spec

    def spec(self, table: str) -> S.TableSpec:
        return self._specs[table]

    def tables(self) -> list[str]:
        return list(self._specs)

    def hbm_bytes(self) -> int:
        return sum(S.table_bytes(sp) for sp in self._specs.values())

    # -- fused-capture escape hatch ------------------------------------------

    def checkout(self, table: str) -> S.TableState:
        with self._lock:
            return self._state[table]

    def commit(self, table: str, new_state: S.TableState) -> None:
        with self._lock:
            self._state[table] = new_state

    # -- verbs ---------------------------------------------------------------

    def _staged(self, value):
        dep = self.deployment
        return dep.stage(value) if dep is not None else value

    def put(self, table: str, key, value) -> None:
        spec = self._specs[table]
        value = self._staged(value)
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._lock:
            self._state[table] = S.put(spec, self._state[table], key, value)

    def put_many(self, table: str, keys, values) -> None:
        spec = self._specs[table]
        values = self._staged(values)
        with self._lock:
            self._state[table] = S.put_many(spec, self._state[table], keys, values)

    # NOTE on donation safety: ``put``/``put_many`` donate the previous
    # table state, which marks its buffers deleted *at dispatch time*.
    # Every read therefore dispatches its op while holding the lock — the
    # lock orders dispatches, and the device stream executes them in
    # dispatch order, so a read enqueued before a donating put always sees
    # live buffers.  (Blocking host-side .item()/print on the result happens
    # outside the lock; the returned arrays are fresh outputs, not aliases.)

    def get(self, table: str, key):
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._lock:
            return S.get(spec, self._state[table], key)

    def get_many(self, table: str, keys):
        spec = self._specs[table]
        with self._lock:
            return S.get_many(spec, self._state[table], keys)

    def sample(self, table: str, rng, n: int):
        spec = self._specs[table]
        with self._lock:
            return S.sample(spec, self._state[table], rng, n)

    def latest(self, table: str, n: int):
        spec = self._specs[table]
        with self._lock:
            return S.latest(spec, self._state[table], n)

    def poll(self, table: str, key) -> bool:
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._lock:
            return bool(S.poll(spec, self._state[table], key))

    def delete(self, table: str, key) -> None:
        spec = self._specs[table]
        key = jax.numpy.asarray(key, S.KEY_DTYPE)
        with self._lock:
            self._state[table] = S.delete(spec, self._state[table], key)

    def watermark(self, table: str) -> int:
        """Total writes so far — the consumer's freshness signal."""
        with self._lock:
            count = jax.numpy.asarray(self._state[table].count).copy()
        return int(count)

    def valid_count(self, table: str) -> int:
        spec = self._specs[table]
        with self._lock:
            n = S.valid_count(spec, self._state[table])
        return int(n)

    def wait_watermark(self, table: str, minimum: int, timeout: float = 60.0,
                       interval: float = 0.005) -> bool:
        """Block until ``watermark >= minimum`` (paper: ML ranks poll the DB
        while waiting for the first snapshot).  Returns False on timeout —
        the caller decides whether to proceed with stale data (straggler
        mitigation) or abort."""
        deadline = time.perf_counter() + timeout
        while time.perf_counter() < deadline:
            if self.watermark(table) >= minimum:
                return True
            time.sleep(interval)
        return self.watermark(table) >= minimum

    # -- metadata (host KV, paper's "useful metadata") ------------------------

    def put_meta(self, name: str, value) -> None:
        with self._meta_event:
            self._meta[name] = value
            self._meta_event.notify_all()

    def get_meta(self, name: str, default=None):
        with self._lock:
            return self._meta.get(name, default)

    def wait_meta(self, name: str, timeout: float = 60.0):
        with self._meta_event:
            ok = self._meta_event.wait_for(lambda: name in self._meta,
                                           timeout=timeout)
            return self._meta.get(name) if ok else None

    # -- model registry (RedisAI analogue) ------------------------------------

    def set_model(self, key: str, apply_fn: Callable, params,
                  jit_compile: bool = True) -> None:
        """Store a model "in the database": params pinned to the store
        placement, apply jitted.  The producer only ever sees ``key``."""
        dep = self.deployment
        if dep is not None and not isinstance(dep, Colocated):
            params = jax.tree.map(dep.stage, params)
        fn = jax.jit(apply_fn) if jit_compile else apply_fn
        with self._lock:
            self._models[key] = (fn, params)

    def has_model(self, key: str) -> bool:
        with self._lock:
            return key in self._models

    def run_model(self, key: str, *inputs):
        with self._lock:
            fn, params = self._models[key]
        return fn(params, *inputs)

    def model_keys(self) -> list[str]:
        with self._lock:
            return list(self._models)

    # -- in-memory checkpointing hook -----------------------------------------

    def snapshot(self) -> dict[str, S.TableState]:
        """Deep snapshot of all table state.  Copies the buffers: later
        ``put``s donate (invalidate) the live state, so a zero-copy
        snapshot would dangle."""
        with self._lock:
            return {name: jax.tree.map(jax.numpy.copy, st)
                    for name, st in self._state.items()}

    def restore(self, snap: dict[str, S.TableState]) -> None:
        with self._lock:
            for name, st in snap.items():
                if name in self._specs:
                    self._state[name] = st
