"""Client: the SmartRedis-verb API (paper §2.2).

One ``Client`` per producer/consumer rank.  Mirrors the SmartRedis surface
the paper leans on ("a single call … each requiring a single line of code"):

    client = Client(server, rank=3)
    client.put_tensor("x.3.120", x)                     # named put
    client.send_step("field", step=120, value=x)        # rank/step-keyed put
    y, ok = client.get_tensor("x.3.120")
    client.poll_tensor("x.3.120", timeout=10.0)
    client.set_model("encoder", apply_fn, params)
    client.run_model("encoder", inputs=["x.3.120"], outputs=["z.3.120"])
    z, _ = client.get_tensor("z.3.120")

plus the fused ``infer`` fast path (beyond-paper: one dispatch instead of the
paper's three-step send/run/retrieve) and the consumer-side batch loaders.

Every verb is timed into the paper's component buckets:
``client_init`` / ``metadata`` / ``send`` / ``retrieve`` / ``model_eval``.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from . import store as S
from .deployment import StagingPipeline
from .faults import StoreTimeout, TransferDropped, call_with_retry
from .server import StoreServer
from .telemetry import Timers, poll_backoff

__all__ = ["Client"]


class Client:
    def __init__(self, server: StoreServer, rank: int = 0,
                 timers: Timers | None = None):
        t0 = time.perf_counter()
        self.server = server
        self.rank = int(rank)
        self.timers = timers or Timers()
        #: fault-tolerance telemetry, surfaced through ComponentResult:
        #: verb retries absorbed, restarts survived, straggler events seen.
        self.retries = 0
        self.restarts = 0
        self.straggler_events = 0
        self._seq = 0            # next fused-chunk sequence number
        # two-slot overlap pipelines, one per table (clustered fused tier)
        self._staging: dict[str, StagingPipeline] = {}
        # "Client initialization" = establishing the connection in the paper;
        # here: binding the server reference and warming the key hasher.
        S.name_key("__warmup__")
        self.timers.record("client_init", time.perf_counter() - t0)

    # -- fault boundary --------------------------------------------------------

    def _count_retry(self) -> None:
        self.retries += 1
        self.server._bump_retry()

    def _call_verb(self, verb: str, table: str | None, call):
        """Route one store verb through the fault boundary: the server's
        injector (if armed) sees one attempt per call, transient
        ``StoreUnavailable`` windows are absorbed by the plan's
        ``RetryPolicy`` (bounded, jittered, deadline-clamped backoff), and
        every absorbed retry is counted on both the client and the server.
        Without a ``FaultPlan`` this is a plain call — zero overhead."""
        inj = self.server.faults
        if inj is None:
            return call()

        def attempt():
            inj.on_verb(verb, table)
            return call()

        return call_with_retry(attempt, inj.retry, self._count_retry)

    def fault_point(self, component: str, idx: int) -> None:
        """A declared crash point: raises
        :class:`~repro.core.faults.InjectedCrash` exactly once if the plan
        says ``component`` dies at ``idx`` (the caller's restart loop
        catches it and resumes from the watermark / checkpoint)."""
        inj = self.server.faults
        if inj is not None:
            inj.maybe_crash(component, idx)

    # -- named tensors ---------------------------------------------------------

    def put_tensor(self, name: str, value, table: str = "default") -> None:
        with self.timers.time("send", payload=value):
            self._call_verb("put", table,
                            lambda: self.server.put(table, S.name_key(name),
                                                    value))

    def get_tensor(self, name: str, table: str = "default"):
        with self.timers.time("retrieve") as box:
            value, found = self.server.get(table, S.name_key(name))
            box[0] = value
        return value, found

    def delete_tensor(self, name: str, table: str = "default") -> None:
        self.server.delete(table, S.name_key(name))

    def poll_tensor(self, name: str, table: str = "default",
                    timeout: float = 10.0, interval: float = 0.001,
                    max_interval: float = 0.05, strict: bool = True) -> bool:
        """Poll until the key exists (SmartRedis ``poll_tensor``).

        Each probe dispatches one device op, so the spin uses exponential
        backoff (``interval`` doubling up to ``max_interval``) instead of a
        fixed-rate busy loop hammering the dispatch queue.  On timeout
        raises :class:`~repro.core.faults.StoreTimeout` naming the tensor
        and the deadline; ``strict=False`` restores the old silent-False
        contract for callers probing optional keys.
        """
        key = S.name_key(name)
        with self.timers.time("metadata"):
            for _ in poll_backoff(timeout, interval, max_interval):
                if self.server.poll(table, key):
                    return True
            if strict:
                raise StoreTimeout("tensor", name, timeout,
                                   f"table {table!r}")
            return False

    # -- rank/step-keyed streaming (the simulation path) ------------------------

    def send_step(self, table: str, step: int, value) -> None:
        """Send this rank's contribution of one time step (unique key per
        rank and step, exactly the paper's keying scheme)."""
        with self.timers.time("send", payload=value):
            self._call_verb(
                "put", table,
                lambda: self.server.put(table, S.make_key(self.rank, step),
                                        value))

    def put_kv(self, table: str, key, value) -> None:
        """Pre-made-key put through the fault boundary (the session's
        per-verb producer path — retried on transient unavailability)."""
        with self.timers.time("send", payload=value):
            self._call_verb("put", table,
                            lambda: self.server.put(table, key, value))

    def get_kv(self, table: str, key):
        """Pre-made-key get through the fault boundary (the serving
        clients' response poll — retried on transient unavailability).
        Returns ``(value, found)``."""
        with self.timers.time("retrieve") as box:
            value, found = self._call_verb(
                "get", table, lambda: self.server.get(table, key))
            box[0] = value
        return value, found

    def serve_batch(self, req_table: str, res_table: str, keys, mask,
                    apply_fn, params):
        """One continuous-batching drain through the fault boundary: the
        fused gather → model → scatter dispatch
        (``StoreServer.serve_batch``) under a stable chunk id, so a
        dropped response transfer is retried under the SAME id and the
        server's ack set keeps the insert exactly-once.  Returns the
        per-slot served flags."""
        inj = self.server.faults
        chunk_id = None
        if self.server.wal_enabled:
            chunk_id = (self.rank, self._seq)
            self._seq += 1
        with self.timers.time("model_eval") as box:
            def attempt():
                if inj is not None:
                    inj.on_verb("serve", res_table)
                return self.server.serve_batch(req_table, res_table, keys,
                                               mask, apply_fn, params,
                                               chunk_id=chunk_id)

            if inj is None:
                ok = attempt()
            else:
                ok = call_with_retry(attempt, inj.retry, self._count_retry)
            box[0] = ok
        return ok

    def retrieve_step(self, table: str, rank: int, step: int):
        with self.timers.time("retrieve") as box:
            value, found = self.server.get(table, S.make_key(rank, step))
            box[0] = value
        return value, found

    def send_batch(self, table: str, step: int, values, ranks=None) -> None:
        """Vectorized send of many ranks' contributions in one dispatch."""
        n = values.shape[0]
        ranks = jnp.arange(n) if ranks is None else jnp.asarray(ranks)
        keys = S.make_key(ranks, jnp.full((n,), step))
        with self.timers.time("send", payload=values):
            self.server.put_many(table, keys, values)

    # -- fused-capture fast path --------------------------------------------------

    @contextlib.contextmanager
    def capture(self, table: str = "default"):
        """Fused in-situ capture transaction (beyond-paper fast path).

        Yields the server's :class:`~repro.core.server.CaptureTxn` under
        the table's lock: dispatch ONE fused op (``store.capture_scan`` /
        ``store.sample_and_step`` / a fused epoch) against ``txn.state``,
        assign the result back, set ``txn.puts`` — then block on outputs
        after the ``with`` exits.  Replaces O(steps) per-verb calls with
        one dispatch and one lock round-trip.
        """
        with self.server.capture(table) as txn:
            yield txn

    def capture_scan(self, table: str, step_fn, carry, length: int,
                     emit_every: int = 1, t0=0, n_ranks: int | None = None,
                     bucket: bool = False, elem_sharding=None):
        """Fold ``length`` producer steps + their ring puts into ONE
        dispatch under one table-lock round-trip (the fused producer tier).

        ``n_ranks=None``: the single-producer form —
        ``step_fn(carry, t) -> (carry, key, value)``.  With ``n_ranks=R``
        the multi-producer form: ``step_fn(carry_r, rank, t)`` is vmapped
        over the leading ``[R]`` axis of ``carry`` and every emitting step
        interleaves all R snapshots into the ring (see
        ``store.capture_scan_multi``).  ``t0`` is an int or (multi-
        producer) a *concrete* per-rank ``[R]`` array of clock offsets —
        the put count is computed on the host from rank 0's clock, so a
        non-int ``t0`` costs one blocking read here; the cached watermark
        is bumped by the exact static put count.  Returns the new carry
        (the dispatch is async — block on it or on a later read when
        ordering matters).

        ``bucket=True`` pads the chunk to its power-of-two bucket
        (``store.bucket_length``) with traced-masked no-op steps, so a
        driver whose tail chunk is shorter than its body chunk reuses one
        executable per (table, bucket) instead of compiling every distinct
        tail length (the scan runs ``bucket_length(length)`` iterations;
        only the first ``length`` advance the carry or the table).

        Under a *clustered* deployment the whole chunk still costs ONE
        interconnect hop: the steps run collect-only on the client side
        (``store.capture_scan_collect[_multi]``), the stacked chunk is
        staged onto the store mesh in one batched reshard
        (``StoreServer.stage_chunk`` — counted in
        ``stats()["staged_transfers"]``), and one ``store.put_masked``
        dispatch inserts it — instead of the per-element ``device_put``
        the per-verb tier pays.

        ``elem_sharding`` (a ``NamedSharding`` over the element dims, or
        ``None``) pins every emitted value to the producer's own layout —
        a domain-decomposed solver's snapshot is put **shard-local**, the
        ``capture_scan_sharded`` tier of ``insitu.plan``.
        """
        spec = self.server.spec(table)
        t0_gate = int(jnp.reshape(jnp.asarray(t0), (-1,))[0]) \
            if not isinstance(t0, int) else t0
        padded, valid = length, None
        if bucket:
            padded = S.bucket_length(length)
            valid = jnp.asarray(length, jnp.int32)
        dep = self.server.deployment
        staged = dep is not None and dep.crosses_mesh
        # The put-count accounting is deployment-independent — one source,
        # whichever branch dispatches below.
        puts = S.capture_emit_count(length, emit_every, t0_gate) \
            if n_ranks is None else S.capture_emit_count_multi(
                n_ranks, length, emit_every, t0_gate)
        # Crossing deployments must go collect → stage → masked-insert; an
        # armed FaultPlan routes every deployment through the same logged
        # path, because exactly-once needs the chunk boundary: the chunk
        # gets a stable (rank, seq) id — the SAME id on every retry, a NEW
        # id per chunk — that the server's ack set deduplicates, and the
        # applied chunk lands in the WAL for replay after a store restart.
        logged = staged or self.server.wal_enabled
        with self.timers.time("send"):
            if logged:
                chunk_id = (self.rank, self._seq)
                self._seq += 1
                inj = self.server.faults
                # Two-slot overlap: stage this chunk's reshard async, then
                # insert the PREVIOUS chunk (whose transfer has had a full
                # collect-duration to land).  Serial order is preserved —
                # inserts happen in collect order, one capture late — so
                # the ring's last-writer-wins contents are byte-identical.
                overlap = staged and getattr(dep, "overlap", False)

                def attempt():
                    if inj is not None:
                        inj.on_verb("capture", table)
                    try:
                        with self.server.capture(table) as txn:
                            if n_ranks is None:
                                new_carry, keys, vals, mask = \
                                    S.capture_scan_collect(
                                        spec, step_fn, carry, padded,
                                        emit_every, t0=t0, valid=valid,
                                        elem_sharding=elem_sharding)
                            else:
                                new_carry, keys, vals, mask = \
                                    S.capture_scan_collect_multi(
                                        spec, step_fn, carry, padded,
                                        n_ranks, emit_every, t0=t0,
                                        valid=valid,
                                        elem_sharding=elem_sharding)
                            if overlap:
                                pending = self.server.stage_chunk_logged(
                                    table, chunk_id, keys, vals, mask,
                                    puts)
                                prev = self._pipeline(table).swap(pending)
                                if prev is not None:
                                    self.server.insert_chunk(table, txn,
                                                             prev)
                            else:
                                self.server.apply_chunk(table, chunk_id,
                                                        txn, keys, vals,
                                                        mask, puts)
                    except TransferDropped:
                        # drain-on-restage: flush the surviving in-flight
                        # slot before the retry re-collects and re-stages,
                        # so the pipeline never holds a stale slot across
                        # a fault boundary
                        self.drain_captures(table)
                        raise
                    return new_carry

                # collect never donates the carry, so a dropped transfer
                # retries the whole attempt against the original carry
                if inj is None:
                    return attempt()
                return call_with_retry(attempt, inj.retry,
                                       self._count_retry)
            with self.capture(table) as txn:
                txn.puts = puts
                if n_ranks is None:
                    txn.state, carry = S.capture_scan(
                        spec, txn.state, step_fn, carry, padded, emit_every,
                        t0=t0, valid=valid, elem_sharding=elem_sharding)
                else:
                    txn.state, carry = S.capture_scan_multi(
                        spec, txn.state, step_fn, carry, padded, n_ranks,
                        emit_every, t0=t0, valid=valid,
                        elem_sharding=elem_sharding)
        return carry

    def _pipeline(self, table: str) -> StagingPipeline:
        pipe = self._staging.get(table)
        if pipe is None:
            pipe = self._staging[table] = StagingPipeline()
        return pipe

    def drain_captures(self, table: str) -> None:
        """Flush the two-slot staging pipeline: insert the in-flight
        staged chunk in one capture dispatch.  Called at capture end
        (every overlapped producer run ends with exactly one in-flight
        chunk, so the plan predicts this as ONE ``drain`` dispatch) and
        on fault-injected restage (where its dispatch is recovery
        overhead, mirrored by ``faults.simulate_overhead``).  A no-op —
        no dispatch, nothing counted — when nothing is pending."""
        pipe = self._staging.get(table)
        prev = pipe.drain() if pipe is not None else None
        if prev is None:
            return
        with self.timers.time("send"):
            with self.server.capture(table) as txn:
                self.server.insert_chunk(table, txn, prev)

    # -- consumer-side loaders ---------------------------------------------------

    def sample_batch(self, table: str, n: int, rng):
        """Random gather of ``n`` stored tensors (the paper's data loader)."""
        with self.timers.time("retrieve") as box:
            values, keys, ok = self._call_verb(
                "sample", table, lambda: self.server.sample(table, rng, n))
            box[0] = values
        return values, keys, ok

    def sample_staged(self, table: str, n: int, rng):
        """Clustered random gather: sample on the store mesh, bring the
        assembled batch back across the interconnect in ONE counted
        staged transfer (``StoreServer.sample_staged``).  Returns
        ``(values [n,*shape], ok)``."""
        with self.timers.time("retrieve") as box:
            values, ok = self._call_verb(
                "sample_staged", table,
                lambda: self.server.sample_staged(table, rng, n))
            box[0] = values
        return values, ok

    def capture_epoch(self, table: str, body):
        """One fused read-only capture through the fault boundary: a
        transient ``StoreUnavailable`` window on the "capture" verb is
        absorbed *before* the table lock is taken, so a failed attempt
        dispatches nothing and bumps no counters — the retried capture is
        the one that counts.  ``body(txn)``'s return value is passed
        through (the fused trainer's ``(state, metrics)``)."""
        inj = self.server.faults

        def attempt():
            if inj is not None:
                inj.on_verb("capture", table)
            with self.server.capture(table) as txn:
                return body(txn)

        if inj is None:
            return attempt()
        return call_with_retry(attempt, inj.retry, self._count_retry)

    def latest_batch(self, table: str, n: int):
        with self.timers.time("retrieve") as box:
            values, keys, valid = self.server.latest(table, n)
            box[0] = values
        return values, keys, valid

    def wait_for_data(self, table: str, minimum: int = 1,
                      timeout: float = 60.0) -> bool:
        """Paper: "the ML workload must query the database multiple times
        while waiting for the first training snapshot".  Keeps the bool
        contract (``strict=False``): on timeout the trainer proceeds with
        whatever data exists — the straggler mitigation path."""
        with self.timers.time("metadata"):
            return self.server.wait_watermark(table, minimum, timeout,
                                              strict=False)

    def watermark(self, table: str) -> int:
        with self.timers.time("metadata"):
            return self.server.watermark(table)

    # -- metadata ------------------------------------------------------------------

    def put_metadata(self, name: str, value) -> None:
        with self.timers.time("metadata"):
            self.server.put_meta(name, value)

    def get_metadata(self, name: str, timeout: float | None = None,
                     strict: bool = False):
        """Non-strict by default (None on a missed ``timeout`` wait) — the
        inference consumer polls this in a loop; pass ``strict=True`` to
        get a typed :class:`~repro.core.faults.StoreTimeout` instead."""
        with self.timers.time("metadata"):
            if timeout is None:
                return self.server.get_meta(name)
            return self.server.wait_meta(name, timeout=timeout,
                                         strict=strict)

    # -- models (RedisAI verbs) -------------------------------------------------------

    def set_model(self, key: str, apply_fn: Callable, params) -> None:
        with self.timers.time("model_load"):
            self.server.set_model(key, apply_fn, params)

    def run_model(self, key: str, inputs: Sequence[str],
                  outputs: Sequence[str], table: str = "default",
                  out_table: str | None = None) -> None:
        """Evaluate a stored model on stored tensors, store the predictions.

        The three-step paper protocol is: (1) ``put_tensor`` the inference
        data, (2) ``run_model`` by key, (3) ``get_tensor`` the predictions —
        this verb is step (2) alone, so callers measure each step just like
        paper Fig. 7.
        """
        out_table = out_table or table
        ins = []
        for nm in inputs:
            v, found = self.server.get(table, S.name_key(nm))
            ins.append(v)
        with self.timers.time("model_eval") as box:
            outs = self.server.run_model(key, *ins)
            box[0] = outs
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        if len(outs) != len(outputs):
            raise ValueError(f"model {key!r} returned {len(outs)} outputs, "
                             f"expected {len(outputs)}")
        for nm, o in zip(outputs, outs):
            self.server.put(out_table, S.name_key(nm), o)

    def infer(self, key: str, *xs):
        """Fused fast path: one dispatch, no store round-trip (beyond-paper;
        the tightly-coupled LibTorch baseline of Fig. 7, but still going
        through the registry so the producer stays model-agnostic)."""
        with self.timers.time("model_eval") as box:
            out = self.server.run_model(key, *xs)
            box[0] = out
        return out
