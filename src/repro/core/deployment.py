"""Store deployment policies: co-located vs clustered (paper §2.3).

On Polaris the *co-located* deployment runs one database shard per compute
node (sharing the node with the simulation and ML ranks) so that every
send/retrieve stays on-node; the *clustered* deployment gives the database
dedicated nodes and pushes every transfer across the interconnect.

TPU-native translation:

* **Colocated(mesh, elem_spec)** — the store slab's element dims carry the
  *same PartitionSpec as the producer's output*.  A ``put`` of a
  producer-sharded tensor is then a per-device local slab update: the
  compiled HLO contains **zero collective ops** ("all data transfer is
  contained within each node").  The resource the store consumes is HBM
  (slots per chip) rather than CPU cores; ``hbm_budget`` mirrors the
  paper's Fig-3 core-count sweep.

* **Clustered(client_mesh, db_mesh, elem_spec)** — the store lives on a
  *dedicated* device subset (its own mesh).  ``stage`` moves a
  producer-mesh array onto the store mesh (``jax.device_put`` across
  meshes = the TCP transfer of the paper), and the many-clients-per-shard
  contention that wrecks the paper's clustered weak scaling shows up as a
  producer:db fan-in ratio.

Both policies expose the same small interface consumed by the
``StoreServer``/``Client``:

    slab_sharding(spec)  -> sharding for the [capacity, *shape] slab
    elem_sharding(spec)  -> sharding of one element (what ``stage`` targets)
    stage(x)             -> move x onto the store placement (identity when
                            co-located and already aligned)
    fan_in               -> clients per store shard (1 for co-located)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .store import TableSpec

__all__ = ["Deployment", "Colocated", "Clustered", "split_devices"]


def split_devices(devices=None, db_fraction: float = 0.25):
    """Split the available devices into (client, db) sets for Clustered.

    Mirrors the paper's node split (e.g. 448 sim + 16 DB nodes).  At least
    one device lands on each side; with a single device both sides share it
    (degenerate but keeps laptop-scale runs working).
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) == 1:
        return devices, devices
    n_db = max(1, int(round(len(devices) * db_fraction)))
    n_db = min(n_db, len(devices) - 1)
    return devices[:-n_db], devices[-n_db:]


class Deployment:
    """Interface; see module docstring."""

    #: clients per store shard — drives the clustered contention model.
    fan_in: int = 1

    def slab_sharding(self, spec: TableSpec):
        raise NotImplementedError

    def elem_sharding(self, spec: TableSpec):
        raise NotImplementedError

    def stage(self, x):
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


@dataclass
class Colocated(Deployment):
    """Store sharded exactly like the producer output (on-node DB analogue).

    ``elem_spec`` is the PartitionSpec of one stored element; it must match
    the sharding the producer emits so that put/get are collective-free.
    ``capacity_axis`` optionally shards the slot axis too (spreading the
    ring across an unused mesh axis — beyond-paper, trades capacity for
    per-chip HBM).
    """

    mesh: Mesh
    elem_spec: P = P()
    capacity_axis: str | None = None

    fan_in: int = 1

    def slab_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.capacity_axis, *self.elem_spec))

    def elem_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.elem_spec)

    def stage(self, x):
        # Producer output is already placed correctly: zero-copy.  We do not
        # device_put here on purpose — a sharding mismatch should surface as
        # a collective in the compiled put (tests assert it does not).
        return x

    def describe(self) -> str:
        return (f"colocated(mesh={tuple(self.mesh.shape.items())}, "
                f"elem_spec={self.elem_spec})")


@dataclass
class Clustered(Deployment):
    """Store on dedicated devices; every transfer crosses the interconnect."""

    client_mesh: Mesh
    db_mesh: Mesh
    elem_spec: P = P()          # layout of an element across the db mesh

    def __post_init__(self):
        n_clients = int(np.prod(list(self.client_mesh.shape.values())))
        n_db = int(np.prod(list(self.db_mesh.shape.values())))
        self.fan_in = max(1, n_clients // max(1, n_db))

    def slab_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.db_mesh, P(None, *self.elem_spec))

    def elem_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.db_mesh, self.elem_spec)

    def stage(self, x):
        """The cross-network hop: reshard from client mesh onto the db mesh."""
        return jax.device_put(x, self.elem_sharding(None))

    def describe(self) -> str:
        return (f"clustered(clients={tuple(self.client_mesh.shape.items())}, "
                f"db={tuple(self.db_mesh.shape.items())}, fan_in={self.fan_in})")


def make_colocated_1d(axis: str = "data", mesh: Mesh | None = None,
                      shard_dim: int = 0, ndim: int = 1) -> Colocated:
    """Convenience: co-located deployment sharding element dim 0 over `axis`."""
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), (axis,))
    spec = [None] * ndim
    spec[shard_dim] = axis
    return Colocated(mesh=mesh, elem_spec=P(*spec))
