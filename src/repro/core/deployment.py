"""Store deployment policies: co-located vs clustered (paper §2.3).

On Polaris the *co-located* deployment runs one database shard per compute
node (sharing the node with the simulation and ML ranks) so that every
send/retrieve stays on-node; the *clustered* deployment gives the database
dedicated nodes and pushes every transfer across the interconnect.

TPU-native translation:

* **Colocated(mesh, elem_spec)** — the store slab's element dims carry the
  *same PartitionSpec as the producer's output*.  A ``put`` of a
  producer-sharded tensor is then a per-device local slab update: the
  compiled HLO contains **zero collective ops** ("all data transfer is
  contained within each node").  The resource the store consumes is HBM
  (slots per chip) rather than CPU cores; ``hbm_budget`` mirrors the
  paper's Fig-3 core-count sweep.

* **Clustered(client_mesh, db_mesh, elem_spec)** — the store lives on a
  *dedicated* device subset (its own mesh).  ``stage`` moves a
  producer-mesh array onto the store mesh (``jax.device_put`` across
  meshes = the TCP transfer of the paper), and the many-clients-per-shard
  contention that wrecks the paper's clustered weak scaling shows up as a
  producer:db fan-in ratio.  ``slab_axis`` optionally partitions the
  slot axis over the db mesh — the slab-sharded *clustered* data plane
  (each db shard owns ``capacity/D`` slots, like the paper's sharded
  KeyDB run).

Both policies expose the same small interface consumed by the
``StoreServer``/``Client``:

    slab_sharding(spec)      -> sharding for the [capacity, *shape] slab
    elem_sharding(spec)      -> sharding of one element (``stage``'s target)
    stage(x, spec)           -> move one element onto the store placement
                                (identity when co-located and aligned)
    stage_batch(xs, spec)    -> move a [n, *shape] batch in ONE transfer
    stage_chunk(k, v, m, spec) -> move a whole fused-capture chunk
                                (keys + values + mask) in ONE transfer
    stage_to_clients(x)      -> the read-side hop back onto the clients
    crosses_mesh             -> does ``stage`` actually move bytes across
                                the interconnect? (drives the server's
                                staged-transfer telemetry)
    fan_in                   -> clients per store shard (1 for co-located)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .faults import FaultPlan
from .store import TableSpec

__all__ = ["Deployment", "Colocated", "Clustered", "split_devices",
           "fan_in_ratio", "StagingPipeline",
           "make_colocated_1d", "make_clustered_1d", "make_clustered_2d"]


def fan_in_ratio(n_clients: int, n_db: int) -> int:
    """Clients per db shard — the paper's Fig.-5 contention knob.

    Ceiling division: 3 clients over 2 db shards load the busiest shard
    with 2, not 1 — the contention model cares about the *hottest* shard.
    This is THE single source both ``Clustered.fan_in`` and the plan's
    ``ComponentPlan.fan_in`` consult; floors at 1 when clients < shards.
    """
    return max(1, -(-int(n_clients) // max(1, int(n_db))))


# jax.device_put grew buffer donation in 0.4.31; staging works (one extra
# copy alive) without it, so feature-detect instead of pinning a version.
try:
    import inspect as _inspect
    _DEVICE_PUT_DONATE = "donate" in _inspect.signature(
        jax.device_put).parameters
except Exception:  # pragma: no cover - signature introspection only
    _DEVICE_PUT_DONATE = False


def split_devices(devices=None, db_fraction: float = 0.25):
    """Split the available devices into (client, db) sets for Clustered.

    Mirrors the paper's node split (e.g. 448 sim + 16 DB nodes).  At least
    one device lands on each side; with a single device both sides share it
    (degenerate but keeps laptop-scale runs working).
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) == 1:
        return devices, devices
    n_db = max(1, int(round(len(devices) * db_fraction)))
    n_db = min(n_db, len(devices) - 1)
    return devices[:-n_db], devices[-n_db:]


class Deployment:
    """Interface; see module docstring."""

    #: clients per store shard — drives the clustered contention model.
    fan_in: int = 1
    #: does ``stage`` move bytes across the interconnect?  The server
    #: counts one staged transfer per stage call only when this is set.
    crosses_mesh: bool = False
    #: declared fault plan (``core.faults.FaultPlan``) — a server built on
    #: this deployment arms its injector + exactly-once machinery with it.
    faults: FaultPlan | None = None

    def slab_sharding(self, spec: TableSpec):
        raise NotImplementedError

    def elem_sharding(self, spec: TableSpec):
        raise NotImplementedError

    def stage(self, x, spec: TableSpec | None = None):
        raise NotImplementedError

    def stage_batch(self, values, spec: TableSpec | None = None):
        """Move a ``[n, *shape]`` batch onto the store placement in one
        transfer (leading batch axis never sharded by ``elem_spec``)."""
        raise NotImplementedError

    def stage_chunk(self, keys, values, mask, spec: TableSpec | None = None):
        """Move a whole fused-capture chunk (keys ``[n]``, values
        ``[n, *shape]``, emit mask ``[n]``) onto the store placement as
        ONE batched transfer."""
        raise NotImplementedError

    def stage_to_clients(self, x):
        """The read-side hop: move a gathered batch from the store
        placement back onto the consumers (identity when co-located)."""
        raise NotImplementedError

    def describe(self) -> str:
        raise NotImplementedError


@dataclass
class Colocated(Deployment):
    """Store sharded exactly like the producer output (on-node DB analogue).

    ``elem_spec`` is the PartitionSpec of one stored element; it must match
    the sharding the producer emits so that put/get are collective-free.
    ``capacity_axis`` optionally shards the slot axis too (spreading the
    ring across an unused mesh axis — beyond-paper, trades capacity for
    per-chip HBM).
    """

    mesh: Mesh
    elem_spec: P = P()
    capacity_axis: str | None = None

    fan_in: int = 1
    crosses_mesh: bool = False
    faults: FaultPlan | None = None

    def slab_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.mesh, P(self.capacity_axis, *self.elem_spec))

    def elem_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.elem_spec)

    def stage(self, x, spec: TableSpec | None = None):
        # Producer output is already placed correctly: zero-copy.  We do not
        # device_put here on purpose — a sharding mismatch should surface as
        # a collective in the compiled put (tests assert it does not).
        return x

    def stage_batch(self, values, spec: TableSpec | None = None):
        return values

    def stage_chunk(self, keys, values, mask, spec: TableSpec | None = None):
        return keys, values, mask

    def stage_to_clients(self, x):
        return x

    def describe(self) -> str:
        return (f"colocated(mesh={tuple(self.mesh.shape.items())}, "
                f"elem_spec={self.elem_spec})")


def _fit_spec(parts: Sequence, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop mesh axes that do not divide their dim (device_put targets
    must divide exactly; GSPMD padding only applies to intermediates).
    An elem_spec LONGER than the element rank is a misconfiguration, not
    a fitting problem — keep it loud instead of silently truncating."""
    parts = tuple(parts)
    if len(parts) > len(shape):
        raise ValueError(
            f"elem_spec {parts} has more entries than the element rank "
            f"{len(shape)} (shape {tuple(shape)})")
    fitted = []
    for dim, entry in zip(shape, parts + (None,) * (len(shape) -
                                                    len(parts))):
        if entry is not None:
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            n = int(np.prod([mesh.shape[a] for a in axes]))
            if dim % n != 0:
                entry = None
        fitted.append(entry)
    return P(*fitted)


@dataclass
class Clustered(Deployment):
    """Store on dedicated devices; every transfer crosses the interconnect.

    ``elem_spec`` lays one element out across the db mesh; it is *fitted*
    per table — axes that do not divide the element dims fall back to
    replicated instead of silently mis-placing (``elem_sharding(spec)``).
    ``slab_axis`` names a db-mesh axis to partition the slot axis over:
    the slab-sharded clustered data plane (``capacity/D`` slots per db
    shard; falls back to an unpartitioned slab when capacity does not
    divide).  ``overlap`` enables the two-slot staging pipeline on the
    fused put path: chunk N's cross-mesh reshard rides the async dispatch
    queue while chunk N+1's collect-scan runs, and the masked insert of
    chunk N happens one capture later (drained explicitly at capture end
    and on fault-injected restage).
    """

    client_mesh: Mesh
    db_mesh: Mesh
    elem_spec: P = P()          # layout of an element across the db mesh
    slab_axis: str | None = None  # slot-partition the slab over this axis
    overlap: bool = True        # double-buffer the fused staging hop
    #: a fitted ``insitu.plan.ContentionModel`` (kept untyped — core must
    #: not import the plan layer).  When set, the session's plan autotunes
    #: the fused chunk from it and predicts producer steps/s per entry.
    cost_model: object | None = None

    crosses_mesh: bool = True
    faults: FaultPlan | None = None

    def __post_init__(self):
        n_clients = int(np.prod(list(self.client_mesh.shape.values())))
        n_db = int(np.prod(list(self.db_mesh.shape.values())))
        self.fan_in = fan_in_ratio(n_clients, n_db)
        if self.slab_axis is not None:
            used = {a for entry in self.elem_spec if entry is not None
                    for a in ((entry,) if isinstance(entry, str)
                              else entry)}
            if self.slab_axis in used:
                raise ValueError(
                    f"slab_axis {self.slab_axis!r} also appears in "
                    f"elem_spec {self.elem_spec}: a slot-partitioned "
                    f"slab keeps each element whole on its owning shard "
                    f"— use disjoint mesh axes")

    def _elem_spec_for(self, spec: TableSpec | None) -> P:
        if spec is None:
            return self.elem_spec
        return _fit_spec(self.elem_spec, spec.shape, self.db_mesh)

    def slab_shards(self, spec: TableSpec) -> int:
        """How many slot partitions the slab actually splits into (1 when
        ``slab_axis`` is unset or capacity does not divide)."""
        if self.slab_axis is None:
            return 1
        d = int(self.db_mesh.shape[self.slab_axis])
        return d if spec.capacity % d == 0 else 1

    def gather_shards(self, spec: TableSpec) -> int:
        """Shard count usable by the shard-local staged gather
        (``store.make_clustered_gather``): the slot-partition factor,
        but ONLY when the element dims are replicated on the db mesh —
        the sharded gather assumes local ``[capacity/D, *shape]`` rows.
        An element-sharded slab falls back to the plain gather (GSPMD
        handles any layout) rather than silently resharding the slab.
        This is THE rule both the server's runtime gather and the plan's
        ``plan(hlo=True)`` compile consult — keep it single-sourced."""
        if any(e is not None for e in self._elem_spec_for(spec)):
            return 1
        return self.slab_shards(spec)

    def slab_sharding(self, spec: TableSpec) -> NamedSharding:
        cap_axis = self.slab_axis if self.slab_shards(spec) > 1 else None
        return NamedSharding(self.db_mesh,
                             P(cap_axis, *self._elem_spec_for(spec)))

    def elem_sharding(self, spec: TableSpec) -> NamedSharding:
        return NamedSharding(self.db_mesh, self._elem_spec_for(spec))

    def stage(self, x, spec: TableSpec | None = None):
        """The cross-network hop: reshard from client mesh onto the db
        mesh, honoring the table's fitted element layout."""
        return jax.device_put(x, self.elem_sharding(spec))

    def stage_batch(self, values, spec: TableSpec | None = None):
        values = jnp.asarray(values)
        es = self._elem_spec_for(spec)
        # however many leading batch dims ride ahead of the element dims
        # (put_many sends [n, *shape]; put_stream may send [T, R, *shape]).
        # Without a spec the element rank is unknown — assume the
        # documented one-batch-dim contract rather than guessing from
        # elem_spec's length (which may be shorter than the element rank).
        lead = max(1, values.ndim - len(spec.shape)) if spec is not None \
            else 1
        sh = NamedSharding(self.db_mesh, P(*([None] * lead), *es))
        return jax.device_put(values, sh)

    def stage_chunk(self, keys, values, mask, spec: TableSpec | None = None,
                    donate: bool = False):
        """ONE batched cross-mesh reshard for a whole fused-capture chunk:
        the stacked values ride with their keys and emit mask in a single
        ``jax.device_put`` — this is the clustered fused put's only
        interconnect hop per dispatch.  ``device_put`` dispatches async;
        the transfer overlaps whatever the host enqueues next.
        ``donate=True`` (the overlap pipeline) releases the client-side
        collect buffers to the transfer — they are never read again (a
        fault-injected restage re-collects from the original carry)."""
        meta = NamedSharding(self.db_mesh, P())
        vsh = NamedSharding(self.db_mesh, P(None, *self._elem_spec_for(spec)))
        if donate and _DEVICE_PUT_DONATE:
            return jax.device_put((keys, values, mask), (meta, vsh, meta),
                                  donate=True)
        return jax.device_put((keys, values, mask), (meta, vsh, meta))

    def stage_to_clients(self, x):
        """The read-side hop: a gathered batch (any pytree) leaves the db
        mesh for the consumers (replicated over the client mesh) in one
        batched ``device_put`` call."""
        sh = NamedSharding(self.client_mesh, P())
        return jax.device_put(x, jax.tree.map(lambda _: sh, x))

    def describe(self) -> str:
        return (f"clustered(clients={tuple(self.client_mesh.shape.items())}, "
                f"db={tuple(self.db_mesh.shape.items())}, "
                f"fan_in={self.fan_in}"
                + (", overlap" if self.overlap else "")
                + (f", slab_axis={self.slab_axis!r}"
                   if self.slab_axis else "") + ")")


class StagingPipeline:
    """Two-slot staging pipeline for the overlapped clustered put path.

    Slot A (held here) is the *in-flight* chunk: its cross-mesh
    ``stage_chunk`` transfer has been dispatched but its masked insert
    has not.  Slot B is the chunk currently being collected on the
    client mesh — it lives in the caller's hands until its own stage
    dispatch, at which point ``swap`` retires slot A for insertion and
    the freshly staged chunk becomes the new in-flight slot.  ``drain``
    empties slot A without refilling it (capture end, or the
    drain-on-restage flush after a fault-injected ``TransferDropped``).
    Insert order is therefore exactly the collect order — the ring's
    last-writer-wins semantics cannot observe the pipelining.
    """

    __slots__ = ("_in_flight",)

    def __init__(self):
        self._in_flight = None

    @property
    def pending(self) -> bool:
        return self._in_flight is not None

    def swap(self, staged):
        """Retire the in-flight slot (returning it for insertion, or
        ``None`` on the first chunk) and park ``staged`` in its place."""
        prev = self._in_flight
        self._in_flight = staged
        return prev

    def drain(self):
        """Empty the in-flight slot without refilling it."""
        prev = self._in_flight
        self._in_flight = None
        return prev


def make_colocated_1d(axis: str = "data", mesh: Mesh | None = None,
                      shard_dim: int = 0, ndim: int = 1,
                      faults: FaultPlan | None = None) -> Colocated:
    """Convenience: co-located deployment sharding element dim 0 over `axis`."""
    if mesh is None:
        mesh = jax.make_mesh((len(jax.devices()),), (axis,))
    spec = [None] * ndim
    spec[shard_dim] = axis
    return Colocated(mesh=mesh, elem_spec=P(*spec), faults=faults)


def make_clustered_1d(db_fraction: float = 0.25, axis: str = "data",
                      devices=None, elem_spec: P = P(),
                      slab_axis: str | None = None, overlap: bool = True,
                      faults: FaultPlan | None = None) -> Clustered:
    """Convenience: split the visible devices into client/db 1-D meshes
    (``split_devices``) and build the ``Clustered`` deployment over them.
    ``overlap=False`` restores the serial stage-then-insert put path
    (the pre-pipeline baseline the parity tests and benches compare
    against)."""
    client_devs, db_devs = split_devices(devices, db_fraction)
    return Clustered(
        client_mesh=Mesh(np.asarray(client_devs), (axis,)),
        db_mesh=Mesh(np.asarray(db_devs), (axis,)),
        elem_spec=elem_spec, slab_axis=slab_axis, overlap=overlap,
        faults=faults)


def make_clustered_2d(elem_spec: P, db_fraction: float = 0.5,
                      slab_axis: str = "slab", elem_axis: str = "space",
                      client_axis: str = "space", devices=None,
                      slab_shards: int | None = None, overlap: bool = True,
                      faults: FaultPlan | None = None) -> Clustered:
    """Clustered deployment over a 2-D **(slab, element)** db mesh.

    ``Clustered`` requires the slot partition and the element partition to
    live on *disjoint mesh axes* — on a 1-D db mesh that forces a choice
    between them.  This factory lifts that to both-at-once by reshaping
    the db devices into a ``(slab_shards, elem_shards)`` grid: the slot
    axis partitions over ``slab_axis`` (rows), each stored element lays
    out over ``elem_axis`` (columns) with ``elem_spec``, so a
    domain-decomposed producer's shard-local put stays shard-local *and*
    the slab still scales with capacity.  The client mesh is 1-D over
    ``client_axis`` — name it after the producer's mesh axis (default
    ``"space"``) so one ``elem_spec`` reads the same on both sides.

    ``slab_shards=None`` picks the largest split ≤ 2 that divides the db
    device count (1 when the pool is odd or a single device).
    """
    used = {a for entry in elem_spec if entry is not None
            for a in ((entry,) if isinstance(entry, str) else entry)}
    if slab_axis in used:
        raise ValueError(
            f"slab_axis {slab_axis!r} also appears in elem_spec "
            f"{elem_spec}: the 2-D db mesh gives the slot and element "
            f"partitions their own axes — put the element layout on "
            f"{elem_axis!r}")
    client_devs, db_devs = split_devices(devices, db_fraction)
    n_db = len(db_devs)
    if slab_shards is None:
        slab_shards = 2 if n_db % 2 == 0 and n_db >= 2 else 1
    if slab_shards < 1 or n_db % slab_shards != 0:
        raise ValueError(
            f"slab_shards={slab_shards} does not divide the {n_db}-device "
            f"db pool: the (slab, element) grid needs equal rows")
    db_grid = np.asarray(db_devs).reshape(slab_shards, n_db // slab_shards)
    return Clustered(
        client_mesh=Mesh(np.asarray(client_devs), (client_axis,)),
        db_mesh=Mesh(db_grid, (slab_axis, elem_axis)),
        elem_spec=elem_spec, slab_axis=slab_axis, overlap=overlap,
        faults=faults)
