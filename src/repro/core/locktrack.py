"""Runtime lock-order witness for :class:`~repro.core.server.StoreServer`.

The static rules in ``tools/lint/rules_locks.py`` prove lock discipline
*lexically*; this module proves it *dynamically*: every lock the server
owns is wrapped in a tracking proxy, each acquisition records
``held -> acquired`` edges into a process-wide lock-order graph, and
:meth:`LockTracker.assert_acyclic` fails with the offending cycle if two
code paths ever disagree on ordering.  The chaos suite runs under
:meth:`LockTracker.instrument` (see ``tests/conftest.py``), so the graph
is built from the most hostile schedules the repo can produce —
concurrent producers, trainers, serving drains, injected restarts.

The expected (acyclic) graph, for reference::

    server._lock ──────────┐
    table:<a> ── table:<b> ─┴──▶ server._ops_lock      (leaf)

with ``table:<a> -> table:<b>`` only ever in sorted-name order (the
canonical two-lock acquisition in ``serve_batch``).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Iterator

__all__ = ["LockTracker", "LockCycleError"]


class LockCycleError(AssertionError):
    """The witnessed lock-order graph contains a cycle (deadlock hazard)."""


class _TrackedLock:
    """Proxy around a ``threading`` lock that reports acquire/release.

    Everything not intercepted — notably the private
    ``_is_owned``/``_release_save``/``_acquire_restore`` hooks
    :class:`threading.Condition` looks up — is delegated to the raw
    lock, so a Condition built on a tracked lock behaves identically.
    """

    def __init__(self, tracker: "LockTracker", raw, name: str):
        self._tracker = tracker
        self._raw = raw
        self._name = name

    def acquire(self, *args, **kwargs) -> bool:
        got = self._raw.acquire(*args, **kwargs)
        if got:
            self._tracker._note_acquire(self._name)
        return got

    def release(self) -> None:
        self._tracker._note_release(self._name)
        self._raw.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def __getattr__(self, attr):
        return getattr(self._raw, attr)

    def __repr__(self) -> str:
        return f"<TrackedLock {self._name} of {self._raw!r}>"


class LockTracker:
    """Collects the realised lock-order graph across all threads."""

    def __init__(self):
        self._mu = threading.Lock()
        self._edges: dict[str, set[str]] = {}
        self._names: set[str] = set()
        self._held = threading.local()

    # -- wrapping ------------------------------------------------------------

    def wrap(self, raw, name: str) -> _TrackedLock:
        with self._mu:
            self._names.add(name)
        return _TrackedLock(self, raw, name)

    def attach(self, server) -> None:
        """Wrap every lock a live ``StoreServer`` owns (and any table
        lock it creates later)."""
        server._lock = self.wrap(server._lock, "server._lock")
        server._ops_lock = self.wrap(server._ops_lock, "server._ops_lock")
        # Rebuild the metadata Condition on the tracked registry lock so
        # wait/notify keep going through one (witnessed) mutex.
        server._meta_event = threading.Condition(server._lock)
        for t, lk in list(server._table_locks.items()):
            server._table_locks[t] = self.wrap(lk, f"table:{t}")

        orig_create = server.create_table

        def create_table(spec, *args, **kwargs):
            out = orig_create(spec, *args, **kwargs)
            raw = server._table_locks[spec.name]
            if not isinstance(raw, _TrackedLock):
                server._table_locks[spec.name] = \
                    self.wrap(raw, f"table:{spec.name}")
            return out

        server.create_table = create_table

    @classmethod
    @contextlib.contextmanager
    def instrument(cls) -> Iterator["LockTracker"]:
        """Patch ``StoreServer.__init__`` so every server constructed in
        the ``with`` block is attached to one shared tracker — how the
        chaos suite wires the witness in without touching call sites."""
        from .server import StoreServer
        tracker = cls()
        orig_init = StoreServer.__init__

        def init(self, *args, **kwargs):
            orig_init(self, *args, **kwargs)
            tracker.attach(self)

        StoreServer.__init__ = init  # type: ignore[method-assign]
        try:
            yield tracker
        finally:
            StoreServer.__init__ = orig_init  # type: ignore[method-assign]

    # -- recording -----------------------------------------------------------

    def _stack(self) -> list[str]:
        stack = getattr(self._held, "stack", None)
        if stack is None:
            stack = self._held.stack = []
        return stack

    def _note_acquire(self, name: str) -> None:
        stack = self._stack()
        with self._mu:
            for held in stack:
                if held != name:
                    self._edges.setdefault(held, set()).add(name)
        stack.append(name)

    def _note_release(self, name: str) -> None:
        stack = self._stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    def note_acquire(self, name: str) -> None:
        """Public recording hook (tests build synthetic graphs with it)."""
        with self._mu:
            self._names.add(name)
        self._note_acquire(name)

    def note_release(self, name: str) -> None:
        self._note_release(name)

    # -- the graph -----------------------------------------------------------

    def edges(self) -> dict[str, tuple[str, ...]]:
        with self._mu:
            return {k: tuple(sorted(v)) for k, v in self._edges.items()}

    def find_cycle(self) -> list[str] | None:
        """A witnessed cycle as ``[a, b, ..., a]``, or None."""
        edges = self.edges()
        WHITE, GRAY, BLACK = 0, 1, 2
        color = {n: WHITE for n in
                 set(edges) | {d for v in edges.values() for d in v}}
        path: list[str] = []

        def dfs(node: str) -> list[str] | None:
            color[node] = GRAY
            path.append(node)
            for nxt in edges.get(node, ()):
                if color[nxt] == GRAY:
                    return path[path.index(nxt):] + [nxt]
                if color[nxt] == WHITE:
                    cyc = dfs(nxt)
                    if cyc is not None:
                        return cyc
            path.pop()
            color[node] = BLACK
            return None

        for node in sorted(color):
            if color[node] == WHITE:
                cyc = dfs(node)
                if cyc is not None:
                    return cyc
        return None

    def assert_acyclic(self) -> None:
        cyc = self.find_cycle()
        if cyc is not None:
            raise LockCycleError(
                "lock-order cycle witnessed: " + " -> ".join(cyc)
                + f" (full graph: {self.edges()})")
