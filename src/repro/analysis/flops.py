"""Analytic FLOP / byte model for the roofline (per arch × shape × step).

Two FLOP numbers per cell:

* ``MODEL_FLOPS`` — the assignment's useful-work definition: 6·N·D for
  training (N = params, D = tokens; N_active for MoE) and 2·N·D for
  inference steps.
* ``machine_flops`` — what the compiled program actually executes,
  term-by-term from the model math: projections, attention (including the
  documented 2× slack of the dense-causal-mask fallback), MoE capacity
  slack (×capacity_factor), SSD chunk matmuls, CE logits, plus backward
  (2×fwd) and remat recompute (+1×fwd) for training.

XLA's ``cost_analysis`` undercounts ``lax.scan`` bodies (trip count not
multiplied); the dry-run reports HLO numbers with a layer-scan correction
as a cross-check, but roofline terms use this analytic model (documented in
EXPERIMENTS.md §Methodology).

Byte model (per step, global):
* ``param_bytes`` — every live parameter read once (weights stream HBM→MXU);
* ``cache_bytes`` — decode: KV/state cache read (+written once at pos);
* ``act_bytes`` — activation traffic estimate: 2·(bytes of layer I/O)·layers.
"""

from __future__ import annotations

from dataclasses import dataclass

from ..configs.registry import ShapeSpec
from ..models.config import ModelConfig

__all__ = ["FlopReport", "analyze", "hbm_occupancy"]


def hbm_occupancy(cfg: ModelConfig, shape: ShapeSpec, chips: int) -> dict:
    """Analytic per-chip HBM residency (bytes) — the honest "does it fit"
    estimate (the CPU backend's memory_analysis doesn't model 16 GiB HBM).

    train: params + grads (model dtype) + optimizer state (Adam: 8 B/param
    fp32 moments; Adafactor: factored vectors ≈ 2·P/min(dims)) all ZeRO-
    sharded over every chip, plus remat-saved block inputs (one [tokens_loc,
    D] per layer) and the transient CE chunk.
    decode: params (per the serving sharding) + KV/state caches + logits.
    """
    import jax.numpy as jnp
    dtb = jnp.dtype(cfg.dtype).itemsize
    P = cfg.param_count()
    out: dict[str, float] = {}
    dp = 32 if chips == 512 else 16
    state_ways = 256                  # data(16) x model(16); pod replicates
    if shape.kind == "train":
        if cfg.optimizer == "adafactor":
            opt = 0.02 * P * 4            # factored row/col vectors
        else:
            opt = 8.0 * P                 # fp32 mu+nu
        out["state"] = (P * dtb * 2 + opt) / state_ways  # p+g+opt
        accum = max(1, cfg.grad_accum)
        tokens_loc = shape.global_batch * shape.seq_len // dp // accum
        n_layers = cfg.n_layers + cfg.encoder_layers
        out["saved_acts"] = tokens_loc * cfg.d_model * dtb * n_layers
        out["grad_accum_buf"] = (cfg.param_count() * 4 / state_ways) \
            if accum > 1 else 0.0
        ce_rows = tokens_loc * (cfg.ce_chunk or shape.seq_len) \
            / shape.seq_len
        out["ce_chunk"] = ce_rows * cfg.vocab * 4
    elif shape.kind == "prefill":
        out["state"] = P * dtb / state_ways
        tokens_loc = shape.global_batch * shape.seq_len // dp
        out["acts"] = 4 * tokens_loc * cfg.d_model * dtb
        n_attn = sum(1 for m, _ in cfg.pattern if m == "attn") \
            * cfg.n_periods
        out["kv_cache"] = n_attn * 2 * tokens_loc * cfg.n_kv_heads \
            * cfg.head_dim * dtb / 16       # kv-head dim model-sharded
    else:
        if cfg.serve_replicate_params:
            out["state"] = P * dtb / 16          # model shard only
        else:
            out["state"] = P * dtb / state_ways
        n_attn = sum(1 for m, _ in cfg.pattern if m == "attn") \
            * cfg.n_periods
        kv_el = (1 + 4.0 / cfg.head_dim) if cfg.kv_cache_quant else dtb
        out["kv_cache"] = n_attn * 2 * shape.global_batch * shape.seq_len \
            * cfg.n_kv_heads * cfg.head_dim * kv_el / chips
        n_mamba = sum(1 for m, _ in cfg.pattern if m == "mamba") \
            * cfg.n_periods
        out["ssm_state"] = n_mamba * shape.global_batch * cfg.ssm_heads \
            * cfg.ssm_headdim * cfg.ssm_state * 4 / chips
    out["total"] = sum(out.values())
    return out


@dataclass
class FlopReport:
    model_flops: float           # assignment "useful" FLOPs
    machine_flops: float         # executed FLOPs (global)
    param_bytes: float           # live parameter bytes (global)
    cache_bytes: float           # KV/state cache bytes touched (global)
    act_bytes: float             # activation HBM traffic estimate (global)
    breakdown: dict

    @property
    def hbm_bytes(self) -> float:
        return self.param_bytes + self.cache_bytes + self.act_bytes


def _attn_proj_flops(cfg, tokens):
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return 2 * tokens * (D * H * dh + 2 * D * K * dh + H * dh * D)


def _eff_heads(cfg, tp: int = 16) -> int:
    """Executed head count: TP padding (§Perf H1.2) costs extra heads."""
    H, K = cfg.n_heads, cfg.n_kv_heads
    if not cfg.pad_heads or H % tp == 0:
        return H
    G = H // K
    if G == 1:
        return H + (-H) % tp
    gp = G
    while (K * gp) % tp:
        gp += 1
    return K * gp


def _attn_score_flops(cfg, tokens, kv_len, causal: bool = True):
    # scores + PV.  The chunked-jnp fallback computes the full rectangle
    # and masks (2x causal slack); the Pallas flash kernel (attn_impl=
    # "flash") skips above-diagonal blocks, recovering the 2x.
    H, dh = _eff_heads(cfg), cfg.head_dim
    factor = 0.5 if (causal and cfg.attn_impl.startswith("flash")) else 1.0
    return 2 * tokens * kv_len * H * dh * 2 * factor


def _mlp_flops(cfg, tokens):
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    return 2 * tokens * cfg.d_model * cfg.d_ff * mult


def _moe_flops(cfg, tokens):
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    Fm = cfg.d_ff_moe or cfg.d_ff
    router = 2 * tokens * cfg.d_model * cfg.n_experts
    # capacity buffers are sized S·k·cf/E per expert and fully multiplied
    experts = 2 * tokens * cfg.top_k * cfg.capacity_factor \
        * cfg.d_model * Fm * mult
    shared = _shared_flops(cfg, tokens)
    return router + experts + shared


def _shared_flops(cfg, tokens):
    if not cfg.shared_expert:
        return 0.0
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    Fm = cfg.d_ff_moe or cfg.d_ff
    return 2 * tokens * cfg.d_model * Fm * mult


def _ssd_flops(cfg, tokens):
    D, di = cfg.d_model, cfg.ssm_inner
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    Q = cfg.ssm_chunk
    proj = 2 * tokens * D * (2 * di + 2 * N + H) + 2 * tokens * di * D
    conv = 2 * tokens * (di + 2 * N) * cfg.ssm_conv
    # per chunk: CBᵀ 2Q²N ; (scores∘W)·X 2Q²HP ; inter 2QNHP ; state 2QNHP
    chunks = max(1, tokens // Q)
    scan = chunks * (2 * Q * Q * N + 2 * Q * Q * H * P + 4 * Q * N * H * P)
    return proj + conv + scan


def _ssd_decode_flops(cfg, batch):
    D, di = cfg.d_model, cfg.ssm_inner
    N, H, P = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    proj = 2 * batch * D * (2 * di + 2 * N + H) + 2 * batch * di * D
    state = 2 * batch * H * P * N * 2
    return proj + state


def _layer_counts(cfg):
    attn = sum(1 for m, _ in cfg.pattern if m == "attn") * cfg.n_periods
    mamba = sum(1 for m, _ in cfg.pattern if m == "mamba") * cfg.n_periods
    mlp = sum(1 for _, f in cfg.pattern if f == "mlp") * cfg.n_periods
    moe = sum(1 for _, f in cfg.pattern if f == "moe") * cfg.n_periods
    return attn, mamba, mlp, moe


def _dtype_bytes(cfg):
    import jax.numpy as jnp
    return jnp.dtype(cfg.dtype).itemsize


def _fwd_flops(cfg, tokens, kv_len):
    n_attn, n_mamba, n_mlp, n_moe = _layer_counts(cfg)
    fl = {}
    fl["attn_proj"] = n_attn * _attn_proj_flops(cfg, tokens)
    fl["attn_score"] = n_attn * _attn_score_flops(cfg, tokens, kv_len)
    fl["mlp"] = n_mlp * _mlp_flops(cfg, tokens)
    fl["moe"] = n_moe * _moe_flops(cfg, tokens)
    fl["ssd"] = n_mamba * _ssd_flops(cfg, tokens)
    fl["logits"] = 2 * tokens * cfg.d_model * cfg.vocab
    return fl


def analyze(cfg: ModelConfig, shape: ShapeSpec) -> FlopReport:
    B, S = shape.global_batch, shape.seq_len
    pbytes = cfg.param_count() * _dtype_bytes(cfg)
    dtb = _dtype_bytes(cfg)
    n_attn, n_mamba, n_mlp, n_moe = _layer_counts(cfg)
    n_layers_total = cfg.n_layers + cfg.encoder_layers

    if shape.kind == "train":
        tokens = B * S
        fl = _fwd_flops(cfg, tokens, kv_len=S)
        if cfg.is_encdec:
            enc_tok = B * cfg.encoder_ctx
            fl["encoder"] = cfg.encoder_layers * (
                _attn_proj_flops(cfg, enc_tok)
                + _attn_score_flops(cfg, enc_tok, cfg.encoder_ctx, causal=False)
                + _mlp_flops(cfg, enc_tok))
            fl["cross"] = cfg.n_layers * (
                _attn_proj_flops(cfg, tokens)
                + _attn_score_flops(cfg, tokens, cfg.encoder_ctx, causal=False))
        fwd = sum(fl.values())
        # bwd = 2×fwd; full remat recompute ≈ +1×fwd; "dots" policy saves
        # matmul outputs so recompute is elementwise-only (≈ +0.1×fwd)
        if cfg.remat and cfg.remat_policy == "dots":
            machine = fwd * 3.1
        elif cfg.remat:
            machine = fwd * 4.0
        else:
            machine = fwd * 3.0
        model = 6.0 * cfg.active_param_count() * tokens
        act = 2 * tokens * cfg.d_model * dtb * n_layers_total * 4
        return FlopReport(model, machine, pbytes * 3,  # p + grad + opt read
                          0.0, act, fl)

    if shape.kind == "prefill":
        tokens = B * S
        fl = _fwd_flops(cfg, tokens, kv_len=S)
        fl["logits"] = 2 * B * cfg.d_model * cfg.vocab   # last position only
        if cfg.is_encdec:
            enc_tok = B * cfg.encoder_ctx
            fl["encoder"] = cfg.encoder_layers * (
                _attn_proj_flops(cfg, enc_tok)
                + _attn_score_flops(cfg, enc_tok, cfg.encoder_ctx, causal=False)
                + _mlp_flops(cfg, enc_tok))
            fl["cross"] = cfg.n_layers * (
                _attn_proj_flops(cfg, tokens)
                + _attn_score_flops(cfg, tokens, cfg.encoder_ctx, causal=False))
        machine = sum(fl.values())
        model = 2.0 * cfg.active_param_count() * tokens
        kv_write = n_attn * 2 * B * S * cfg.n_kv_heads * cfg.head_dim * dtb
        act = 2 * tokens * cfg.d_model * dtb * n_layers_total * 2
        return FlopReport(model, machine, pbytes, kv_write, act, fl)

    # decode: one token per sequence
    tokens = B
    fl = {}
    fl["attn_proj"] = n_attn * _attn_proj_flops(cfg, tokens)
    fl["attn_score"] = n_attn * 2 * tokens * S * cfg.n_heads * cfg.head_dim * 2
    fl["mlp"] = n_mlp * _mlp_flops(cfg, tokens)
    fl["moe"] = n_moe * _moe_flops(cfg, tokens)
    fl["ssd"] = n_mamba * _ssd_decode_flops(cfg, B)
    fl["logits"] = 2 * tokens * cfg.d_model * cfg.vocab
    if cfg.is_encdec:
        fl["cross"] = cfg.n_layers * (
            _attn_proj_flops(cfg, tokens)
            + 2 * tokens * cfg.encoder_ctx * cfg.n_heads * cfg.head_dim * 2)
    machine = sum(fl.values())
    model = 2.0 * cfg.active_param_count() * tokens
    kv_elem_bytes = dtb
    if cfg.kv_cache_quant:
        # int8 payload + f32 scale per (token, kv-head)
        kv_elem_bytes = 1 + 4.0 / cfg.head_dim
    kv = n_attn * 2 * B * S * cfg.n_kv_heads * cfg.head_dim * kv_elem_bytes
    mamba_state = n_mamba * B * (
        cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
        + (cfg.ssm_inner + 2 * cfg.ssm_state) * (cfg.ssm_conv - 1) * dtb)
    if cfg.is_encdec:
        kv += cfg.n_layers * 2 * B * cfg.encoder_ctx \
            * cfg.n_kv_heads * cfg.head_dim * dtb
    act = 2 * tokens * cfg.d_model * dtb * n_layers_total * 2
    # MoE decode reads only the routed experts' weights
    if cfg.n_experts:
        Fm = cfg.d_ff_moe or cfg.d_ff
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        dense_bytes = (cfg.param_count() - cfg.active_param_count()) * dtb
        touched = min(B * cfg.top_k, cfg.n_experts)
        frac = touched / cfg.n_experts
        pbytes = pbytes - dense_bytes * (1 - frac)
    if cfg.serve_replicate_params:
        # weights-stationary serving: every data-parallel replica streams
        # its model-shard per step — global bytes = params × data degree
        pbytes = pbytes * 16.0
    return FlopReport(model, machine, pbytes, kv + mamba_state, act, fl)
