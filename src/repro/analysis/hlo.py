"""HLO inspection: collective-byte accounting from compiled modules.

``collective_bytes(text)`` scans post-SPMD optimized HLO for
``all-reduce`` / ``all-gather`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` ops and sums their result-shape bytes (the paper's
interconnect-traffic analogue; cost_analysis does not expose this).

Caveat handled by the caller: ``lax.scan`` bodies appear once in HLO
(while-loop trip counts are not multiplied), so the dry-run compiles a
1-period and a 2-period variant of each model and extrapolates
``total = f(1) + (periods-1)·(f(2) - f(1))``.
"""

from __future__ import annotations

import re
from collections import defaultdict

__all__ = ["collective_bytes", "parse_shape_bytes", "count_ops",
           "assert_collective_free", "COLLECTIVE_OPS"]

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def parse_shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string like 'bf16[8,128]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# e.g. ``%all-reduce.5 = bf16[4096]{0} all-reduce(...)``
# or  ``ROOT %r = (bf16[2,4]{...}, f32[8]{...}) all-gather(...)``
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],]+(?:\{[^}]*\})?)\s+(" +
    "|".join(COLLECTIVE_OPS) + r")[\.( ]")


#: approximate per-device link traffic per result byte (ring algorithms):
#: all-gather receives ~result bytes; all-reduce = reduce-scatter+all-gather
#: ≈ 2×; permute/all-to-all move ~result bytes.
_LINK_WEIGHT = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-kind result bytes over a (post-SPMD, i.e. per-device)
    HLO module.  ``total`` sums raw result bytes; ``link_bytes`` applies the
    ring-algorithm traffic weights above — the per-device ICI traffic
    estimate the roofline's collective term uses."""
    out: dict[str, float] = defaultdict(float)
    link = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        b = parse_shape_bytes(type_str)
        out[op] += b
        link += b * _LINK_WEIGHT[op]
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["link_bytes"] = link
    return {k: int(v) for k, v in out.items()}


def count_ops(hlo_text: str, op_names=COLLECTIVE_OPS) -> dict[str, int]:
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m:
            counts[m.group(2)] += 1
    return dict(counts)


def assert_collective_free(hlo_text: str, what: str = "computation") -> None:
    """Assert a compiled (post-SPMD) HLO module contains NO collective ops.

    This is the structural form of the paper's "all data transfer is
    contained within each node": a co-located store put — per-verb or the
    fused ``capture_scan`` path — must lower to pure local
    dynamic-update-slices, so any ``all-reduce``/``all-gather``/… in its
    optimized HLO is a deployment-alignment regression.  Raises
    ``AssertionError`` naming the offending ops with their byte counts
    (from :func:`collective_bytes`); the roofline check and the tier-1
    zero-collective tests both route through this.
    """
    counts = count_ops(hlo_text)
    if counts:
        raise AssertionError(
            f"{what} contains collectives: {counts} "
            f"(bytes: {collective_bytes(hlo_text)})")
