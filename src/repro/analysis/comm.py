"""Analytic per-device collective-traffic model (ICI bytes per step).

Why analytic: the CPU-target compile lowers bf16 dots through f32, so the
partitioned HLO's collective operands show f32 (a 2× overstatement vs the
TPU target), and `lax.scan`-free extrapolation can't see dtype intent.
Like the FLOP/HBM terms, the roofline's collective term therefore comes
from this explicit model of the sharding strategy; the compiled HLO remains
the structural cross-check (which collectives exist, upper-bound bytes).

Per-device ICI bytes per step (ring-algorithm traffic ≈ payload bytes):

train:
  * ZeRO/FSDP param all-gathers over `data`: each device receives its
    model-shard of every gathered param, twice (forward + backward
    recompute): 2 · P/model_deg
  * gradient reduce-scatter over `data` (1 · P/model_deg) and, multi-pod,
    grad all-reduce over `pod` (2 · P/(model·data))
  * TP activation all-reduces: per layer, 1 AR per TP-contracted matmul
    output ([B_loc, S, D]), ×(fwd + bwd + remat) = 3
  * MoE EP all-to-all: tokens_loc · top_k · D, both directions,
    ×(fwd + bwd + remat)
prefill: the forward slice of the above (1× gathers, 1× ARs, 1× A2A).
decode:  param gathers once per token step (0 when
  ``serve_replicate_params``), tiny TP ARs on [B_loc,1,D], EP A2A on the
  decoded tokens; long-context SP adds the LSE-merge reductions (≈ B·H·dh).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp

from ..configs.registry import ShapeSpec
from ..models.config import ModelConfig

__all__ = ["CommReport", "collective_model"]


@dataclass
class CommReport:
    per_device_bytes: float
    breakdown: dict

    def as_dict(self):
        return {"per_device_bytes": self.per_device_bytes,
                "breakdown": self.breakdown}


def _degrees(mesh_kind: str):
    if mesh_kind == "multi":
        return {"pod": 2, "data": 16, "model": 16, "chips": 512}
    return {"pod": 1, "data": 16, "model": 16, "chips": 256}


def collective_model(cfg: ModelConfig, shape: ShapeSpec, mesh_kind: str,
                     rules: dict | None = None) -> CommReport:
    deg = _degrees(mesh_kind)
    dtb = jnp.dtype(cfg.dtype).itemsize
    B, S = shape.global_batch, shape.seq_len
    dp = deg["pod"] * deg["data"]          # batch sharding degree
    embed_fsdp = True
    moe_ep = cfg.moe_ep
    if rules is not None:
        embed_fsdp = rules.get("embed") is not None
        moe_ep = rules.get("expert") is not None
    if shape.kind == "decode" and cfg.serve_replicate_params:
        embed_fsdp = False

    P_dev_modelshard = cfg.param_count() * dtb / deg["model"]
    n_attn = sum(1 for m, _ in cfg.pattern if m == "attn") * cfg.n_periods
    n_mamba = sum(1 for m, _ in cfg.pattern if m == "mamba") * cfg.n_periods
    n_mlp = sum(1 for _, f in cfg.pattern if f == "mlp") * cfg.n_periods
    n_moe = sum(1 for _, f in cfg.pattern if f == "moe") * cfg.n_periods
    n_tp_ar = n_attn + n_mamba + n_mlp + n_moe   # 1 AR per mixer + 1 per ffn
    if cfg.is_encdec:
        n_tp_ar += 2 * cfg.encoder_layers + cfg.n_layers  # enc blocks+cross

    br: dict[str, float] = {}
    if shape.kind == "train":
        tokens_loc = B * S // dp
        act_ar = tokens_loc * cfg.d_model * dtb
        # fwd + bwd (+ remat recompute when the full policy recomputes
        # the TP matmuls; "dots" saves their outputs)
        passes = 3 if (cfg.remat and cfg.remat_policy != "dots") else 2
        accum = max(1, cfg.grad_accum)
        br["fsdp_gather"] = (2 * accum * P_dev_modelshard) if embed_fsdp \
            else 0.0
        br["grad_reduce"] = P_dev_modelshard if embed_fsdp else \
            2 * cfg.param_count() * dtb / deg["chips"]
        if deg["pod"] > 1:
            br["pod_grad_allreduce"] = 2 * cfg.param_count() * dtb \
                / (deg["model"] * deg["data"])
        br["tp_activation_ar"] = n_tp_ar * act_ar * passes
        if cfg.n_experts and moe_ep:
            br["ep_all_to_all"] = n_moe * 2 * tokens_loc * cfg.top_k \
                * cfg.d_model * dtb * passes
        return CommReport(sum(br.values()), br)

    if shape.kind == "prefill":
        tokens_loc = B * S // dp
        act_ar = tokens_loc * cfg.d_model * dtb
        br["fsdp_gather"] = P_dev_modelshard if embed_fsdp else 0.0
        br["tp_activation_ar"] = n_tp_ar * act_ar
        if cfg.n_experts and moe_ep:
            br["ep_all_to_all"] = n_moe * 2 * tokens_loc * cfg.top_k \
                * cfg.d_model * dtb
        return CommReport(sum(br.values()), br)

    # decode
    batch_replicated = (rules is not None and rules.get("batch") is None) \
        or cfg.serve_2d_tp
    b_loc = B if batch_replicated else max(1, B // dp)
    act_ar = b_loc * cfg.d_model * dtb
    if batch_replicated:
        # 2-D TP: weights stationary (contraction dim sharded over `data`)
        # — no gathers; every matmul ends in an activation AR instead,
        # counted once per matmul rather than once per block:
        br["fsdp_gather"] = 0.0
        matmuls_per_block = 4          # qkv+o / in+out+gates etc. ≈ 4
        br["tp_activation_ar"] = n_tp_ar * matmuls_per_block * act_ar
        # tokens are resident everywhere: EP dispatch is local masking
    else:
        br["fsdp_gather"] = P_dev_modelshard if embed_fsdp else 0.0
        br["tp_activation_ar"] = n_tp_ar * act_ar
        if cfg.n_experts and moe_ep:
            br["ep_all_to_all"] = n_moe * 2 * b_loc * cfg.top_k \
                * cfg.d_model * dtb
    if B == 1 and cfg.sub_quadratic:
        # sequence-parallel KV: per-attn-layer LSE merge of partial
        # attention (stats + weighted values) over the kv_length shards
        br["sp_lse_merge"] = n_attn * 2 * cfg.n_heads * cfg.head_dim * 4
    return CommReport(sum(br.values()), br)
