"""Roofline + HLO analysis for the dry-run."""

from . import flops, hlo, roofline

__all__ = ["flops", "hlo", "roofline"]
