"""Roofline analysis (TPU v5e targets) — the §Roofline deliverable.

For each compiled (arch × shape × mesh) cell, derive the three terms:

    compute term    = FLOPs            / (chips × 197e12 bf16 FLOP/s)
    memory term     = HBM bytes        / (chips × 819e9  B/s)
    collective term = collective bytes / (chips × links × 50e9 B/s)

FLOPs/bytes come from the analytic model (``analysis.flops``) — exact for
our model math — with the HLO cost_analysis numbers (layer-scan-corrected)
reported alongside as the compiled cross-check.  Collective bytes come
from the compiled HLO (scan-corrected; see ``analysis.hlo``).

The step time lower bound is max(terms) assuming perfect overlap;
``bound`` names the dominant term, ``roofline_fraction`` =
model-useful-time / max-term (how close useful work runs to the roof).
"""

from __future__ import annotations

from dataclasses import dataclass, field

from ..launch.mesh import HW

__all__ = ["RooflineTerms", "roofline"]


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # inputs (global)
    machine_flops: float
    model_flops: float
    hbm_bytes: float
    collective_bytes: float
    # derived (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bound: str = ""
    useful_ratio: float = 0.0        # MODEL_FLOPS / machine_flops
    roofline_fraction: float = 0.0   # useful-compute-time / max(terms)
    notes: str = ""
    extra: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in (
            "arch", "shape", "mesh", "chips", "machine_flops", "model_flops",
            "hbm_bytes", "collective_bytes", "t_compute", "t_memory",
            "t_collective", "bound", "useful_ratio", "roofline_fraction",
            "notes")} | {"extra": self.extra}


def roofline(arch: str, shape: str, mesh_name: str, chips: int,
             machine_flops: float, model_flops: float, hbm_bytes: float,
             collective_bytes: float, useful_bytes: float | None = None,
             notes: str = "", extra: dict | None = None) -> RooflineTerms:
    """``roofline_fraction`` scores against the *dominant* roof:

    * compute-bound: useful-FLOP time / max-term (MFU-style);
    * memory-bound: irreducible bytes (params + caches — ``useful_bytes``)
      / total HBM bytes — i.e. how much of the streamed traffic a perfect
      implementation would still have to move;
    * collective-bound: useful-FLOP time / max-term (comm is pure overhead).
    """
    peak = chips * HW["peak_flops_bf16"]
    bw = chips * HW["hbm_bytes_per_s"]
    # collective_bytes comes from the PARTITIONED module = per-device link
    # traffic; the roof is one chip's aggregate ICI bandwidth.
    ici = HW["ici_links"] * HW["ici_bytes_per_s_per_link"]
    t_c = machine_flops / peak
    t_m = hbm_bytes / bw
    t_x = collective_bytes / ici
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bound = max(terms, key=terms.get)
    t_max = max(terms.values())
    if bound == "memory" and useful_bytes is not None and hbm_bytes:
        frac = useful_bytes / hbm_bytes
    else:
        frac = (model_flops / peak / t_max) if t_max else 0.0
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        machine_flops=machine_flops, model_flops=model_flops,
        hbm_bytes=hbm_bytes, collective_bytes=collective_bytes,
        t_compute=t_c, t_memory=t_m, t_collective=t_x, bound=bound,
        useful_ratio=(model_flops / machine_flops) if machine_flops else 0.0,
        roofline_fraction=frac, notes=notes, extra=extra or {})
