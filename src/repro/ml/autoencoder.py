"""QuadConv autoencoder for flow-state compression (paper §4, Fig. 9).

Structure (paper Fig. 9, adapted hyper-parameters as the paper itself did):

  encoder:  B=2 blocks of [QuadConv → GELU → LayerNorm → 4× point pool]
            then flatten → linear → latent (dim 100)
  decoder:  linear → unflatten → B blocks of [4× point unpool → QuadConv →
            GELU → LayerNorm] → linear channel head back to 4 channels

* 16 internal data channels, five-layer filter MLPs mapping R³ → R^{16×16}
  (paper §4) — both via ``ml.quadconv``.
* Point sets: level-l coords are a stride-4ˡ subset of the level-0 grid
  (the paper pools on its structured-but-stretched grid the same way);
  pooling takes the max over each group of 4 consecutive points, unpooling
  broadcasts (paper: max-pool / un-pool).
* Latent 100 → the paper's headline "1700× spatial compression" ratio
  ``(C·N)/latent`` is reported by ``compression_factor``.
* Loss: MSE; validation metric: relative Frobenius reconstruction error
  (paper Eq. 1), in ``rel_frobenius``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from .quadconv import QuadConv, mlp_init, mlp_apply

__all__ = ["AEConfig", "init_autoencoder", "encode", "decode", "reconstruct",
           "loss_fn", "rel_frobenius", "coords_pyramid", "compression_factor"]


@dataclass(frozen=True)
class AEConfig:
    n_points: int               # level-0 point count (per rank partition)
    channels: int = 4           # (p, u, v, w)
    internal: int = 16          # paper: 16 internal data channels
    latent: int = 100           # paper: latent dimension 100
    blocks: int = 2             # paper: two blocks in encoder and decoder
    pool: int = 4               # point-pool factor per block
    mlp_width: int = 32
    mlp_depth: int = 5          # paper: five-layer filter MLPs
    support: float = 0.75
    mode: str | None = None     # quadconv kernel dispatch

    def level_points(self, level: int) -> int:
        return self.n_points // (self.pool ** level)

    @property
    def bottleneck(self) -> int:
        return self.level_points(self.blocks) * self.internal


def compression_factor(cfg: AEConfig) -> float:
    """Paper: size of the per-rank simulation data / latent dimension."""
    return (cfg.n_points * cfg.channels) / cfg.latent


def coords_pyramid(cfg: AEConfig, coords: jax.Array) -> list[jax.Array]:
    """Strided point subsets per level: [N], [N/4], [N/16], ..."""
    out = [coords]
    for level in range(1, cfg.blocks + 1):
        out.append(coords[:: cfg.pool ** level])
    return out


def _conv(cfg: AEConfig, c_in: int, c_out: int) -> QuadConv:
    return QuadConv(c_in=c_in, c_out=c_out, mlp_width=cfg.mlp_width,
                    mlp_depth=cfg.mlp_depth, support=cfg.support,
                    mode=cfg.mode)


def init_autoencoder(key, cfg: AEConfig) -> dict:
    keys = jax.random.split(key, 2 * cfg.blocks + 3)
    params: dict[str, Any] = {"enc": [], "dec": []}
    c = cfg.channels
    for b in range(cfg.blocks):
        conv = _conv(cfg, c, cfg.internal)
        p = conv.init(keys[b], cfg.level_points(b))
        p["ln_scale"] = jnp.ones((cfg.internal,))
        p["ln_bias"] = jnp.zeros((cfg.internal,))
        params["enc"].append(p)
        c = cfg.internal
    params["enc_head"] = {
        "w": jax.random.normal(keys[cfg.blocks], (cfg.bottleneck, cfg.latent))
        * jnp.sqrt(1.0 / cfg.bottleneck),
        "b": jnp.zeros((cfg.latent,)),
    }
    params["dec_head"] = {
        "w": jax.random.normal(keys[cfg.blocks + 1],
                               (cfg.latent, cfg.bottleneck))
        * jnp.sqrt(1.0 / cfg.latent),
        "b": jnp.zeros((cfg.bottleneck,)),
    }
    for b in range(cfg.blocks):
        conv = _conv(cfg, cfg.internal, cfg.internal)
        p = conv.init(keys[cfg.blocks + 2 + b],
                      cfg.level_points(cfg.blocks - b - 1))
        p["ln_scale"] = jnp.ones((cfg.internal,))
        p["ln_bias"] = jnp.zeros((cfg.internal,))
        params["dec"].append(p)
    params["out_head"] = {
        "w": jax.random.normal(keys[-1], (cfg.internal, cfg.channels))
        * jnp.sqrt(1.0 / cfg.internal),
        "b": jnp.zeros((cfg.channels,)),
    }
    return params


def _layernorm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def _pool_max(x: jax.Array, k: int) -> jax.Array:
    b, n, c = x.shape
    return jnp.max(x.reshape(b, n // k, k, c), axis=2)


def _unpool(x: jax.Array, k: int) -> jax.Array:
    b, n, c = x.shape
    return jnp.broadcast_to(x[:, :, None, :], (b, n, k, c)).reshape(b, n * k, c)


def encode(params: dict, cfg: AEConfig, levels: list[jax.Array],
           f: jax.Array) -> jax.Array:
    """f: [B, N, C] → z: [B, latent]."""
    x = f
    c = cfg.channels
    for b in range(cfg.blocks):
        conv = _conv(cfg, c, cfg.internal)
        p = params["enc"][b]
        x = conv.apply(p, x, levels[b], levels[b])
        x = jax.nn.gelu(x)
        x = _layernorm(x, p["ln_scale"], p["ln_bias"])
        x = _pool_max(x, cfg.pool)
        c = cfg.internal
    x = x.reshape(x.shape[0], -1)
    return x @ params["enc_head"]["w"] + params["enc_head"]["b"]


def decode(params: dict, cfg: AEConfig, levels: list[jax.Array],
           z: jax.Array) -> jax.Array:
    """z: [B, latent] → f̂: [B, N, C]."""
    x = z @ params["dec_head"]["w"] + params["dec_head"]["b"]
    x = x.reshape(z.shape[0], cfg.level_points(cfg.blocks), cfg.internal)
    for b in range(cfg.blocks):
        lvl = cfg.blocks - b - 1
        x = _unpool(x, cfg.pool)
        conv = _conv(cfg, cfg.internal, cfg.internal)
        p = params["dec"][b]
        x = conv.apply(p, x, levels[lvl], levels[lvl])
        x = jax.nn.gelu(x)
        x = _layernorm(x, p["ln_scale"], p["ln_bias"])
    return x @ params["out_head"]["w"] + params["out_head"]["b"]


def reconstruct(params: dict, cfg: AEConfig, levels: list[jax.Array],
                f: jax.Array) -> jax.Array:
    return decode(params, cfg, levels, encode(params, cfg, levels, f))


def loss_fn(params: dict, cfg: AEConfig, levels: list[jax.Array],
            f: jax.Array) -> jax.Array:
    """Mean-squared reconstruction error (paper: MSE loss)."""
    rec = reconstruct(params, cfg, levels, f)
    return jnp.mean(jnp.square(rec - f))


def rel_frobenius(f: jax.Array, rec: jax.Array) -> jax.Array:
    """Paper Eq. 1: mean over samples of ‖F−F̂‖_F / ‖F‖_F."""
    num = jnp.sqrt(jnp.sum(jnp.square(f - rec), axis=(-2, -1)))
    den = jnp.sqrt(jnp.sum(jnp.square(f), axis=(-2, -1)))
    return jnp.mean(num / jnp.maximum(den, 1e-12))
