"""ResNet50 in pure JAX — the paper's inference benchmark model (§3.2).

The paper characterizes in-situ inference cost with ResNet50
((n,3,224,224) → (n,1000)) served through RedisAI.  We implement the
standard bottleneck-v1.5 network as init/apply pure functions.  BatchNorm
runs in inference mode (folded scale/shift), matching a deployed model; the
benchmarks measure transfer + evaluation cost, not accuracy, so weights are
randomly initialized.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["init_resnet50", "apply_resnet50", "RESNET50_STAGES"]

RESNET50_STAGES = (3, 4, 6, 3)


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    return jax.random.normal(key, (kh, kw, cin, cout)) * jnp.sqrt(2.0 / fan_in)


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bottleneck_init(key, cin, cmid, stride):
    ks = jax.random.split(key, 4)
    cout = cmid * 4
    p = {
        "conv1": _conv_init(ks[0], 1, 1, cin, cmid), "bn1": _bn_init(cmid),
        "conv2": _conv_init(ks[1], 3, 3, cmid, cmid), "bn2": _bn_init(cmid),
        "conv3": _conv_init(ks[2], 1, 1, cmid, cout), "bn3": _bn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], 1, 1, cin, cout)
        p["bn_proj"] = _bn_init(cout)
    return p


def init_resnet50(key, num_classes: int = 1000) -> dict:
    keys = jax.random.split(key, 2 + sum(RESNET50_STAGES))
    params: dict = {
        "stem": _conv_init(keys[0], 7, 7, 3, 64),
        "bn_stem": _bn_init(64),
        "stages": [],
    }
    cin, ki = 64, 1
    for s, blocks in enumerate(RESNET50_STAGES):
        cmid = 64 * (2 ** s)
        stage = []
        for b in range(blocks):
            stride = 2 if (b == 0 and s > 0) else 1
            stage.append(_bottleneck_init(keys[ki], cin, cmid, stride))
            cin = cmid * 4
            ki += 1
        params["stages"].append(stage)
    params["fc"] = {
        "w": jax.random.normal(keys[ki], (cin, num_classes))
        * jnp.sqrt(1.0 / cin),
        "b": jnp.zeros((num_classes,)),
    }
    return params


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p):
    return x * p["scale"] + p["bias"]


def _bottleneck(p, x, stride):
    y = jax.nn.relu(_bn(_conv(x, p["conv1"]), p["bn1"]))
    y = jax.nn.relu(_bn(_conv(y, p["conv2"], stride), p["bn2"]))
    y = _bn(_conv(y, p["conv3"]), p["bn3"])
    if "proj" in p:
        x = _bn(_conv(x, p["proj"], stride), p["bn_proj"])
    return jax.nn.relu(x + y)


def apply_resnet50(params: dict, x: jax.Array) -> jax.Array:
    """x: [N, 3, 224, 224] (paper's NCHW interface) → logits [N, 1000]."""
    x = x.transpose(0, 2, 3, 1)                     # NCHW → NHWC (TPU layout)
    x = jax.nn.relu(_bn(_conv(x, params["stem"], 2), params["bn_stem"]))
    x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 3, 3, 1),
                              (1, 2, 2, 1), "SAME")
    for s, stage in enumerate(params["stages"]):
        for b, block in enumerate(stage):
            stride = 2 if (b == 0 and s > 0) else 1
            x = _bottleneck(block, x, stride)
    x = jnp.mean(x, axis=(1, 2))
    return x @ params["fc"]["w"] + params["fc"]["b"]
