"""Data-consumer substrate: QuadConv autoencoder (paper §4), ResNet50
(paper §3.2 inference benches) and the store-backed in-situ trainer."""

from . import autoencoder, quadconv, resnet, trainer
from .autoencoder import AEConfig
from .trainer import TrainerConfig, TrainState

__all__ = ["autoencoder", "quadconv", "resnet", "trainer", "AEConfig",
           "TrainerConfig", "TrainState"]
