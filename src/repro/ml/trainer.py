"""Distributed in-situ trainer (the paper's data-consumer component, §4).

Mirrors the paper's PyTorch-DDP training workload with the store-backed
data loader swapped in ("the distributed training application … gathers the
data before each epoch by simply modifying the existing dataloaders"):

* at the start of each epoch every ML rank gathers ``gather`` tensors from
  the store (paper: 6 = 24 sim ranks / 4 ML ranks per node), concatenates
  them, holds one out at random for validation (paper §4), and runs
  mini-batch SGD on the rest;
* Adam + MSE, lr = 1e-4 × n_ranks (paper's linear scaling rule);
* per-channel standardization statistics are computed from the first
  gathered snapshots and broadcast via store *metadata* (the paper's
  metadata transfers);
* component timers land in the same buckets as paper Table 2
  (client_init / metadata / retrieve / train).

Two execution tiers (``TrainerConfig.fused``):

* **fused** (default, beyond-paper): the whole epoch — store gather,
  normalization, held-out split, the mini-batch SGD scan, and validation —
  is ONE jitted dispatch against the checked-out table state
  (``Client.capture``).  O(1) dispatches per epoch instead of
  O(gather·batches), and the consumer holds the table lock only for the
  enqueue.
* **per-verb** (paper-fidelity): one client verb per gather + one dispatch
  per mini-batch, matching the paper's component-measurable loop.

DDP (``TrainerConfig.mesh``): the **sharded fused epoch** runs the whole
fused epoch — store gather, normalization, the mini-batch SGD scan with an
explicit gradient all-reduce, and validation — inside ONE ``shard_map``
over the mesh's ``data`` axis, so a multi-device epoch is still a single
dispatch.  Every rank derives the identical gather/permutation from the
shared epoch rng (replicated compute, cheap), takes its slice of each
mini-batch, and the per-rank gradients are combined with either an exact
fp32 ``psum`` (``ddp="psum"``, default) or the int8-compressed wire format
from ``parallel/compress.py`` (``ddp="int8"``, ≈¼ the interconnect bytes,
biased per step).  The paper's perfect train-scaling claim becomes a
structural property: dispatches/epoch stays O(1) at any mesh size.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core import store as S
from ..core.client import Client
from ..parallel.compress import compressed_psum_mean, compressed_psum_mean_ef
from ..train import optimizer as opt
from . import autoencoder as ae

__all__ = ["TrainState", "TrainerConfig", "make_train_step",
           "make_fused_epoch", "make_sharded_fused_epoch",
           "make_clustered_sharded_epoch", "make_per_verb_epoch",
           "EPOCH_BUILDERS", "insitu_train", "EpochResult"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


@dataclass(frozen=True)
class TrainerConfig:
    """Consumer-loop configuration (paper §4 values as defaults).

    Fused-epoch knobs:

    * ``fused`` — run each epoch as ONE jitted dispatch against the
      checked-out table state (``Client.capture``): gather, normalization,
      held-out split, the mini-batch SGD scan and validation all fuse.
      ``False`` keeps the paper-fidelity per-verb loop (one dispatch per
      gather and per mini-batch) for component-level measurement.  The
      gather reads the table under the capture transaction, so producer
      puts racing the epoch keep per-verb semantics — batched ring writes
      resolve **last-writer-wins** (see ``core.store.put_many``), and the
      epoch sees either the pre- or post-chunk table, never a torn one.
    * ``mesh`` / ``mesh_axis`` — a device mesh turns the fused epoch into
      the *sharded* fused epoch: the same one-dispatch epoch inside a
      single ``shard_map`` over ``mesh_axis``, mini-batches sharded across
      ranks and gradients all-reduced every SGD microstep (DDP).
      ``batch_size`` must divide by the mesh-axis size.  Requires
      ``fused=True``.
    * ``ddp`` — gradient wire format on the mesh: ``"psum"`` (exact fp32
      all-reduce, bit-deterministic given fixed mesh) or ``"int8"``
      (``parallel.compress`` compressed all-reduce, ≈¼ the bytes, biased
      per step — validated to track the exact path in tests).
    * ``ddp_error_feedback`` — for ``ddp="int8"``: thread the quantization
      residual through the epoch scan's carry
      (``parallel.compress.compressed_psum_mean_ef``) so the compressed
      wire stops silently dropping what int8 rounded away.  Resets at each
      epoch boundary (the carry is per-dispatch state).
    * ``slab_sharded`` — slab-sharded *data plane*: the table slab enters
      the sharded fused epoch's ``shard_map`` already partitioned along
      the mesh axis (slot axis split ``capacity/D`` per rank,
      ``parallel.sharding.slab_sharding`` placement) instead of
      replicated.  The store gather becomes shard-local
      (``core.store.sample_sharded_impl``) with one explicit ``psum``
      reassembling each batch — no table all-gather on entry, per-device
      table memory O(capacity/D), results bit-identical to the
      replicated-entry tier.  Requires ``mesh`` and a table capacity
      divisible by the mesh-axis size.
    * ``db_mesh`` / ``db_axis`` — the slab-sharded *clustered* data
      plane (tier ``slab_sharded_clustered``): the table lives
      slot-partitioned on a *dedicated* db mesh (a ``Clustered``
      deployment's store devices; the session wires these from the
      deployment) while the trainer's ``shard_map`` runs on ``mesh``
      (the client devices).  Each epoch gathers ON the db mesh
      (shard-local rows + one explicit psum), moves the assembled batch
      across the interconnect in ONE counted staged transfer, and
      trains on the client mesh — the gather psum becomes an explicit
      cross-mesh hop instead of an implicit replication.  Requires
      ``slab_sharded=True`` and ``mesh``.
    """

    ae: ae.AEConfig
    epochs: int = 50
    gather: int = 6              # tensors gathered per rank per epoch (paper)
    batch_size: int = 4
    lr: float = 1e-4             # paper base lr, scaled by n_ranks
    n_ranks: int = 1
    min_snapshots: int = 1
    wait_timeout_s: float = 60.0
    table: str = "field"
    seed: int = 0
    fused: bool = True           # one-dispatch epochs via Client.capture
    mesh: Any = None             # device mesh -> sharded fused epoch (DDP)
    mesh_axis: str = "data"      # mesh axis the batch shards over
    ddp: str = "psum"            # "psum" (exact) | "int8" (compressed wire)
    ddp_error_feedback: bool = True   # int8: residual rides the scan carry
    slab_sharded: bool = False   # table enters the shard_map pre-sharded
    db_mesh: Any = None          # clustered: the store's dedicated mesh
    db_axis: str | None = None   # clustered: slot-partition axis on db_mesh

    def __post_init__(self):
        if self.ddp not in ("psum", "int8"):
            raise ValueError(f"unknown ddp mode {self.ddp!r}")
        if self.mesh is not None and not self.fused:
            raise ValueError("mesh-sharded training requires fused=True")
        if self.slab_sharded and self.mesh is None:
            raise ValueError("slab_sharded needs a mesh (the slab shards "
                             "over cfg.mesh_axis)")
        if self.db_mesh is not None and not self.slab_sharded:
            raise ValueError("db_mesh is the slab-sharded clustered data "
                             "plane; it needs slab_sharded=True")

    @property
    def scaled_lr(self) -> float:
        return self.lr * self.n_ranks   # paper's linear scaling rule


@dataclass
class EpochResult:
    epoch: int
    train_loss: float
    val_loss: float
    val_rel_error: float
    watermark: int


def make_train_step(cfg: TrainerConfig, levels, tx: opt.GradientTransformation):
    """jit'd (state, batch[B,N,C]) → (state, loss)."""

    return jax.jit(_microstep_fn(cfg, levels, tx))


def _microstep_fn(cfg: TrainerConfig, levels, tx: opt.GradientTransformation):
    """Raw (unjitted) SGD microstep, traceable inside the fused epoch."""

    def loss_fn(params, batch):
        return ae.loss_fn(params, cfg.ae, levels, batch)

    def step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        updates, opt_state = tx.update(grads, state.opt_state, state.params)
        params = opt.apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), loss

    return step


def _epoch_data(cfg: TrainerConfig, spec: S.TableSpec, table_state, rng,
                mu, sd, sample: Callable | None = None):
    """The shared per-epoch data pipeline (traceable): random store gather,
    standardization, random held-out validation tensor, shuffled train set.

    Both the single-device fused epoch and the sharded fused epoch consume
    the epoch rng identically here, so a mesh run trains on exactly the
    same data stream as the single-device tier — the basis of the
    parity tests.  ``sample`` overrides the gather primitive (the
    slab-sharded tier passes ``store.sample_sharded_impl`` bound to its
    mesh axis; slot selection stays replicated compute, so the rng stream
    is untouched).  Returns ``(train [n_train,N,C], val [1,N,C], ok)``.
    """
    n_train = max(cfg.gather - 1, 1)
    k_samp, k_val, k_perm = jax.random.split(rng, 3)
    if sample is None:
        vals, _, ok = S.sample_impl(spec, table_state, k_samp, cfg.gather)
    else:
        vals, _, ok = sample(table_state, k_samp, cfg.gather)
    data = (vals.transpose(0, 2, 1) - mu) / sd              # [G, N, C]
    # hold one tensor out at random (paper §4); train on the rest
    val_idx = jax.random.randint(k_val, (), 0, cfg.gather)
    val = jax.lax.dynamic_index_in_dim(data, val_idx, 0, keepdims=True)
    if cfg.gather > 1:
        tr_idx = (val_idx + 1 + jnp.arange(cfg.gather - 1)) % cfg.gather
    else:
        tr_idx = jnp.zeros((1,), jnp.int32)
    train = data[tr_idx]
    train = train[jax.random.permutation(k_perm, n_train)]
    return train, val, ok


def make_fused_epoch(cfg: TrainerConfig, levels,
                     tx: opt.GradientTransformation, spec: S.TableSpec):
    """One-dispatch training epoch over the checked-out table state.

    Fuses the paper's per-epoch consumer sequence — random store gather,
    standardization, random held-out validation tensor, shuffled mini-batch
    SGD, validation metrics — into a single jitted function

        (table_state, train_state, rng, mu, sd)
            -> (train_state, (train_loss, val_loss, val_rel, ok))

    Mini-batches are equal-sized clipped windows over the shuffled train
    set (the final window is shifted back to full size when
    ``gather-1 % batch_size != 0``), so the SGD loop is a ``lax.scan``.
    """
    n_train = max(cfg.gather - 1, 1)
    bs = min(cfg.batch_size, n_train)
    n_batches = -(-n_train // bs)
    micro = _microstep_fn(cfg, levels, tx)

    @jax.jit
    def epoch(table_state: S.TableState, state: TrainState, rng, mu, sd):
        train, val, ok = _epoch_data(cfg, spec, table_state, rng, mu, sd)
        starts = jnp.clip(jnp.arange(n_batches) * bs, 0, n_train - bs)

        def body(ts, s):
            batch = jax.lax.dynamic_slice_in_dim(train, s, bs, 0)
            return micro(ts, batch)

        state, losses = jax.lax.scan(body, state, starts)
        rec = ae.reconstruct(state.params, cfg.ae, levels, val)
        val_loss = jnp.mean(jnp.square(rec - val))
        val_rel = ae.rel_frobenius(val, rec)
        return state, (jnp.mean(losses), val_loss, val_rel, ok)

    return epoch


def make_per_verb_epoch(cfg: TrainerConfig, levels,
                        tx: opt.GradientTransformation, spec: S.TableSpec):
    """The paper-fidelity epoch: identical math to :func:`make_fused_epoch`
    dispatched verb by verb.

    One client ``sample_batch`` (a store dispatch), one jitted data-prep
    dispatch, one jitted SGD dispatch per mini-batch, one validation
    dispatch — each component measurable in its own paper Table-2 bucket.
    The rng splits and the clipped equal-size mini-batch windows mirror
    ``_epoch_data`` and the fused scan exactly, so the per-verb tier and
    the fused tier train on bit-identical data in bit-identical order
    (the plan/tier parity suite asserts the resulting ``TrainState``
    matches bitwise).

    Returns ``epoch(client, state, rng, mu, sd) ->
    (state, (train_loss, val_loss, val_rel, ok))`` — the same metrics
    tuple as the fused builders, but driven through a live ``Client``
    instead of a checked-out table state.
    """
    n_train = max(cfg.gather - 1, 1)
    bs = min(cfg.batch_size, n_train)
    n_batches = -(-n_train // bs)
    micro = jax.jit(_microstep_fn(cfg, levels, tx))

    @jax.jit
    def prep(vals, k_val, k_perm, mu, sd):
        data = (vals.transpose(0, 2, 1) - mu) / sd          # [G, N, C]
        val_idx = jax.random.randint(k_val, (), 0, cfg.gather)
        val = jax.lax.dynamic_index_in_dim(data, val_idx, 0, keepdims=True)
        if cfg.gather > 1:
            tr_idx = (val_idx + 1 + jnp.arange(cfg.gather - 1)) % cfg.gather
        else:
            tr_idx = jnp.zeros((1,), jnp.int32)
        train = data[tr_idx]
        return train[jax.random.permutation(k_perm, n_train)], val

    @jax.jit
    def take_batch(train, s):
        return jax.lax.dynamic_slice_in_dim(train, s, bs, 0)

    @jax.jit
    def validate(params, val):
        rec = ae.reconstruct(params, cfg.ae, levels, val)
        return jnp.mean(jnp.square(rec - val)), ae.rel_frobenius(val, rec)

    starts = [min(i * bs, n_train - bs) for i in range(n_batches)]

    def epoch(client: Client, state: TrainState, rng, mu, sd):
        k_samp, k_val, k_perm = jax.random.split(rng, 3)
        vals, _, ok = client.sample_batch(cfg.table, cfg.gather, k_samp)
        train, val = prep(vals, k_val, k_perm, mu, sd)
        losses = []
        with client.timers.time("train"):
            for s in starts:
                state, loss = micro(state, take_batch(train, s))
                losses.append(loss)
            jax.block_until_ready(state.params)
        val_loss, val_rel = validate(state.params, val)
        return state, (jnp.mean(jnp.stack(losses)), val_loss, val_rel, ok)

    def warmup(state, mu, sd):
        """Pre-compile the per-verb dispatches on dummy data (no client,
        no store ops) so the timed loop measures dispatch, not compile —
        the same off-clock treatment the fused tiers get."""
        vals = jnp.zeros((cfg.gather, *spec.shape), spec.dtype)
        k = jax.random.key(0)
        train, val = prep(vals, k, k, mu, sd)
        s2, _ = micro(state, take_batch(train, starts[0]))
        jax.block_until_ready(validate(s2.params, val))

    epoch.warmup = warmup
    return epoch


def make_sharded_fused_epoch(cfg: TrainerConfig, levels,
                             tx: opt.GradientTransformation,
                             spec: S.TableSpec):
    """The fused epoch *and* DDP inside ONE ``shard_map`` over the mesh.

    Same signature and semantics as :func:`make_fused_epoch`, but the whole
    epoch body runs as a single SPMD program over ``cfg.mesh``'s
    ``cfg.mesh_axis`` (size D):

    * the gather / holdout / shuffle pipeline is computed redundantly on
      every rank from the shared epoch rng (replicated compute — it is a
      few permutations, while the gradient work dominates), so the global
      data order matches the single-device tier exactly;
    * each SGD microstep slices the rank's ``batch_size/D`` mini-batch
      shard, takes the local mean-loss gradient, and all-reduces it —
      exact fp32 ``psum`` or the int8-compressed wire
      (``parallel.compress.compressed_psum_mean``) per ``cfg.ddp``; with
      ``cfg.ddp_error_feedback`` the int8 quantization residual rides the
      scan carry (``compressed_psum_mean_ef``) instead of being dropped;
    * optimizer state stays replicated: every rank applies the identical
      synced gradient, so no post-hoc parameter broadcast is needed.

    One host dispatch per epoch regardless of mesh size — the paper's
    "perfect scaling of training" claim made structural.

    Data-plane entry (``cfg.slab_sharded``, tier ``"slab_sharded"``):

    * **replicated entry** (default, tier ``"sharded_fused"``): every
      operand — table state included — enters the ``shard_map``
      replicated, so each device holds the whole ``[capacity, *elem]``
      slab and a slab-sharded table is all-gathered on entry;
    * **slab-sharded entry**: the slab's in-spec partitions the slot axis
      over ``cfg.mesh_axis`` (matching the
      ``parallel.sharding.slab_sharding`` placement), metadata stays
      replicated, and the gather runs shard-local
      (``store.sample_sharded_impl``) with ONE explicit ``psum``
      reassembling each batch.  No table all-gather, per-device slab
      memory O(capacity/D), bit-identical results (each slot has exactly
      one owner, so the psum adds zeros to the owned row).
    """
    mesh = cfg.mesh
    if mesh is None:
        raise ValueError("make_sharded_fused_epoch needs cfg.mesh")
    axis = cfg.mesh_axis
    ndev = int(mesh.shape[axis])
    n_train = max(cfg.gather - 1, 1)
    bs = min(cfg.batch_size, n_train)
    if bs % ndev:
        raise ValueError(
            f"batch_size {bs} must divide by mesh axis {axis!r} size {ndev}")
    bl = bs // ndev
    n_batches = -(-n_train // bs)

    if cfg.slab_sharded:
        if spec.capacity % ndev:
            raise ValueError(
                f"slab-sharded entry needs capacity {spec.capacity} "
                f"divisible by mesh axis {axis!r} size {ndev}")
        sample = partial(S.sample_sharded_impl, spec, axis=axis)
        slab_spec = P(axis)
    else:
        sample = None
        slab_spec = P()

    run = _make_ddp_scan(cfg, levels, tx, axis, ndev, bs, n_train,
                         n_batches)

    def epoch_body(table_state: S.TableState, state: TrainState, rng,
                   mu, sd):
        train, val, ok = _epoch_data(cfg, spec, table_state, rng, mu, sd,
                                     sample=sample)
        return run(state, train, val, ok)

    table_specs = S.TableState(slab=slab_spec, keys=P(), version=P(),
                               ptr=P(), count=P())
    sharded = shard_map(epoch_body, mesh=mesh,
                        in_specs=(table_specs, P(), P(), P(), P()),
                        out_specs=(P(), P()),
                        check_rep=False)
    return jax.jit(sharded)


def _make_ddp_scan(cfg: TrainerConfig, levels, tx, axis: str, ndev: int,
                   bs: int, n_train: int, n_batches: int):
    """The DDP mini-batch SGD scan + validation, traceable inside a
    ``shard_map`` over mesh axis ``axis`` — the epoch half shared by
    :func:`make_sharded_fused_epoch` (gather in-dispatch) and
    :func:`make_clustered_sharded_epoch` (batch staged across meshes).

    Returns ``run(state, train, val, ok) -> (state, metrics)``.
    """
    bl = bs // ndev
    use_ef = cfg.ddp == "int8" and cfg.ddp_error_feedback

    def loss_fn(params, batch):
        return ae.loss_fn(params, cfg.ae, levels, batch)

    def run(state: TrainState, train, val, ok):
        starts = jnp.clip(jnp.arange(n_batches) * bs, 0, n_train - bs)
        ridx = jax.lax.axis_index(axis)

        def body(carry, s):
            ts, resid = carry
            batch = jax.lax.dynamic_slice_in_dim(train, s, bs, 0)
            local = jax.lax.dynamic_slice_in_dim(batch, ridx * bl, bl, 0)
            loss_l, grads_l = jax.value_and_grad(loss_fn)(ts.params, local)
            if use_ef:
                grads, resid = compressed_psum_mean_ef(grads_l, resid,
                                                       axis, ndev)
            elif cfg.ddp == "int8":
                grads = compressed_psum_mean(grads_l, axis, ndev)
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.psum(g, axis) / ndev, grads_l)
            loss = jax.lax.psum(loss_l, axis) / ndev
            updates, opt_state = tx.update(grads, ts.opt_state, ts.params)
            params = opt.apply_updates(ts.params, updates)
            return (TrainState(params, opt_state, ts.step + 1), resid), loss

        # Error feedback is per-dispatch state: the residual starts at zero
        # each epoch and lives only inside the scan carry.
        resid0 = jax.tree.map(jnp.zeros_like, state.params) if use_ef \
            else jnp.zeros(())
        (state, _), losses = jax.lax.scan(body, (state, resid0), starts)
        # validation is replicated compute (identical on every rank)
        rec = ae.reconstruct(state.params, cfg.ae, levels, val)
        val_loss = jnp.mean(jnp.square(rec - val))
        val_rel = ae.rel_frobenius(val, rec)
        return state, (jnp.mean(losses), val_loss, val_rel, ok)

    return run


def make_clustered_sharded_epoch(cfg: TrainerConfig, levels,
                                 tx: opt.GradientTransformation,
                                 spec: S.TableSpec):
    """The slab-sharded *clustered* tier: db mesh ≠ trainer mesh.

    The table slab lives slot-partitioned on the deployment's dedicated
    ``cfg.db_mesh`` (``Clustered(slab_axis=...)`` placement) while the
    trainer's DDP ``shard_map`` runs on ``cfg.mesh`` (the client
    devices), so one jitted program cannot span both.  Each epoch is
    therefore:

    1. ONE staged-gather store verb (``Client.sample_staged``): slot
       selection + shard-local row gather + the explicit batch-assembly
       ``psum`` run on the db mesh (``store.make_clustered_gather``), and
       the assembled ``[gather, *shape]`` batch crosses the interconnect
       in ONE counted staged transfer — the co-located tier's gather psum
       made an explicit cross-mesh hop;
    2. ONE client-mesh ``shard_map`` dispatch running the identical DDP
       epoch body (:func:`_make_ddp_scan`) on the staged batch.

    Client-driven signature like :func:`make_per_verb_epoch`:
    ``epoch(client, state, rng, mu, sd)``.  The epoch rng stream matches
    every other tier exactly — the staged gather consumes the same
    ``k_samp`` the fused tiers split off in ``_epoch_data``, so slot
    selection (and hence training data) is tier-independent.  One store
    dispatch per epoch; the db-side gather executable compiles lazily on
    the first epoch (server-side cache), charged to its retrieve bucket.
    """
    mesh = cfg.mesh
    if mesh is None:
        raise ValueError("make_clustered_sharded_epoch needs cfg.mesh")
    axis = cfg.mesh_axis
    ndev = int(mesh.shape[axis])
    n_train = max(cfg.gather - 1, 1)
    bs = min(cfg.batch_size, n_train)
    if bs % ndev:
        raise ValueError(
            f"batch_size {bs} must divide by mesh axis {axis!r} size {ndev}")
    n_batches = -(-n_train // bs)
    run = _make_ddp_scan(cfg, levels, tx, axis, ndev, bs, n_train,
                         n_batches)

    def train_body(vals, ok_in, state: TrainState, rng, mu, sd):
        # _epoch_data splits the same epoch rng; its k_samp was already
        # consumed by the staged gather, so the sample override just
        # injects the staged batch — identical stream to the fused tiers.
        train, val, ok = _epoch_data(
            cfg, spec, None, rng, mu, sd,
            sample=lambda _ts, _k, _n: (vals, None, ok_in))
        return run(state, train, val, ok)

    train_fn = jax.jit(shard_map(train_body, mesh=mesh,
                                 in_specs=(P(),) * 6,
                                 out_specs=(P(), P()),
                                 check_rep=False))

    def epoch(client: Client, state: TrainState, rng, mu, sd):
        k_samp = jax.random.split(rng, 3)[0]
        vals, ok = client.sample_staged(cfg.table, cfg.gather, k_samp)
        with client.timers.time("train"):
            state, metrics = train_fn(vals, ok, state, rng, mu, sd)
            jax.block_until_ready(state.params)
        return state, metrics

    def warmup(state, mu, sd):
        """Pre-compile the client-mesh half on a zero batch placed like
        the staged one (no store ops — dispatch accounting stays exact;
        the db-side gather compiles on the first real epoch)."""
        vals = jax.device_put(
            jnp.zeros((cfg.gather, *spec.shape), spec.dtype),
            NamedSharding(mesh, P()))
        jax.block_until_ready(
            train_fn(vals, jnp.asarray(True), state, jax.random.key(0),
                     mu, sd)[1])

    epoch.warmup = warmup
    epoch.train_fn = train_fn      # HLO accounting (plan(hlo=True))
    return epoch


#: Consumer tier -> epoch builder.  Tier *selection* is plan data
#: (``repro.insitu.plan.trainer_tier``); this table is the only place the
#: names meet code, so adding a tier is one entry, not another if-chain.
#: ``sharded_fused`` and ``slab_sharded`` share one builder — the entry
#: layout is read from ``cfg.slab_sharded``, which the tier rules keep
#: consistent with the tier name.
EPOCH_BUILDERS: dict[str, Callable] = {
    "fused": make_fused_epoch,
    "sharded_fused": make_sharded_fused_epoch,
    "slab_sharded": make_sharded_fused_epoch,
    "slab_sharded_clustered": make_clustered_sharded_epoch,
    "per_verb": make_per_verb_epoch,
}

#: tiers whose epoch is driven through a live ``Client`` (one verb per
#: component) instead of a fused capture against checked-out table state.
CLIENT_DRIVEN_TIERS = ("per_verb", "slab_sharded_clustered")


def _strong(x):
    """Drop weak types so the step-N state has the same avals as init
    (a weak-typed init leaf forces a silent recompile on the 2nd step)."""
    x = jnp.asarray(x)
    return jax.lax.convert_element_type(x, x.dtype)


def init_state(cfg: TrainerConfig, key, tx) -> TrainState:
    params = jax.tree.map(_strong, ae.init_autoencoder(key, cfg.ae))
    return TrainState(params=params,
                      opt_state=jax.tree.map(_strong, tx.init(params)),
                      step=jnp.zeros((), jnp.int32))


def _standardize_stats(batch: jax.Array):
    """Per-channel mean/std over [B,N,C] → ([C],[C])."""
    mu = jnp.mean(batch, axis=(0, 1))
    sd = jnp.std(batch, axis=(0, 1)) + 1e-6
    return mu, sd


def insitu_train(client: Client, coords: jax.Array, cfg: TrainerConfig,
                 stop_event=None,
                 on_epoch: Callable[[EpochResult], None] | None = None,
                 state: TrainState | None = None, tier: str | None = None,
                 memckpt=None, component: str | None = None,
                 on_checkpoint: Callable[[int, TrainState], None]
                 | None = None):
    """The consumer loop.  Returns (state, [EpochResult...], levels, stats).

    This is the runtime behind ``repro.insitu.InSituSession``'s
    ``TrainerConsumer`` (and the legacy direct entry point).  ``tier``
    names the execution tier — ``"fused"`` / ``"sharded_fused"`` /
    ``"per_verb"``, one key of :data:`EPOCH_BUILDERS`; when ``None`` it is
    resolved from ``cfg`` by ``repro.insitu.plan.trainer_tier`` (the same
    data-driven rule a session ``Plan`` records).  Every tier consumes the
    epoch rng identically and trains on the identical data stream, so tier
    choice is a deployment decision, not a numerics decision.

    The loop never blocks on the producer beyond ``wait_timeout_s``
    (straggler mitigation): it trains on whatever the store already holds.

    Fault tolerance: ``memckpt`` (a ``train.checkpoint.MemoryCheckpoint``)
    parks ``(state, rng, history)`` in store metadata after every epoch —
    and once before epoch 0, right after the norm-stats bootstrap — so a
    crashed trainer re-entering this function resumes at the first
    unfinished epoch with the identical rng stream (bit-identical final
    state vs an uncrashed run).  ``component`` names this consumer to the
    deployment's ``FaultPlan``: each epoch opens with a crash point the
    injector may fire exactly once.  Checkpoint traffic is host-side
    metadata — zero store dispatches, so crash/recovery never perturbs the
    plan's op-count predictions.

    ``on_checkpoint(epoch, state)`` fires at the end of every completed
    epoch, after its checkpoint save — the hot-swap publication hook (the
    session publishes versioned model generations from it).  Because the
    crash point opens an epoch and this hook closes one, a resumed run
    skips completed epochs and never re-fires their publications.
    """
    if tier is None:
        from ..insitu.plan import trainer_tier
        tier = trainer_tier(cfg)
    if tier not in EPOCH_BUILDERS:
        raise ValueError(f"unknown trainer tier {tier!r} "
                         f"(have {sorted(EPOCH_BUILDERS)})")
    levels = ae.coords_pyramid(cfg.ae, coords)
    tx = opt.adam(cfg.scaled_lr)
    resumed = memckpt.restore() if memckpt is not None else None
    if state is None and resumed is None:
        state = init_state(cfg, jax.random.key(cfg.seed), tx)
    epoch_fn = EPOCH_BUILDERS[tier](cfg, levels, tx,
                                    client.server.spec(cfg.table))
    # capture-driven tiers dispatch one fused epoch against checked-out
    # table state; client-driven tiers (per-verb, the clustered staged
    # gather) run their epoch through live store verbs instead.
    fused = tier not in CLIENT_DRIVEN_TIERS
    rng = jax.random.key(cfg.seed + 1)
    history: list[EpochResult] = []
    start_epoch = 0

    if resumed is not None:
        # --- crash recovery: pick up at the first unfinished epoch -------
        # The checkpoint was written after the bootstrap published the
        # norm stats, so the metadata read below always hits; no store
        # verbs are issued on this path (warmup reuses the in-process jit
        # cache, the wait/bootstrap already happened before the crash).
        saved_epoch, payload = resumed
        state = payload["state"]
        rng = payload["rng"]
        history = list(payload["history"])
        start_epoch = saved_epoch + 1
        mu, sd = client.get_metadata("norm_stats")
        if tier == "slab_sharded_clustered":
            sh = NamedSharding(cfg.mesh, P())
            mu, sd = jax.device_put(mu, sh), jax.device_put(sd, sh)
    else:
        # Paper: "the ML workload must query the database multiple times
        # while waiting for the first training snapshot".
        client.wait_for_data(cfg.table, minimum=cfg.min_snapshots,
                             timeout=cfg.wait_timeout_s)

        # Standardization stats from the first gather, published as
        # metadata.
        mu_sd = client.get_metadata("norm_stats")
        if mu_sd is None:
            rng, k = jax.random.split(rng)
            first, _, ok = client.sample_batch(cfg.table, cfg.gather, k)
            batch = first.transpose(0, 2, 1)        # [G, N, C]
            mu, sd = _standardize_stats(batch)
            client.put_metadata("norm_stats", (mu, sd))
            mu_sd = (mu, sd)
        mu, sd = mu_sd
        if tier == "slab_sharded_clustered":
            # The bootstrap stats were computed from a sample living on the
            # store's db mesh; pin them onto the trainer's client mesh so
            # the staged epoch stays a pure client-mesh program (one jitted
            # computation cannot span both device sets).
            sh = NamedSharding(cfg.mesh, P())
            mu, sd = jax.device_put(mu, sh), jax.device_put(sd, sh)

        if fused:
            # Warm the fused-epoch executable on a throwaway empty table so
            # the timed loop measures dispatch, not compilation (charged to
            # its own component bucket, like the paper's one-off model-load
            # cost).  The slab-sharded tier places the dummy like the live
            # table — jit caches on input shardings, so a replicated dummy
            # would compile a second executable the timed loop never uses.
            # (Every other tier keeps the dummy uncommitted: jit re-places
            # it freely, which is what the epoch does to the live
            # single-device state too.)
            with client.timers.time("jit_compile"):
                dummy_sharding = None
                if tier == "slab_sharded":
                    from ..parallel.sharding import slab_sharding
                    dummy_sharding = slab_sharding(
                        client.server.spec(cfg.table), cfg.mesh,
                        cfg.mesh_axis)
                dummy = S.init_table(client.server.spec(cfg.table),
                                     dummy_sharding)
                jax.block_until_ready(
                    epoch_fn(dummy, state, jax.random.key(0), mu, sd)[1])
        else:
            # The per-verb tier gets the same off-clock compile treatment.
            with client.timers.time("jit_compile"):
                epoch_fn.warmup(state, mu, sd)

        if memckpt is not None:
            # Anchor checkpoint: a crash at epoch 0 resumes here instead of
            # re-running the bootstrap (which would burn an extra sample
            # verb and fork the rng stream).
            memckpt.save(-1, {"state": state, "rng": rng, "history": []})

    epoch_timer_start = time.perf_counter()
    for epoch in range(start_epoch, cfg.epochs):
        if stop_event is not None and stop_event.is_set():
            break
        if component is not None:
            # Crash point: before the rng split, so a restarted epoch
            # re-derives the identical per-epoch key from the checkpoint.
            client.fault_point(component, epoch)
        rng, k_ep = jax.random.split(rng)
        if fused:
            # --- fused: ONE dispatch for gather + SGD + validation --------
            with client.timers.time("retrieve"):
                # Enqueue-only under the table lock (orders the read against
                # donating producer puts); blocking happens below.  Routed
                # through ``capture_epoch`` so a transient store-unavailable
                # window retries the read-only capture.
                prev = state
                state, metrics = client.capture_epoch(
                    cfg.table,
                    lambda txn: epoch_fn(txn.state, prev, k_ep, mu, sd))
            with client.timers.time("train"):
                jax.block_until_ready(state.params)
        else:
            # --- per-verb: same math, one dispatch per component ----------
            state, metrics = epoch_fn(client, state, k_ep, mu, sd)
        train_loss_t, val_loss_t, val_err_t, _ok = metrics
        res = EpochResult(epoch=epoch, train_loss=float(train_loss_t),
                          val_loss=float(val_loss_t),
                          val_rel_error=float(val_err_t),
                          watermark=client.watermark(cfg.table))
        history.append(res)
        if on_epoch is not None:
            on_epoch(res)
        if memckpt is not None:
            memckpt.save(epoch, {"state": state, "rng": rng,
                                 "history": list(history)})
        if on_checkpoint is not None:
            on_checkpoint(epoch, state)
    client.timers.record("total_training",
                         time.perf_counter() - epoch_timer_start)
    return state, history, levels, (mu, sd)
