"""Distributed in-situ trainer (the paper's data-consumer component, §4).

Mirrors the paper's PyTorch-DDP training workload with the store-backed
data loader swapped in ("the distributed training application … gathers the
data before each epoch by simply modifying the existing dataloaders"):

* at the start of each epoch every ML rank gathers ``gather`` tensors from
  the store (paper: 6 = 24 sim ranks / 4 ML ranks per node), concatenates
  them, holds one out at random for validation (paper §4), and runs
  mini-batch SGD on the rest;
* Adam + MSE, lr = 1e-4 × n_ranks (paper's linear scaling rule);
* per-channel standardization statistics are computed from the first
  gathered snapshots and broadcast via store *metadata* (the paper's
  metadata transfers);
* component timers land in the same buckets as paper Table 2
  (client_init / metadata / retrieve / train).

DDP: on a device mesh the batch is sharded over the ``data`` axis and JAX
autodiff's mean-loss gradient *is* the all-reduced DDP gradient.  An
explicit shard_map DDP path with int8-compressed all-reduce lives in
``parallel/compress.py`` (beyond-paper distributed-optimization trick).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..core.client import Client
from ..train import optimizer as opt
from . import autoencoder as ae

__all__ = ["TrainState", "TrainerConfig", "make_train_step", "insitu_train",
           "EpochResult"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


@dataclass(frozen=True)
class TrainerConfig:
    ae: ae.AEConfig
    epochs: int = 50
    gather: int = 6              # tensors gathered per rank per epoch (paper)
    batch_size: int = 4
    lr: float = 1e-4             # paper base lr, scaled by n_ranks
    n_ranks: int = 1
    min_snapshots: int = 1
    wait_timeout_s: float = 60.0
    table: str = "field"
    seed: int = 0

    @property
    def scaled_lr(self) -> float:
        return self.lr * self.n_ranks   # paper's linear scaling rule


@dataclass
class EpochResult:
    epoch: int
    train_loss: float
    val_loss: float
    val_rel_error: float
    watermark: int


def make_train_step(cfg: TrainerConfig, levels, tx: opt.GradientTransformation):
    """jit'd (state, batch[B,N,C]) → (state, loss)."""

    def loss_fn(params, batch):
        return ae.loss_fn(params, cfg.ae, levels, batch)

    @jax.jit
    def step(state: TrainState, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        updates, opt_state = tx.update(grads, state.opt_state, state.params)
        params = opt.apply_updates(state.params, updates)
        return TrainState(params, opt_state, state.step + 1), loss

    return step


def init_state(cfg: TrainerConfig, key, tx) -> TrainState:
    params = ae.init_autoencoder(key, cfg.ae)
    return TrainState(params=params, opt_state=tx.init(params),
                      step=jnp.zeros((), jnp.int32))


def _standardize_stats(batch: jax.Array):
    """Per-channel mean/std over [B,N,C] → ([C],[C])."""
    mu = jnp.mean(batch, axis=(0, 1))
    sd = jnp.std(batch, axis=(0, 1)) + 1e-6
    return mu, sd


def insitu_train(client: Client, coords: jax.Array, cfg: TrainerConfig,
                 stop_event=None,
                 on_epoch: Callable[[EpochResult], None] | None = None,
                 state: TrainState | None = None):
    """The consumer loop.  Returns (state, [EpochResult...], levels, stats).

    The loop never blocks on the producer beyond ``wait_timeout_s``
    (straggler mitigation): it trains on whatever the store already holds.
    """
    levels = ae.coords_pyramid(cfg.ae, coords)
    tx = opt.adam(cfg.scaled_lr)
    if state is None:
        state = init_state(cfg, jax.random.key(cfg.seed), tx)
    train_step = make_train_step(cfg, levels, tx)
    rng = jax.random.key(cfg.seed + 1)

    # Paper: "the ML workload must query the database multiple times while
    # waiting for the first training snapshot".
    client.wait_for_data(cfg.table, minimum=cfg.min_snapshots,
                         timeout=cfg.wait_timeout_s)

    # Standardization stats from the first gather, published as metadata.
    mu_sd = client.get_metadata("norm_stats")
    if mu_sd is None:
        rng, k = jax.random.split(rng)
        first, _, ok = client.sample_batch(cfg.table, cfg.gather, k)
        batch = first.transpose(0, 2, 1)            # [G, N, C]
        mu, sd = _standardize_stats(batch)
        client.put_metadata("norm_stats", (mu, sd))
        mu_sd = (mu, sd)
    mu, sd = mu_sd

    history: list[EpochResult] = []
    epoch_timer_start = time.perf_counter()
    for epoch in range(cfg.epochs):
        if stop_event is not None and stop_event.is_set():
            break
        rng, k_samp, k_val, k_perm = jax.random.split(rng, 4)
        # --- gather (paper: "6 arrays of training data are gathered and
        # concatenated before the distributed … optimization is applied")
        vals, keys, ok = client.sample_batch(cfg.table, cfg.gather, k_samp)
        data = (vals.transpose(0, 2, 1) - mu) / sd   # [G, N, C]
        # --- hold one tensor out at random for validation (paper §4)
        val_idx = jax.random.randint(k_val, (), 0, cfg.gather)
        val = data[val_idx][None]
        mask = jnp.arange(cfg.gather) != val_idx
        train = data[mask]

        # --- mini-batch SGD over the gathered tensors
        n = train.shape[0]
        perm = jax.random.permutation(k_perm, n)
        train = train[perm]
        losses = []
        with client.timers.time("train"):
            for lo in range(0, n, cfg.batch_size):
                batch = train[lo: lo + cfg.batch_size]
                state, loss = train_step(state, batch)
                losses.append(loss)
            jax.block_until_ready(state.params)
        train_loss = float(jnp.mean(jnp.stack(losses)))

        rec = ae.reconstruct(state.params, cfg.ae, levels, val)
        val_loss = float(jnp.mean(jnp.square(rec - val)))
        val_err = float(ae.rel_frobenius(val, rec))
        res = EpochResult(epoch=epoch, train_loss=train_loss,
                          val_loss=val_loss, val_rel_error=val_err,
                          watermark=client.watermark(cfg.table))
        history.append(res)
        if on_epoch is not None:
            on_epoch(res)
    client.timers.record("total_training",
                         time.perf_counter() - epoch_timer_start)
    return state, history, levels, (mu, sd)
