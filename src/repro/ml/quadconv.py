"""QuadConv: quadrature-based continuous convolution (Doherty et al. 2023).

The operator behind the paper's autoencoder (§4).  A continuous convolution
over a *non-uniform* point cloud is approximated with one quadrature sum,

    (K ∗ f)(x_j) ≈ Σ_i  w_i · K_θ(x_j − y_i) · f(y_i),

where both the quadrature weights ``w_i`` and the kernel ``K_θ`` (a 5-layer
MLP mapping 3-D offsets to an O×C matrix, paper: R³ → R^{16×16}) are learned.
Compact support is enforced with a smooth bump window so kernels stay local
on the stretched boundary-layer grid.

The pairwise contraction (the FLOPs hot spot) is delegated to
``repro.kernels.quadconv`` (Pallas on TPU, oracle on CPU).  The MLP kernel
evaluation over J×I offsets is a plain batched MLP and is left to XLA.

Spectral normalization from the original QuadConv MLPs is omitted — the
paper removes it "to ensure traceability for online inference"; we keep
LayerNorm between autoencoder blocks instead (see ``autoencoder.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..kernels.quadconv import quadconv_contract

__all__ = ["QuadConv", "mlp_init", "mlp_apply"]


def mlp_init(key, sizes: tuple[int, ...], scale: float = 1.0) -> list[dict]:
    """Plain MLP params: list of {w,b}; he-style init, small final layer."""
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (din, dout) in enumerate(zip(sizes[:-1], sizes[1:])):
        std = jnp.sqrt(2.0 / din)
        if i == len(sizes) - 2:
            std = std * scale
        params.append({
            "w": jax.random.normal(keys[i], (din, dout)) * std,
            "b": jnp.zeros((dout,)),
        })
    return params


def mlp_apply(params: list[dict], x: jax.Array) -> jax.Array:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.gelu(x)
    return x


def _bump(d2: jax.Array, r: float) -> jax.Array:
    """C¹ compact-support window: (max(0, 1 − (d/r)²))²."""
    return jnp.square(jnp.maximum(0.0, 1.0 - d2 / (r * r)))


@dataclass(frozen=True)
class QuadConv:
    """One QuadConv layer: I input points/C channels → J output points/O.

    Static hyper-parameters only; learned state lives in the params dict so
    the layer is a pure function (jit/pjit friendly).
    """

    c_in: int
    c_out: int
    mlp_width: int = 32
    mlp_depth: int = 5          # paper: five-layer filter MLPs
    support: float = 0.75       # compact-support radius (domain units)
    mode: str | None = None     # kernel dispatch: None=auto|"ref"|"interpret"

    def init(self, key, n_in_points: int) -> dict:
        km, kw = jax.random.split(key)
        sizes = (3,) + (self.mlp_width,) * (self.mlp_depth - 1) \
            + (self.c_out * self.c_in,)
        return {
            # learned quadrature weights, init to uniform rule 1/I
            "quad_w": jnp.full((n_in_points,), 1.0 / n_in_points),
            "mlp": mlp_init(km, sizes, scale=0.3),
            "bias": jnp.zeros((self.c_out,)),
        }

    def kernel_tensor(self, params: dict, coords_out: jax.Array,
                      coords_in: jax.Array) -> jax.Array:
        """G[j,i,o,c] = MLP(x_j − y_i) ⊙ bump(|x_j − y_i|)."""
        deltas = coords_out[:, None, :] - coords_in[None, :, :]   # [J,I,3]
        j, i, _ = deltas.shape
        g = mlp_apply(params["mlp"], deltas.reshape(j * i, 3))
        g = g.reshape(j, i, self.c_out, self.c_in)
        win = _bump(jnp.sum(deltas * deltas, -1), self.support)   # [J,I]
        return g * win[:, :, None, None]

    def apply(self, params: dict, f: jax.Array, coords_in: jax.Array,
              coords_out: jax.Array) -> jax.Array:
        """f: [B, I, C_in] → [B, J, C_out]."""
        g = self.kernel_tensor(params, coords_out, coords_in)
        out = quadconv_contract(f, params["quad_w"], g, self.mode)
        return out + params["bias"]
