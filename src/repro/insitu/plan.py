"""Plan: tier selection as *data*.

Before this module, each layer of the stack picked its own fast path with
local control flow — ``ml.trainer`` chose between three epoch
constructors, ``core.client`` chose single vs multi-rank capture,
``launch/insitu`` chose per-verb vs fused producers — the same decision
tree duplicated in four files.  A :class:`Plan` freezes those decisions
into one inspectable value: per component it records the chosen tier, the
chunk/bucket policy, the mesh slice, and the *predicted* store dispatch
count; ``explain()`` renders the whole thing (including compiled-HLO
collective counts when the session resolved them), and the parity tests
verify the predictions against ``StoreServer.stats()["op_count"]`` and
``analysis/hlo`` ground truth.

Tier names
----------

=============  =====================================================
producer       ``per_verb`` | ``capture_scan`` | ``capture_scan_multi``
trainer        ``per_verb`` | ``fused`` | ``sharded_fused``
inference      ``fused_registry`` | ``three_step``
=============  =====================================================
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from ..core import store as S

__all__ = [
    "PRODUCER_TIERS", "TRAINER_TIERS", "INFERENCE_TIERS",
    "producer_tier", "trainer_tier", "inference_tier",
    "default_chunk", "ComponentPlan", "Plan",
    "producer_dispatches", "trainer_dispatches", "inference_dispatches",
]

PRODUCER_TIERS = ("per_verb", "capture_scan", "capture_scan_multi")
TRAINER_TIERS = ("per_verb", "fused", "sharded_fused")
INFERENCE_TIERS = ("fused_registry", "three_step")


def producer_tier(comp) -> str:
    """Resolve a :class:`~.components.Producer`'s tier.

    Forced tiers are validated; otherwise: non-traceable steps pin the
    per-verb tier, traceable single-rank steps take ``capture_scan``,
    multi-rank steps take ``capture_scan_multi``.
    """
    if comp.tier is not None:
        if comp.tier not in PRODUCER_TIERS:
            raise ValueError(f"unknown producer tier {comp.tier!r} "
                             f"(have {PRODUCER_TIERS})")
        if comp.tier != "per_verb" and not comp.traceable:
            raise ValueError(f"tier {comp.tier!r} needs a traceable step_fn")
        if comp.tier == "capture_scan" and comp.ranks > 1:
            raise ValueError("capture_scan is single-rank; use "
                             "capture_scan_multi or ranks=1")
        if comp.tier == "capture_scan_multi" and comp.ranks == 1:
            raise ValueError("capture_scan_multi needs ranks > 1")
        return comp.tier
    if not comp.traceable:
        return "per_verb"
    return "capture_scan" if comp.ranks == 1 else "capture_scan_multi"


def trainer_tier(cfg, override: str | None = None) -> str:
    """Resolve a trainer tier from a ``TrainerConfig`` (the rule
    ``ml.trainer.insitu_train`` consults when no plan names one)."""
    if override is not None:
        if override not in TRAINER_TIERS:
            raise ValueError(f"unknown trainer tier {override!r} "
                             f"(have {TRAINER_TIERS})")
        if override == "sharded_fused" and cfg.mesh is None:
            raise ValueError("sharded_fused needs cfg.mesh")
        if override != "sharded_fused" and cfg.mesh is not None:
            raise ValueError(
                f"cfg.mesh is set; tier {override!r} would ignore it")
        if override != "per_verb" and not cfg.fused:
            raise ValueError(f"tier {override!r} needs cfg.fused=True")
        return override
    if not cfg.fused:
        return "per_verb"
    return "sharded_fused" if cfg.mesh is not None else "fused"


def inference_tier(comp) -> str:
    if comp.tier is not None:
        if comp.tier not in INFERENCE_TIERS:
            raise ValueError(f"unknown inference tier {comp.tier!r} "
                             f"(have {INFERENCE_TIERS})")
        return comp.tier
    return "fused_registry"


def default_chunk(emit_every: int) -> int:
    """The fused producer's default chunk length (steps per dispatch)."""
    return max(8 * emit_every, 8)


@dataclass(frozen=True)
class ComponentPlan:
    """One component's frozen execution decision."""

    name: str
    kind: str                    # "producer" | "trainer" | "inference"
    tier: str
    table: str | None = None
    ranks: int = 1
    steps: int = 0               # producer steps / trainer epochs / inf calls
    chunk: int = 0               # fused producer: steps per dispatch
    bucketed: bool = False
    mesh_devices: int = 1        # sharded trainer: devices in its slice
    #: predicted store dispatches this component will perform, by cause.
    dispatches: tuple[tuple[str, int], ...] = ()
    #: collective-op counts from compiled HLO of the component's hot path
    #: (``None`` until the session resolved them with ``plan(hlo=True)``).
    collectives: tuple[tuple[str, int], ...] | None = None

    @property
    def store_dispatches(self) -> int:
        return sum(n for _, n in self.dispatches)

    def explain(self) -> dict:
        out: dict[str, Any] = {
            "tier": self.tier,
            "store_dispatches": self.store_dispatches,
            "dispatch_detail": dict(self.dispatches),
        }
        if self.kind == "producer":
            out["ranks"] = self.ranks
            out["dispatches_per_step"] = \
                self.store_dispatches / max(1, self.steps)
            if self.tier != "per_verb":
                out["chunk"] = self.chunk
                out["bucketed"] = self.bucketed
        if self.kind == "trainer":
            d = dict(self.dispatches)
            out["dispatches_per_epoch"] = \
                d.get("epoch", 0) / max(1, self.steps)
            out["mesh_devices"] = self.mesh_devices
        if self.collectives is not None:
            out["collectives"] = dict(self.collectives)
        return out


@dataclass(frozen=True)
class Plan:
    """The session's full execution decision, frozen.

    ``components`` follow the session's declaration order (trainer
    replicas expand to one entry each).  The dispatch predictions assume a
    fresh store; sequential runs make them exact per component, while
    concurrent multi-consumer runs may race the one-off norm-stats
    bootstrap between replicas, shifting which replica pays it.
    """

    deployment: str
    components: tuple[ComponentPlan, ...]

    def __post_init__(self):
        names = [c.name for c in self.components]
        dups = {n for n in names if names.count(n) > 1}
        if dups:
            raise ValueError(
                f"component names collide after normalization: "
                f"{sorted(dups)} — rename the explicit components "
                f"(count-expanded replicas claim '<name>0..<name>N-1')")

    def component(self, name: str) -> ComponentPlan:
        for c in self.components:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def store_dispatches(self) -> int:
        """Predicted total store dispatches for one session run."""
        return sum(c.store_dispatches for c in self.components)

    def explain(self) -> dict:
        """Chosen tiers, expected dispatch counts, and (when resolved)
        compiled-HLO collective counts — the whole *how* as one dict."""
        return {
            "deployment": self.deployment,
            "store_dispatches": self.store_dispatches,
            "components": {c.name: c.explain() for c in self.components},
        }

    def describe(self) -> str:
        """One line per component, for logs and reports."""
        lines = [f"deployment: {self.deployment}"]
        for c in self.components:
            bits = [f"tier={c.tier}", f"dispatches={c.store_dispatches}"]
            if c.kind == "producer":
                bits.append(f"ranks={c.ranks}")
                if c.tier != "per_verb":
                    bits.append(f"chunk={c.chunk}"
                                + ("+bucketed" if c.bucketed else ""))
            if c.kind == "trainer" and c.mesh_devices > 1:
                bits.append(f"mesh={c.mesh_devices}dev")
            lines.append(f"  {c.name} [{c.kind}]: " + " ".join(bits))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Dispatch predictions (used by the session's resolver)
# ---------------------------------------------------------------------------

def producer_dispatches(tier: str, steps: int, emit_every: int,
                        ranks: int, chunk: int) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of a producer run, by cause.

    Per-verb: one ``put`` per rank per emitting step.  Fused: one capture
    per chunk (``ceil(steps / chunk)``) — bucketing pads executables, not
    dispatches.
    """
    if tier == "per_verb":
        return (("put", ranks * S.capture_emit_count(steps, emit_every)),)
    return (("capture", -(-steps // chunk)),)


def trainer_dispatches(tier: str, epochs: int, bootstrap: bool
                       ) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of one trainer replica.

    Every tier costs one store dispatch per epoch — a fused/sharded
    capture, or the per-verb tier's single ``sample`` (its extra
    per-mini-batch dispatches are host compute, not store ops) — plus the
    one-off norm-stats bootstrap sample for the replica that pays it.
    """
    out = [("epoch", epochs)]
    if bootstrap:
        out.append(("norm_bootstrap", 1))
    return tuple(out)


def inference_dispatches(tier: str, steps: int) -> tuple[tuple[str, int], ...]:
    """Fused registry calls never touch the store; the three-step protocol
    costs put(1) + run_model's get-in/put-out(2) + get(1) per step."""
    if tier == "fused_registry":
        return ()
    return (("three_step", 4 * steps),)
