"""Plan: tier selection as *data*.

Before this module, each layer of the stack picked its own fast path with
local control flow — ``ml.trainer`` chose between three epoch
constructors, ``core.client`` chose single vs multi-rank capture,
``launch/insitu`` chose per-verb vs fused producers — the same decision
tree duplicated in four files.  A :class:`Plan` freezes those decisions
into one inspectable value: per component it records the chosen tier, the
chunk/bucket policy, the mesh slice, and the *predicted* store dispatch
count; ``explain()`` renders the whole thing (including compiled-HLO
collective counts when the session resolved them), and the parity tests
verify the predictions against ``StoreServer.stats()["op_count"]`` and
``analysis/hlo`` ground truth.

Tier names
----------

=============  =====================================================
producer       ``per_verb`` | ``capture_scan`` | ``capture_scan_multi``
               | ``capture_scan_sharded``
trainer        ``per_verb`` | ``fused`` | ``sharded_fused`` |
               ``slab_sharded`` | ``slab_sharded_clustered``
inference      ``fused_registry`` | ``three_step``
=============  =====================================================

The ``capture_scan_sharded`` tier is ``capture_scan`` for a
domain-decomposed producer (``Producer.elem_sharding`` set, e.g.
``sim.distributed.make_producer``): same chunking, dispatch and staging
economics, but every emitted element is pinned to the producer's own
layout so the put is a shard-local slab update — and on a co-located
multi-device mesh the plan *claims* the compiled chunk's only collective
is the solver's own halo exchange (``collective-permute`` nonzero,
``all-gather`` zero; :func:`sharded_producer_prediction`).

Besides dispatch counts, a plan predicts each component's *collective
structure* (``predicted_collectives``): which collective ops the compiled
hot path must / must not contain — the put path is collective-free under
**every** deployment (clustered included: its interconnect hop is a
host-driven staged reshard, never an in-program collective), the sharded
epochs contain the DDP all-reduce, and the slab-sharded epochs must NOT
all-gather the table on entry.  ``plan(hlo=True)`` measures the ground
truth from compiled HLO; the tests compare the two.

Clustered deployments additionally get *staged-transfer* predictions
(``ComponentPlan.staged`` / ``staged_transfers``): how many cross-mesh
hops each component pays — one per put verb on the per-verb tier, exactly
ONE per ``capture_scan`` chunk on the fused tiers, one per epoch for the
staged clustered gather — verified exactly against
``StoreServer.stats()["staged_transfers"]``, with the deployment's
producer:db ``fan_in`` ratio reported by ``Plan.explain()``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from ..analysis.hlo import COLLECTIVE_OPS
from ..core import store as S
from ..core.deployment import fan_in_ratio

__all__ = [
    "PRODUCER_TIERS", "TRAINER_TIERS", "INFERENCE_TIERS", "SERVING_TIERS",
    "producer_tier", "trainer_tier", "inference_tier", "serving_tier",
    "default_chunk", "autotune_chunk", "ContentionModel", "fan_in_ratio",
    "ComponentPlan", "Plan",
    "producer_dispatches", "trainer_dispatches", "inference_dispatches",
    "producer_staged", "trainer_staged", "inference_staged",
    "clients_dispatches", "clients_staged",
    "serving_dispatches", "serving_staged", "serving_swaps",
    "TRAINER_COLLECTIVE_PREDICTIONS", "COLLECTIVE_FREE",
    "trainer_collective_prediction", "sharded_producer_prediction",
    "VERB_CAUSES", "UNPLANNED_VERBS",
]

PRODUCER_TIERS = ("per_verb", "capture_scan", "capture_scan_multi",
                  "capture_scan_sharded")
TRAINER_TIERS = ("per_verb", "fused", "sharded_fused", "slab_sharded",
                 "slab_sharded_clustered")
INFERENCE_TIERS = ("fused_registry", "three_step")
SERVING_TIERS = ("continuous_batch", "three_step")

#: Plan <-> runtime verb-parity contract, machine-checked by repro-lint's
#: ``parity-verb`` rule: every ``op_count``-incrementing public verb on
#: :class:`~repro.core.server.StoreServer` must appear in exactly one of
#: these two tables, and every declared verb must still exist on the
#: server.  ``VERB_CAUSES`` maps a verb to the dispatch-prediction cause
#: labels (the first element of the ``(cause, count)`` pairs the
#: ``*_dispatches`` functions emit) that account for it in a planned
#: run; a verb listed here and missing from a component's prediction
#: would skew ``Plan.explain()``.
VERB_CAUSES: dict[str, tuple[str, ...]] = {
    "put": ("put", "request", "three_step"),
    "get": ("get", "response", "three_step"),
    "capture": ("capture", "drain", "epoch"),
    "sample": ("epoch", "norm_bootstrap"),
    "sample_staged": ("epoch",),
    "serve_batch": ("serve",),
}

#: Verbs no planned component dispatches (utility/baseline API:
#: explicit-commit, batched convenience puts/gets, polling, deletion,
#: occupancy probes).  They still bump ``op_count``, so exactness tests
#: must not interleave them with a measured window.
UNPLANNED_VERBS: tuple[str, ...] = (
    "commit", "put_many", "put_stream", "get_many", "latest", "poll",
    "delete", "valid_count",
)


def producer_tier(comp) -> str:
    """Resolve a :class:`~.components.Producer`'s tier.

    Forced tiers are validated; otherwise: non-traceable steps pin the
    per-verb tier, a set ``elem_sharding`` takes ``capture_scan_sharded``
    (single-rank: the one rank IS the whole device mesh), traceable
    single-rank steps take ``capture_scan``, multi-rank steps take
    ``capture_scan_multi``.
    """
    sharded = getattr(comp, "elem_sharding", None) is not None
    if sharded and not comp.traceable:
        raise ValueError("elem_sharding needs a traceable step_fn: the "
                         "sharded put only exists inside the fused capture")
    if sharded and comp.ranks > 1:
        raise ValueError(
            "elem_sharding is single-rank (ranks=1): a domain-decomposed "
            "producer is ONE rank spread over the mesh — its parallelism "
            "is the sharding, not a vmapped rank axis")
    if comp.tier is not None:
        if comp.tier not in PRODUCER_TIERS:
            raise ValueError(f"unknown producer tier {comp.tier!r} "
                             f"(have {PRODUCER_TIERS})")
        if comp.tier != "per_verb" and not comp.traceable:
            raise ValueError(f"tier {comp.tier!r} needs a traceable step_fn")
        if comp.tier == "capture_scan" and comp.ranks > 1:
            raise ValueError("capture_scan is single-rank; use "
                             "capture_scan_multi or ranks=1")
        if comp.tier == "capture_scan_multi" and comp.ranks == 1:
            raise ValueError("capture_scan_multi needs ranks > 1")
        if comp.tier == "capture_scan_sharded" and not sharded:
            raise ValueError("capture_scan_sharded needs elem_sharding "
                             "(the producer's own element layout)")
        if sharded and comp.tier not in ("per_verb", "capture_scan_sharded"):
            raise ValueError(
                f"tier {comp.tier!r} would drop the declared elem_sharding; "
                f"use capture_scan_sharded (or per_verb to measure the "
                f"unfused baseline)")
        return comp.tier
    if not comp.traceable:
        return "per_verb"
    if sharded:
        return "capture_scan_sharded"
    return "capture_scan" if comp.ranks == 1 else "capture_scan_multi"


def trainer_tier(cfg, override: str | None = None) -> str:
    """Resolve a trainer tier from a ``TrainerConfig`` (the rule
    ``ml.trainer.insitu_train`` consults when no plan names one)."""
    mesh_tiers = ("sharded_fused", "slab_sharded", "slab_sharded_clustered")
    slab_tiers = ("slab_sharded", "slab_sharded_clustered")
    if override is not None:
        if override not in TRAINER_TIERS:
            raise ValueError(f"unknown trainer tier {override!r} "
                             f"(have {TRAINER_TIERS})")
        if override in mesh_tiers and cfg.mesh is None:
            raise ValueError(f"{override} needs cfg.mesh")
        if override not in mesh_tiers and cfg.mesh is not None:
            raise ValueError(
                f"cfg.mesh is set; tier {override!r} would ignore it")
        if override in slab_tiers and not cfg.slab_sharded:
            raise ValueError(f"{override} needs cfg.slab_sharded=True")
        if override not in slab_tiers and cfg.slab_sharded:
            raise ValueError(
                f"cfg.slab_sharded is set; tier {override!r} would pass "
                f"the table replicated")
        if override == "slab_sharded_clustered" and cfg.db_mesh is None:
            raise ValueError("slab_sharded_clustered needs cfg.db_mesh "
                             "(the store's dedicated mesh; a session "
                             "wires it from the Clustered deployment)")
        if override != "slab_sharded_clustered" and cfg.db_mesh is not None:
            raise ValueError(
                f"cfg.db_mesh is set; tier {override!r} would ignore the "
                f"dedicated store mesh")
        if override != "per_verb" and not cfg.fused:
            raise ValueError(f"tier {override!r} needs cfg.fused=True")
        return override
    if not cfg.fused:
        return "per_verb"
    if cfg.mesh is None:
        return "fused"
    if cfg.slab_sharded:
        return "slab_sharded_clustered" if cfg.db_mesh is not None \
            else "slab_sharded"
    return "sharded_fused"


def inference_tier(comp) -> str:
    if comp.tier is not None:
        if comp.tier not in INFERENCE_TIERS:
            raise ValueError(f"unknown inference tier {comp.tier!r} "
                             f"(have {INFERENCE_TIERS})")
        return comp.tier
    return "fused_registry"


def serving_tier(comp) -> str:
    """Resolve a :class:`~.components.ServingConsumer`'s tier: the fused
    continuous-batching drain by default; ``three_step`` forces the
    paper's one-request-at-a-time get → run_model → put baseline."""
    if comp.tier is not None:
        if comp.tier not in SERVING_TIERS:
            raise ValueError(f"unknown serving tier {comp.tier!r} "
                             f"(have {SERVING_TIERS})")
        return comp.tier
    return "continuous_batch"


def default_chunk(emit_every: int) -> int:
    """The fused producer's default chunk length (steps per dispatch):
    one bucket floor's worth of emissions (``store.MIN_BUCKET`` — the
    SAME constant the data plane's ``store.bucket_length`` pads to, so
    the default chunk always lands exactly on a bucket boundary and the
    plan's compile-cache prediction cannot drift from actual
    bucketing)."""
    return max(S.MIN_BUCKET * emit_every, S.MIN_BUCKET)


@dataclass(frozen=True)
class ContentionModel:
    """The fan-in contention model: predicted producer throughput
    (steps/s) as a function of the clients-per-shard ``fan_in`` ratio,
    fitted from a measured dispatch-cost sweep.

    The model is the paper's Fig.-5 story made quantitative: per step,
    the clustered fused tier pays a base cost (solver compute + its
    share of the per-chunk collect/insert dispatch overhead) plus a
    staging term proportional to how many clients contend for the
    busiest db shard,

        t_step(fan_in) = t_base + k_fanin * fan_in
        steps_per_s    = 1 / t_step

    ``k_fanin`` is the marginal per-step cost of one more client per
    shard (staged bytes / effective shard bandwidth); its *sign* is
    fitted, not assumed — on emulated single-host meshes more db devices
    can cost more than shard contention saves, and the model reports
    what the wire measured.  ``fit`` is an ordinary least-squares line
    through ``(fan_in, 1/steps_per_s)`` sweep cells; ``residual``
    reports the worst relative throughput error over the cells it was
    fitted from (the bench gate).
    """

    t_base: float               # seconds/step at fan_in -> 0
    k_fanin: float              # marginal seconds/step per fan-in unit
    step_bytes: float = 0.0     # staged payload bytes per producer step
    #: fixed per-capture host overhead (seconds/dispatch) from the
    #: measured dispatch-cost curve — the autotuner's amortization term.
    t_dispatch: float = 0.0

    @classmethod
    def fit(cls, cells) -> "ContentionModel":
        """Least-squares fit from sweep cells — any iterable of mappings
        with ``fan_in`` and ``steps_per_s`` (and optionally
        ``step_bytes``).  Needs >= 2 distinct fan-in points."""
        pts = sorted({(float(c["fan_in"]), 1.0 / float(c["steps_per_s"]))
                      for c in cells})
        xs = [x for x, _ in pts]
        ys = [y for _, y in pts]
        if len(set(xs)) < 2:
            raise ValueError(
                f"contention fit needs >= 2 distinct fan_in points, got "
                f"{sorted(set(xs))}")
        n = float(len(xs))
        mx, my = sum(xs) / n, sum(ys) / n
        sxx = sum((x - mx) ** 2 for x in xs)
        sxy = sum((x - mx) * (y - my) for x, y in zip(xs, ys))
        k = sxy / sxx
        bites = [float(c.get("step_bytes", 0.0)) for c in cells]
        return cls(t_base=my - k * mx, k_fanin=k,
                   step_bytes=max(bites) if bites else 0.0)

    def predict_steps_per_s(self, fan_in: int) -> float:
        t = self.t_base + self.k_fanin * float(fan_in)
        if t <= 0:
            # an extrapolation below the fitted range hit the axis — the
            # model has nothing honest to say there
            raise ValueError(
                f"contention model predicts non-positive step time "
                f"{t:.3g}s at fan_in={fan_in} (t_base={self.t_base:.3g}, "
                f"k_fanin={self.k_fanin:.3g}) — fit covers too narrow a "
                f"sweep to extrapolate this far")
        return 1.0 / t

    def residual(self, cells) -> float:
        """Worst relative throughput error of the fitted line over
        ``cells`` (the bench gate's fit-quality number)."""
        return max(abs(self.predict_steps_per_s(c["fan_in"])
                       / float(c["steps_per_s"]) - 1.0) for c in cells)


def autotune_chunk(emit_every: int, model: ContentionModel | None = None,
                   dispatch_cost: float | None = None,
                   steps: int | None = None,
                   fan_in: int = 1, max_chunk: int = 512) -> int:
    """Pick the fused producer's chunk length from the fitted cost model.

    Candidates are the power-of-two bucket boundaries from the data
    plane's floor upward (``store.bucket_length`` over ``store.
    MIN_BUCKET`` emissions — the same bucket grid the executables compile
    on, so the tuned chunk is always cache-exact); the winner minimizes
    the model's predicted wall time for the whole ``steps``-step run:

        ceil(steps/chunk) * (dispatch_cost + chunk * t_step(fan_in))
        + dispatch_cost                                  # the drain

    A costlier measured dispatch pushes toward longer chunks (fewer
    captures to pay for); a longer chunk wastes bucket-padded tail steps
    (the scan runs the full bucket, masked or not), which pulls back
    toward the floor.  Without a fitted model this is exactly
    :func:`default_chunk` — the static ``max(8 * emit_every, 8)`` floor
    the autotuner replaces, kept as the un-fitted fallback.
    """
    if model is None:
        return default_chunk(emit_every)
    if dispatch_cost is None:
        dispatch_cost = model.t_dispatch
    try:
        t_step = 1.0 / model.predict_steps_per_s(fan_in)
    except ValueError:
        # fan_in outside the fitted sweep: fall back to the static floor
        return default_chunk(emit_every)
    total = int(steps) if steps else max_chunk
    floor = S.bucket_length(S.MIN_BUCKET * emit_every)
    candidates = []
    c = floor
    while c <= max(floor, max_chunk):
        candidates.append(c)
        c *= 2

    def wall(n: int) -> float:
        n_chunks = -(-total // n)
        return n_chunks * (dispatch_cost + n * t_step) + dispatch_cost

    return min(candidates, key=wall)


def _pred(**nonzero: bool) -> tuple[tuple[str, bool], ...]:
    """Collective-structure prediction: op name -> must-be-nonzero flag
    (keyword names use ``_`` for ``-``)."""
    return tuple((op, bool(nonzero.get(op.replace("-", "_"), False)))
                 for op in COLLECTIVE_OPS)


#: Prediction for any hot path that must compile collective-free (the
#: co-located put, the single-device epochs).
COLLECTIVE_FREE: tuple[tuple[str, bool], ...] = _pred()

#: Structural collective predictions per trainer tier *on a
#: replicated-placed table*, verified against ``plan(hlo=True)`` ground
#: truth in the tests.  Both mesh tiers carry the DDP all-reduce; the
#: slab-sharded tier *additionally* promises the table is NOT
#: all-gathered on entry (``all-gather`` stays zero — its batch-assembly
#: collective is the explicit ``psum``, which lowers to an all-reduce
#: and rides the same flag).  Use :func:`trainer_collective_prediction`
#: to resolve the placement-dependent cases.
TRAINER_COLLECTIVE_PREDICTIONS: dict[str, tuple[tuple[str, bool], ...]] = {
    "per_verb": COLLECTIVE_FREE,
    "fused": COLLECTIVE_FREE,
    "sharded_fused": _pred(all_reduce=True),
    "slab_sharded": _pred(all_reduce=True),
    # db-side gather psum + client-side DDP psum; the cross-mesh hop
    # itself is a staged reshard, never an in-program collective — and
    # the table is never all-gathered (the slab stays on the db mesh).
    "slab_sharded_clustered": _pred(all_reduce=True),
}


def trainer_collective_prediction(tier: str, table_sharded: bool = False
                                  ) -> tuple[tuple[str, bool], ...] | None:
    """Collective-structure prediction for one trainer entry.

    ``table_sharded``: the table this trainer reads is *placed*
    partitioned across more than one device (a slab-sharded trainer's
    placement, or a sharded co-located deployment).  That flips the
    replicated-entry mesh tier's claim: ``sharded_fused`` reading a
    sharded-placed table all-gathers the slab on entry — by design the
    anti-pattern the ``slab_sharded`` tier removes, and exactly what the
    contrast assertion in the tests proves.  The single-device ``fused``
    tier's structure on a sharded table is placement-dependent, so the
    plan makes no claim there (``None``).  The clustered staged tier
    never ingests the table into its shard_map at all, so its claim is
    placement-independent.
    """
    if tier == "slab_sharded_clustered":
        return TRAINER_COLLECTIVE_PREDICTIONS[tier]
    if table_sharded and tier == "sharded_fused":
        return _pred(all_reduce=True, all_gather=True)
    if table_sharded and tier == "fused":
        return None
    return TRAINER_COLLECTIVE_PREDICTIONS[tier]


def sharded_producer_prediction(elem_sharding, colocated: bool
                                ) -> tuple[tuple[str, bool], ...] | None:
    """Collective-structure prediction for a ``capture_scan_sharded``
    producer's compiled chunk.

    The claim: the shard-local put adds **no cross-shard collective
    beyond the producer's own halo exchange** — the chunk compiles with
    ``collective-permute`` nonzero (the ``lax.ppermute`` neighbor faces)
    and everything else, ``all-gather`` above all, zero.  The plan only
    makes it where it is structural:

    * **co-located, > 1 shard** — the table slab carries the same element
      layout as the emission, so the put is a local dynamic-update-slice
      and the halo ppermute is the whole collective story.
    * elsewhere ``None`` (no claim): a *local* (placement-free)
      deployment leaves the slab unplaced, so the compiler may legally
      funnel the sharded emission through one device; a *clustered* chunk
      splits into a client-side collect and a db-side insert with the hop
      staged between them; and a 1-shard mesh's ppermute can fold away.
    """
    if not colocated or elem_sharding is None:
        return None
    if getattr(elem_sharding, "num_devices", 1) <= 1 \
            or getattr(elem_sharding, "is_fully_replicated", False):
        return None
    return _pred(collective_permute=True)


@dataclass(frozen=True)
class ComponentPlan:
    """One component's frozen execution decision."""

    name: str
    kind: str                    # "producer" | "trainer" | "inference"
    tier: str
    table: str | None = None
    ranks: int = 1
    steps: int = 0               # producer steps / trainer epochs / inf calls
    chunk: int = 0               # fused producer: steps per dispatch
    bucketed: bool = False
    mesh_devices: int = 1        # sharded trainer: devices in its slice
    #: predicted store dispatches this component will perform, by cause.
    dispatches: tuple[tuple[str, int], ...] = ()
    #: predicted cross-mesh staged transfers (clustered deployments), by
    #: cause — verified against ``stats()["staged_transfers"]`` exactly.
    staged: tuple[tuple[str, int], ...] = ()
    #: collective-op counts from compiled HLO of the component's hot path
    #: (``None`` until the session resolved them with ``plan(hlo=True)``).
    collectives: tuple[tuple[str, int], ...] | None = None
    #: predicted collective structure of the hot path: op -> must the
    #: compiled HLO contain it?  (``None`` where the plan makes no claim,
    #: e.g. clustered staging.)  ``plan(hlo=True)``'s ``collectives`` is
    #: the measured truth these predictions are tested against.
    predicted_collectives: tuple[tuple[str, bool], ...] | None = None
    #: predicted transient-fault verb retries this component absorbs under
    #: the session's declared ``FaultPlan`` (``core.faults
    #: .simulate_overhead``; 0 on fault-free plans) — verified exactly
    #: against ``ComponentResult.retries``.  Replay ops / re-staged hops
    #: the faults cost land in ``dispatches`` ("replay") and ``staged``
    #: ("restage") so the exactness totals carry them automatically.
    retries: int = 0
    #: predicted crash-recovery restarts this component survives
    #: (producer: resume from the table watermark; trainer: from
    #: ``MemoryCheckpoint``) — verified against ``ComponentResult
    #: .restarts``.
    restarts: int = 0
    #: predicted model-generation adoptions (serving hot-swap) — verified
    #: exactly against ``stats()["model_swaps"]``.  0 everywhere but the
    #: continuous-batching serving tier.
    swaps: int = 0
    #: clients per db shard for THIS component's staged traffic
    #: (``fan_in_ratio`` — the same ceiling-division source
    #: ``Clustered.fan_in`` uses; 1 off clustered).
    fan_in: int = 1
    #: the contention model's predicted throughput for this component
    #: (producer steps/s at its ``fan_in``), resolved only when the
    #: session's deployment carries a fitted :class:`ContentionModel`.
    predicted_steps_per_s: float | None = None

    @property
    def store_dispatches(self) -> int:
        return sum(n for _, n in self.dispatches)

    @property
    def staged_transfers(self) -> int:
        """Predicted interconnect hops (0 off the clustered deployment)."""
        return sum(n for _, n in self.staged)

    def check_collectives(self) -> None:
        """Assert the measured HLO collective counts (``plan(hlo=True)``)
        match the predicted structure.  No-op when either side is
        unresolved."""
        if self.collectives is None or self.predicted_collectives is None:
            return
        measured = dict(self.collectives)
        for op, nonzero in self.predicted_collectives:
            got = measured.get(op, 0)
            if bool(got) != nonzero:
                raise AssertionError(
                    f"{self.name} [{self.tier}]: predicted {op} "
                    f"{'> 0' if nonzero else '== 0'}, compiled HLO has "
                    f"{got} (all: {measured})")

    def explain(self) -> dict:
        out: dict[str, Any] = {
            "tier": self.tier,
            "store_dispatches": self.store_dispatches,
            "dispatch_detail": dict(self.dispatches),
        }
        if self.staged:
            out["staged_transfers"] = self.staged_transfers
            out["staged_detail"] = dict(self.staged)
        if self.kind == "producer":
            out["ranks"] = self.ranks
            out["dispatches_per_step"] = \
                self.store_dispatches / max(1, self.steps)
            if self.tier != "per_verb":
                out["chunk"] = self.chunk
                out["bucketed"] = self.bucketed
                if self.staged:
                    # THE clustered fused claim: one hop per chunk capture
                    # (the overlap pipeline's final drain dispatch stages
                    # nothing, so it divides by captures, not dispatches)
                    captures = dict(self.dispatches).get(
                        "capture", self.store_dispatches)
                    out["staged_per_chunk"] = \
                        self.staged_transfers / max(1, captures)
            if self.staged:
                out["fan_in"] = self.fan_in
            if self.predicted_steps_per_s is not None:
                out["predicted_steps_per_s"] = self.predicted_steps_per_s
        if self.kind == "trainer":
            d = dict(self.dispatches)
            out["dispatches_per_epoch"] = \
                d.get("epoch", 0) / max(1, self.steps)
            out["mesh_devices"] = self.mesh_devices
        if self.kind == "clients":
            out["requests"] = self.steps
        if self.kind == "serving":
            d = dict(self.dispatches)
            out["requests"] = self.steps
            out["drained_batches"] = d.get("serve", 0)
            out["model_swaps"] = self.swaps
            if self.tier == "continuous_batch":
                # THE serving claim: one fused dispatch per drained batch
                out["dispatches_per_batch"] = \
                    self.store_dispatches / max(1, d.get("serve", 0))
        if self.retries or self.restarts:
            out["fault_overhead"] = {"retries": self.retries,
                                     "restarts": self.restarts}
        if self.predicted_collectives is not None:
            out["predicted_collectives"] = {
                op: ("nonzero" if nz else "zero")
                for op, nz in self.predicted_collectives}
        if self.collectives is not None:
            out["collectives"] = dict(self.collectives)
        return out


@dataclass(frozen=True)
class Plan:
    """The session's full execution decision, frozen.

    ``components`` follow the session's declaration order (trainer
    replicas expand to one entry each).  The dispatch predictions assume a
    fresh store; sequential runs make them exact per component, while
    concurrent multi-consumer runs may race the one-off norm-stats
    bootstrap between replicas, shifting which replica pays it.
    """

    deployment: str
    components: tuple[ComponentPlan, ...]
    #: clients per store shard (``Deployment.fan_in``; 1 off clustered) —
    #: the paper's Fig.-5 contention knob, carried so ``explain()`` can
    #: relate predicted staged traffic to the shard ratio that carries it.
    fan_in: int = 1
    #: declared-fault totals — ``core.faults.simulate_overhead``'s
    #: prediction of ``stats()``'s fault counters, as sorted
    #: ``(("faults_injected", n), ("recoveries", n), ("retries", n))``
    #: pairs; ``()`` when no ``FaultPlan`` is armed.
    faults: tuple[tuple[str, int], ...] = ()

    def __post_init__(self):
        names = [c.name for c in self.components]
        dups = {n for n in names if names.count(n) > 1}
        if dups:
            raise ValueError(
                f"component names collide after normalization: "
                f"{sorted(dups)} — rename the explicit components "
                f"(count-expanded replicas claim '<name>0..<name>N-1')")

    def component(self, name: str) -> ComponentPlan:
        for c in self.components:
            if c.name == name:
                return c
        raise KeyError(name)

    @property
    def store_dispatches(self) -> int:
        """Predicted total store dispatches for one session run."""
        return sum(c.store_dispatches for c in self.components)

    @property
    def staged_transfers(self) -> int:
        """Predicted total cross-mesh staged transfers (0 off clustered)."""
        return sum(c.staged_transfers for c in self.components)

    @property
    def model_swaps(self) -> int:
        """Predicted total model-generation adoptions (serving hot-swap;
        verified exactly against ``stats()["model_swaps"]``)."""
        return sum(c.swaps for c in self.components)

    def explain(self) -> dict:
        """Chosen tiers, expected dispatch counts, clustered staging
        traffic + fan-in, and (when resolved) compiled-HLO collective
        counts — the whole *how* as one dict."""
        out = {
            "deployment": self.deployment,
            "store_dispatches": self.store_dispatches,
            "components": {c.name: c.explain() for c in self.components},
        }
        if self.fan_in != 1 or self.staged_transfers:
            out["fan_in"] = self.fan_in
            out["staged_transfers"] = self.staged_transfers
        if self.model_swaps:
            out["model_swaps"] = self.model_swaps
        if self.faults:
            out["faults"] = dict(self.faults)
        return out

    def describe(self) -> str:
        """One line per component, for logs and reports."""
        lines = [f"deployment: {self.deployment}"]
        for c in self.components:
            bits = [f"tier={c.tier}", f"dispatches={c.store_dispatches}"]
            if c.kind == "producer":
                bits.append(f"ranks={c.ranks}")
                if c.tier != "per_verb":
                    bits.append(f"chunk={c.chunk}"
                                + ("+bucketed" if c.bucketed else ""))
            if c.kind == "trainer" and c.mesh_devices > 1:
                bits.append(f"mesh={c.mesh_devices}dev")
            if c.kind == "serving":
                bits.append(f"requests={c.steps} swaps={c.swaps}")
            if c.retries or c.restarts:
                bits.append(f"retries={c.retries} restarts={c.restarts}")
            lines.append(f"  {c.name} [{c.kind}]: " + " ".join(bits))
        if self.faults:
            f = dict(self.faults)
            lines.append(f"  faults: injected={f.get('faults_injected', 0)}"
                         f" retries={f.get('retries', 0)}"
                         f" recoveries={f.get('recoveries', 0)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# Dispatch predictions (used by the session's resolver)
# ---------------------------------------------------------------------------

def producer_dispatches(tier: str, steps: int, emit_every: int,
                        ranks: int, chunk: int, overlap: bool = False
                        ) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of a producer run, by cause.

    Per-verb: one ``put`` per rank per emitting step.  Fused: one capture
    per chunk (``ceil(steps / chunk)``) — bucketing pads executables, not
    dispatches.  ``overlap`` (the clustered two-slot staging pipeline)
    adds the ONE capture-end drain dispatch that inserts the final
    in-flight chunk — every chunk's insert runs one capture late, so the
    last one needs its own flush.
    """
    if tier == "per_verb":
        return (("put", ranks * S.capture_emit_count(steps, emit_every)),)
    out = (("capture", -(-steps // chunk)),)
    if overlap and steps > 0:
        out += (("drain", 1),)
    return out


def trainer_dispatches(tier: str, epochs: int, bootstrap: bool
                       ) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of one trainer replica.

    Every tier costs one store dispatch per epoch — a fused/sharded
    capture, or the per-verb tier's single ``sample`` (its extra
    per-mini-batch dispatches are host compute, not store ops) — plus the
    one-off norm-stats bootstrap sample for the replica that pays it.
    """
    out = [("epoch", epochs)]
    if bootstrap:
        out.append(("norm_bootstrap", 1))
    return tuple(out)


def inference_dispatches(tier: str, steps: int) -> tuple[tuple[str, int], ...]:
    """Fused registry calls never touch the store; the three-step protocol
    costs put(1) + run_model's get-in/put-out(2) + get(1) per step."""
    if tier == "fused_registry":
        return ()
    return (("three_step", 4 * steps),)


# ---------------------------------------------------------------------------
# Staged-transfer predictions (the clustered deployment's interconnect
# traffic; every function returns () off a cross-mesh deployment)
# ---------------------------------------------------------------------------

def producer_staged(tier: str, steps: int, emit_every: int, ranks: int,
                    chunk: int, crosses_mesh: bool
                    ) -> tuple[tuple[str, int], ...]:
    """Predicted cross-mesh hops of a producer run, by cause.

    Per-verb: every put verb stages its element — one hop per rank per
    emitting step (the paper's per-message clustered TCP cost).  Fused:
    the whole chunk crosses in ONE batched reshard per capture dispatch —
    ``ceil(steps / chunk)`` total, the O(k)→O(1) transfer claim.
    """
    if not crosses_mesh:
        return ()
    if tier == "per_verb":
        return (("elem_stage", ranks * S.capture_emit_count(steps,
                                                            emit_every)),)
    return (("chunk_stage", -(-steps // chunk)),)


def trainer_staged(tier: str, epochs: int, crosses_mesh: bool
                   ) -> tuple[tuple[str, int], ...]:
    """Predicted cross-mesh hops of one trainer replica: only the
    clustered staged tier moves bytes (one gathered batch per epoch);
    every other tier reads the table wherever it lives."""
    if crosses_mesh and tier == "slab_sharded_clustered":
        return (("gather_stage", epochs),)
    return ()


def inference_staged(tier: str, steps: int, crosses_mesh: bool
                     ) -> tuple[tuple[str, int], ...]:
    """The three-step protocol stages its put legs (input in, prediction
    out → 2 hops per step); the fused registry path never touches the
    store."""
    if crosses_mesh and tier == "three_step":
        return (("put_stage", 2 * steps),)
    return ()


# ---------------------------------------------------------------------------
# Serving-plane predictions (the request/response queue + the drain)
# ---------------------------------------------------------------------------

def clients_dispatches(requests: int, submit: bool, collect: bool
                       ) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of one :class:`~.components
    .ServingClients` component over all its clients: one ``put`` per
    submitted request (the submission-watermark metadata bump is a host
    write — zero dispatches), one ``get`` per collected response (the
    results-watermark wait is the free cached poll)."""
    out = []
    if submit:
        out.append(("request", requests))
    if collect:
        out.append(("response", requests))
    return tuple(out)


def clients_staged(requests: int, submit: bool, crosses_mesh: bool
                   ) -> tuple[tuple[str, int], ...]:
    """Predicted cross-mesh hops of the serving clients: each submitted
    request's put stages its payload onto the store placement; response
    gets read in place and never stage."""
    if crosses_mesh and submit:
        return (("request_stage", requests),)
    return ()


def serving_dispatches(tier: str, requests: int, max_batch: int
                       ) -> tuple[tuple[str, int], ...]:
    """Predicted store dispatches of the serving drain.

    Continuous batching: ONE fused serve dispatch per drained batch —
    ``ceil(requests / max_batch)`` under canonical admission order (the
    round-robin discovery sweep makes the batch count invariant to
    arrival interleaving).  Three-step: one ``get`` plus one ``put`` per
    request (``run_model`` is registry compute, not a store op).
    """
    if tier == "three_step":
        return (("get", requests), ("put", requests))
    return (("serve", -(-requests // max_batch)),)


def serving_staged(tier: str, requests: int, crosses_mesh: bool
                   ) -> tuple[tuple[str, int], ...]:
    """Predicted cross-mesh hops of the serving drain: the fused serve
    dispatch runs entirely on the store placement (requests, model and
    responses colocated — zero hops); the three-step baseline stages each
    response put."""
    if crosses_mesh and tier == "three_step":
        return (("response_stage", requests),)
    return ()


def serving_swaps(tier: str) -> int:
    """Predicted model-generation adoptions for a sequential run: the
    continuous-batching loop binds exactly the one generation published
    before it drains (re-checks find nothing newer); the three-step
    baseline's ``run_model`` reads the registry directly and never
    binds."""
    return 1 if tier == "continuous_batch" else 0
