"""Declarative in-situ components: *what* runs, never *how*.

The paper's pitch is that coupling a simulation to ML should be "a single
call … each requiring a single line of code".  A component declaration is
that line: it names the workload (a producer step function, a trainer
config, a model key) and leaves every execution decision — per-verb vs
fused capture, single vs multi-rank capture, single-device vs sharded
epochs, device-slice assignment — to the session's :class:`~.plan.Plan`
resolver.  The same declaration therefore runs unmodified across the full
{colocated, clustered} x {per-verb, fused} x {1..R producers, 1..C
consumers} scenario grid.

Each component also has a typed ``*Output`` the session returns from
``run()`` (``SessionResult.outputs``), so results flow back without side
channels.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

from ..ml.trainer import EpochResult, TrainerConfig, TrainState

__all__ = [
    "Producer", "TrainerConsumer", "InferenceConsumer",
    "ServingClients", "ServingConsumer",
    "ProducerOutput", "TrainerOutput", "InferenceOutput",
    "ServingClientsOutput", "ServingOutput",
]


@dataclass
class Producer:
    """A data-producing component (the paper's simulation ranks).

    ``step_fn(carry, rank, t) -> (carry, key, value)`` is one rank's
    single step: advance the solver carry, return the key/value to store
    when step ``t`` emits.  With ``ranks > 1`` the carry pytree stacks the
    per-rank states on a leading ``[ranks]`` axis and the plan picks the
    multi-producer capture.  Mark ``traceable=False`` when the step cannot
    be traced (e.g. an emulated solver that sleeps) — the plan then pins
    the per-verb tier, calling ``step_fn`` eagerly with Python ints.
    """

    step_fn: Callable
    table: str
    steps: int
    ranks: int = 1
    carry: Any = None
    emit_every: int = 1
    traceable: bool = True
    chunk: int | None = None      # fused chunk length (None: plan default)
    bucket: bool = True           # pad tail chunks to their pow2 bucket
    tier: str | None = None       # force a producer tier (see plan module)
    #: NamedSharding of one emitted element (a domain-decomposed solver's
    #: own layout, e.g. ``sim.distributed.make_producer``).  Set -> the
    #: plan resolves the ``capture_scan_sharded`` tier: every put is
    #: pinned shard-local via ``store.capture_scan(elem_sharding=...)``.
    elem_sharding: Any = None
    warmup: bool = True           # pre-compile fused executables off-clock
    name: str = "producer"

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError("steps must be >= 1")
        if self.ranks < 1:
            raise ValueError("ranks must be >= 1")
        if self.emit_every < 1:
            raise ValueError("emit_every must be >= 1")


@dataclass
class ProducerOutput:
    steps: int


@dataclass
class TrainerConsumer:
    """A training component (the paper's distributed ML ranks).

    ``cfg`` carries the numerics (model, epochs, gather, batch, DDP wire);
    the *tier* — per-verb, fused, sharded-fused — is resolved by the plan
    from ``cfg`` unless forced via ``tier``.  ``count > 1`` declares
    multi-consumer training: the plan splits the visible devices into
    ``count`` disjoint mesh slices (``parallel.sharding.disjoint_data_meshes``),
    one trainer replica per slice, all sharing the one store; replicas
    offset ``cfg.seed`` by their index.  Set ``model_key`` to publish the
    trained encoder into the model registry (plus a ``"trained"``
    metadata flag) for downstream :class:`InferenceConsumer`\\ s.
    """

    cfg: TrainerConfig
    coords: Any
    count: int = 1
    tier: str | None = None
    model_key: str | None = None
    on_epoch: Callable[[EpochResult], None] | None = None
    #: publish a versioned checkpoint into the model registry every this
    #: many epochs (requires ``model_key``) — the hot-swap producer side.
    #: ``None``: publish only the final model, the historical behavior.
    publish_every: int | None = None
    name: str = "trainer"

    def __post_init__(self):
        if self.count < 1:
            raise ValueError("count must be >= 1")
        if self.publish_every is not None:
            if self.publish_every < 1:
                raise ValueError("publish_every must be >= 1")
            if self.model_key is None:
                raise ValueError("publish_every requires model_key")
        if self.count > 1 and self.cfg.mesh is not None:
            raise ValueError(
                "multi-consumer sessions own the device slicing: leave "
                "cfg.mesh unset and let the plan assign disjoint slices")


@dataclass
class TrainerOutput:
    steps: int
    state: TrainState
    history: list[EpochResult]
    levels: Any
    norm_stats: Any


@dataclass
class InferenceConsumer:
    """An in-situ inference component (paper §3.2 / Fig. 1b).

    Evaluates the registered model ``model_key`` on inputs produced by
    ``feed(client, step)``.  The default tier is the fused registry call
    (one dispatch, no store round-trip); forcing ``tier="three_step"``
    runs the paper's put → run_model → get protocol through scratch
    tables so each leg is measurable.  ``wait_meta`` blocks until a
    metadata flag (a trainer's ``"trained"``) appears, which sequences
    inference after training inside one concurrent session;
    ``wait_timeout_s=None`` (default) waits as long as the session's
    wall budget allows, so a long training run cannot starve it.
    ``warmup`` runs one untimed model evaluation before the measured
    loop (jit compile charged off-clock, like every other component).
    """

    model_key: str
    feed: Callable
    steps: int = 5
    wait_meta: str | None = "trained"
    wait_timeout_s: float | None = None
    warmup: bool = True
    tier: str | None = None
    name: str = "inference"

    def __post_init__(self):
        if self.steps < 1:
            raise ValueError("steps must be >= 1")


@dataclass
class InferenceOutput:
    steps: int
    last: Any


@dataclass
class ServingClients:
    """The request-submitting side of the serving plane: ``clients``
    concurrent inference clients, each submitting ``requests`` requests
    (``feed(client, seq) -> value``) into the store-backed request
    ``table`` under packed (client, seq) keys, then polling the paired
    results table for their answers.

    ``submit`` / ``collect`` split the two halves for sequential
    scheduling: a sequential exactness grid declares one submit-only
    writer component before the :class:`ServingConsumer` and one
    collect-only reader after it, while a concurrent session uses a
    single submit+collect component.  ``order_seed`` shuffles the
    arrival interleave across clients (per-client sequence ids stay
    monotone) — admission-order canonicalization in the serving loop
    makes the batch count invariant to it.
    """

    feed: Callable
    table: str
    clients: int = 2
    requests: int = 4
    submit: bool = True
    collect: bool = True
    order_seed: int | None = None
    name: str = "clients"

    def __post_init__(self):
        if self.clients < 1:
            raise ValueError("clients must be >= 1")
        if self.requests < 1:
            raise ValueError("requests must be >= 1")
        if not (self.submit or self.collect):
            raise ValueError("at least one of submit/collect is required")


@dataclass
class ServingClientsOutput:
    requests: int
    #: collected responses keyed ``(client, seq)`` (empty when
    #: ``collect=False``)
    responses: dict


@dataclass
class ServingConsumer:
    """The serving plane's drain side: continuous batching over the
    request ``table``, responses into ``results``, model ``model_key``
    hot-swapped from the registry between batches.

    The default tier (``continuous_batch``) drains up to ``max_batch``
    requests per fused dispatch and re-checks the model version every
    ``reload_every`` batches; ``tier="three_step"`` forces the paper's
    one-at-a-time get → run_model → put baseline the parity tests
    compare against.  ``wait_timeout_s`` bounds the wait for the first
    published model and for request arrival.
    """

    model_key: str
    table: str
    results: str
    clients: int = 2
    requests: int = 4
    max_batch: int = 4
    reload_every: int = 1
    wait_timeout_s: float | None = None
    tier: str | None = None
    name: str = "serving"

    def __post_init__(self):
        if self.clients < 1:
            raise ValueError("clients must be >= 1")
        if self.requests < 1:
            raise ValueError("requests must be >= 1")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.reload_every < 1:
            raise ValueError("reload_every must be >= 1")
        if self.table == self.results:
            raise ValueError("request and results tables must differ")


@dataclass
class ServingOutput:
    steps: int      # requests served
    batches: int    # fused serve dispatches (0 for three_step)
    swaps: int      # model generations adopted
