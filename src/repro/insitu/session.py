"""InSituSession: one declarative call for every coupling scenario.

The paper's driver program wires a database, a CFD simulation and a
distributed trainer together with SmartSim; PR 1–2 grew beyond-paper fast
paths (fused captures, sharded epochs) but left them reachable only
through scattered constructors and per-script thread wiring.  A session
collapses that surface: declare *what* runs —

    session = InSituSession(
        tables=[TableSpec("field", shape=(4, n), capacity=24)],
        components=[
            Producer(step_fn, table="field", steps=200, ranks=4),
            TrainerConsumer(cfg, coords, model_key="encoder"),
            InferenceConsumer("encoder", feed),
        ],
        deployment=Colocated(mesh),          # or Clustered(...) or None
    )
    plan = session.plan()                    # *how*: frozen, inspectable
    print(plan.describe())
    result = session.run()                   # threads, tiers, reports

— and the :class:`~.plan.Plan` resolver picks *how*: per-verb vs
``capture_scan`` vs ``capture_scan_multi`` producers, per-verb vs fused vs
sharded-fused (incl. multi-consumer disjoint-mesh) trainers, fused vs
three-step inference.  The same declaration runs unmodified at every
point of the {colocated, clustered} x {per-verb, fused} x {1..R
producers, 1..C consumers} grid; forcing a component's ``tier`` moves it
through the grid for measurements and parity tests.

``session.run(sequential=True)`` executes components in declaration order
instead of concurrently — deterministic per-component dispatch accounting
(``SessionResult`` exposes ``op_delta`` per component) for benchmarks and
the plan-verification tests.
"""

from __future__ import annotations

import random
import time
from dataclasses import dataclass, replace as _dc_replace
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from ..core import store as S
from ..core.client import Client
from ..core.deployment import Clustered, Deployment
from ..core.faults import FaultPlan, InjectedCrash, simulate_overhead
from ..core.orchestrator import InSituDriver, RunResult, StragglerPolicy
from ..core.server import StoreServer
from ..ml import autoencoder as ae
from ..ml import trainer as tr
from ..parallel.sharding import disjoint_data_meshes, slab_sharding
from ..serve.engine import ServeLoop, request_key, submitted_meta
from ..train.checkpoint import MemoryCheckpoint
from . import plan as P
from .components import (InferenceConsumer, InferenceOutput, Producer,
                         ProducerOutput, ServingClients,
                         ServingClientsOutput, ServingConsumer,
                         ServingOutput, TrainerConsumer, TrainerOutput)

__all__ = ["InSituSession", "SessionResult"]


@dataclass
class SessionResult:
    """What a session run produced: the orchestrator's RunResult, the plan
    it executed, the live server (for ``stats()`` checks and post-run
    clients), and typed per-component outputs."""

    run: RunResult
    plan: P.Plan
    server: StoreServer
    driver: InSituDriver

    @property
    def ok(self) -> bool:
        return self.run.ok

    @property
    def timers(self):
        """Merged component timers (RunResult-compatible accessor — the
        paper-table reports and table12 read them from here)."""
        return self.run.timers

    @property
    def outputs(self) -> dict[str, Any]:
        return self.run.outputs

    def output(self, name: str):
        return self.run.components[name].output

    def op_delta(self, name: str) -> int | None:
        """Store dispatches attributed to one component (sequential runs)."""
        return self.run.components[name].op_delta

    def staged_delta(self, name: str) -> int | None:
        """Cross-mesh staged transfers attributed to one component
        (sequential runs; 0 off a clustered deployment)."""
        return self.run.components[name].staged_delta

    @property
    def straggler_events(self) -> int:
        """Total straggler events (component iterations exceeding the
        ``StragglerPolicy.max_step_s`` deadline) across all components."""
        return sum(c.straggler_events
                   for c in self.run.components.values())

    @property
    def retries(self) -> int:
        """Total transient-fault verb retries absorbed across components."""
        return sum(c.retries for c in self.run.components.values())

    @property
    def restarts(self) -> int:
        """Total crash-recovery restarts survived across components."""
        return sum(c.restarts for c in self.run.components.values())

    def client(self, rank: int = 99) -> Client:
        return self.driver.client(rank=rank)


class InSituSession:
    """Declarative in-situ coupling session (see module docstring)."""

    def __init__(self, components: Sequence[Any],
                 tables: Sequence[S.TableSpec] = (),
                 deployment: Deployment | None = None,
                 straggler: StragglerPolicy | None = None,
                 faults: FaultPlan | None = None):
        if not components:
            raise ValueError("a session needs at least one component")
        self.tables = tuple(tables)
        self.deployment = deployment
        self.straggler = straggler
        self.faults = faults
        self.components = self._normalize(components)
        table_names = {t.name for t in self.tables}
        for comp in self.components:
            if isinstance(comp, Producer) and comp.table not in table_names:
                raise ValueError(f"producer {comp.name!r} targets unknown "
                                 f"table {comp.table!r}")
            if isinstance(comp, TrainerConsumer) \
                    and comp.cfg.table not in table_names:
                raise ValueError(f"trainer {comp.name!r} reads unknown "
                                 f"table {comp.cfg.table!r}")
            if isinstance(comp, ServingClients):
                if comp.table not in table_names:
                    raise ValueError(f"serving clients {comp.name!r} target "
                                     f"unknown table {comp.table!r}")
                if comp.collect \
                        and self._serving_consumer_for(comp.table) is None:
                    raise ValueError(
                        f"serving clients {comp.name!r} collect from table "
                        f"{comp.table!r} but no ServingConsumer drains it")
            if isinstance(comp, ServingConsumer):
                for tname in (comp.table, comp.results):
                    if tname not in table_names:
                        raise ValueError(f"serving {comp.name!r} uses "
                                         f"unknown table {tname!r}")
                    spec = self._spec(tname)
                    total = comp.clients * comp.requests
                    # packed (client, seq) keys are unique but not dense:
                    # the hash engine would collide them mod capacity, and
                    # a ring smaller than the request volume would evict
                    # unanswered requests — both break exactly-once.
                    if spec.engine != "ring":
                        raise ValueError(
                            f"serving table {tname!r} must use the ring "
                            f"engine (hash collides packed request keys)")
                    if spec.capacity < total:
                        raise ValueError(
                            f"serving table {tname!r} capacity "
                            f"{spec.capacity} < {total} total requests")
        for comp in self.components:
            if isinstance(comp, ServingConsumer):
                subs = [c for c in self.components
                        if isinstance(c, ServingClients) and c.submit
                        and c.table == comp.table]
                if len(subs) != 1:
                    raise ValueError(
                        f"serving {comp.name!r} needs exactly one "
                        f"submitting ServingClients on table "
                        f"{comp.table!r}, found {len(subs)}")
                if (subs[0].clients, subs[0].requests) != \
                        (comp.clients, comp.requests):
                    raise ValueError(
                        f"serving {comp.name!r} drains "
                        f"{comp.clients}x{comp.requests} requests but "
                        f"{subs[0].name!r} submits "
                        f"{subs[0].clients}x{subs[0].requests}")

    @staticmethod
    def _normalize(components) -> tuple[Any, ...]:
        """Give every component a unique name (suffix duplicates)."""
        seen: dict[str, int] = {}
        out = []
        for comp in components:
            name = comp.name
            if name in seen or sum(c.name == name for c in components) > 1:
                idx = seen.get(name, 0)
                seen[name] = idx + 1
                comp = _dc_replace(comp, name=f"{name}{idx}")
            else:
                seen[name] = 1
            out.append(comp)
        return tuple(out)

    # -- plan resolution ----------------------------------------------------

    def plan(self, hlo: bool = False) -> P.Plan:
        """Resolve the frozen execution :class:`~.plan.Plan`.

        ``hlo=True`` additionally compiles each component's hot path and
        records its collective-op counts (``analysis/hlo``) in the plan —
        the structural zero-collective / DDP-all-reduce predictions the
        tests verify.  Compilation is not free; leave it off on the
        run-only path (the executables warm at run time anyway).
        """
        entries: list[P.ComponentPlan] = []
        #: static component walk for the fault-cost simulator — one dict
        #: per plan entry, in the sequential execution order the exactness
        #: claim covers (see ``core.faults.simulate_overhead``).
        schedule: list[dict] = []
        first_trainer = True
        crosses = self.deployment is not None \
            and self.deployment.crosses_mesh
        # The put path compiles collective-free under EVERY deployment —
        # clustered included: its interconnect hop is a host-driven staged
        # reshard (predicted in ``staged``, measured in
        # ``stats()["staged_transfers"]``), never an in-program collective.
        put_pred = P.COLLECTIVE_FREE
        for comp in self.components:
            if isinstance(comp, Producer):
                tier = P.producer_tier(comp)
                # the two-slot staging pipeline only exists on the fused
                # crossing path (per-verb puts stage per element)
                overlap = crosses and tier != "per_verb" \
                    and getattr(self.deployment, "overlap", False)
                fan_in = self.deployment.fan_in if crosses else 1
                cost_model = getattr(self.deployment, "cost_model", None)
                chunk = comp.chunk or P.autotune_chunk(
                    comp.emit_every, cost_model, steps=comp.steps,
                    fan_in=fan_in)
                if tier == "per_verb":
                    schedule.append({
                        "kind": "producer", "name": comp.name, "tier": tier,
                        "table": comp.table, "steps": comp.steps,
                        "emit_every": comp.emit_every, "ranks": comp.ranks})
                else:
                    schedule.append({
                        "kind": "producer", "name": comp.name, "tier": tier,
                        "table": comp.table, "overlap": overlap,
                        "n_chunks": -(-comp.steps // chunk)})
                if tier == "capture_scan_sharded":
                    # the sharded chunk legitimately contains the solver's
                    # halo ppermute — claim exactly that (and nothing
                    # more) where the placement makes it structural
                    pred = P.sharded_producer_prediction(
                        comp.elem_sharding,
                        colocated=self.deployment is not None
                        and not crosses)
                else:
                    pred = put_pred
                predicted_sps = None
                if cost_model is not None and tier != "per_verb":
                    try:
                        predicted_sps = cost_model.predict_steps_per_s(
                            fan_in)
                    except ValueError:
                        pass    # fan_in outside the fitted sweep: no claim
                entries.append(P.ComponentPlan(
                    name=comp.name, kind="producer", tier=tier,
                    table=comp.table, ranks=comp.ranks, steps=comp.steps,
                    chunk=0 if tier == "per_verb" else chunk,
                    bucketed=comp.bucket and tier != "per_verb",
                    fan_in=fan_in,
                    predicted_steps_per_s=predicted_sps,
                    dispatches=P.producer_dispatches(
                        tier, comp.steps, comp.emit_every, comp.ranks,
                        chunk, overlap=overlap),
                    staged=P.producer_staged(
                        tier, comp.steps, comp.emit_every, comp.ranks,
                        chunk, crosses),
                    predicted_collectives=pred,
                    collectives=self._producer_collectives(comp, tier, chunk)
                    if hlo else None))
            elif isinstance(comp, TrainerConsumer):
                meshes = self._consumer_meshes(comp)
                for i, mesh in enumerate(meshes):
                    cfg = self._replica_cfg(comp, i, mesh)
                    tier = P.trainer_tier(cfg, comp.tier)
                    ndev = int(mesh.shape[cfg.mesh_axis]) \
                        if mesh is not None else 1
                    name = comp.name if comp.count == 1 \
                        else f"{comp.name}{i}"
                    schedule.append({
                        "kind": "trainer", "name": name, "tier": tier,
                        "table": cfg.table, "epochs": cfg.epochs,
                        "bootstrap": first_trainer})
                    entries.append(P.ComponentPlan(
                        name=name, kind="trainer", tier=tier,
                        table=cfg.table, steps=cfg.epochs,
                        mesh_devices=ndev,
                        dispatches=P.trainer_dispatches(
                            tier, cfg.epochs, bootstrap=first_trainer),
                        staged=P.trainer_staged(tier, cfg.epochs, crosses),
                        predicted_collectives=
                        P.trainer_collective_prediction(
                            tier, self._table_is_sharded(cfg.table)),
                        collectives=self._trainer_collectives(comp, cfg,
                                                              tier)
                        if hlo else None))
                    first_trainer = False
            elif isinstance(comp, InferenceConsumer):
                tier = P.inference_tier(comp)
                schedule.append({
                    "kind": "inference", "name": comp.name, "tier": tier,
                    "steps": comp.steps})
                entries.append(P.ComponentPlan(
                    name=comp.name, kind="inference", tier=tier,
                    steps=comp.steps,
                    dispatches=P.inference_dispatches(tier, comp.steps),
                    staged=P.inference_staged(tier, comp.steps, crosses)))
            elif isinstance(comp, ServingClients):
                total = comp.clients * comp.requests
                schedule.append({
                    "kind": "clients", "name": comp.name,
                    "tier": "per_verb", "table": comp.table,
                    "results": self._serving_results(comp.table)
                    if comp.collect else None,
                    "requests": total, "submit": comp.submit,
                    "collect": comp.collect})
                entries.append(P.ComponentPlan(
                    name=comp.name, kind="clients", tier="per_verb",
                    table=comp.table, steps=total,
                    dispatches=P.clients_dispatches(total, comp.submit,
                                                    comp.collect),
                    staged=P.clients_staged(total, comp.submit, crosses),
                    predicted_collectives=put_pred if comp.submit
                    else None))
            elif isinstance(comp, ServingConsumer):
                tier = P.serving_tier(comp)
                total = comp.clients * comp.requests
                schedule.append({
                    "kind": "serving", "name": comp.name, "tier": tier,
                    "table": comp.table, "results": comp.results,
                    "requests": total,
                    "n_batches": -(-total // comp.max_batch)})
                entries.append(P.ComponentPlan(
                    name=comp.name, kind="serving", tier=tier,
                    table=comp.table, steps=total,
                    dispatches=P.serving_dispatches(tier, total,
                                                    comp.max_batch),
                    staged=P.serving_staged(tier, total, crosses),
                    swaps=P.serving_swaps(tier),
                    # the drain runs entirely on the store placement —
                    # structurally collective-free on every deployment
                    predicted_collectives=put_pred,
                    collectives=self._serving_collectives(comp, tier)
                    if hlo else None))
            else:
                raise TypeError(f"unknown component type {type(comp)!r}")
        dep = self.deployment.describe() if self.deployment is not None \
            else "local"
        fault_totals: tuple[tuple[str, int], ...] = ()
        fplan = self._fault_plan()
        if fplan is not None:
            # Simulate the declared faults against the static schedule: the
            # walk drives a FRESH injector through the exact call sequence
            # the runtime makes, so the predicted retry dispatches, replay
            # ops and re-staged hops equal the measured counters exactly.
            per, totals = simulate_overhead(fplan, schedule, crosses)
            merged = []
            for e in entries:
                o = per.get(e.name)
                if o is None or o.empty:
                    merged.append(e)
                    continue
                dispatches = e.dispatches + (
                    (("replay", o.extra_ops),) if o.extra_ops else ())
                staged = e.staged + (
                    (("restage", o.extra_staged),) if o.extra_staged else ())
                merged.append(_dc_replace(
                    e, dispatches=dispatches, staged=staged,
                    retries=o.retries, restarts=o.restarts))
            entries = merged
            fault_totals = tuple(sorted(totals.items()))
        return P.Plan(deployment=dep, components=tuple(entries),
                      fan_in=self.deployment.fan_in
                      if self.deployment is not None else 1,
                      faults=fault_totals)

    def _fault_plan(self) -> FaultPlan | None:
        """The armed fault plan: the session's own, else the deployment's
        (``Deployment.faults``); ``None`` disarms the whole machinery."""
        if self.faults is not None:
            return self.faults
        return getattr(self.deployment, "faults", None)

    def _consumer_meshes(self, comp: TrainerConsumer):
        if comp.count == 1:
            return [comp.cfg.mesh]
        # multi-consumer slices must never claim the store's dedicated
        # devices — under Clustered, carve the replicas out of the
        # CLIENT mesh only
        devices = list(self.deployment.client_mesh.devices.ravel()) \
            if isinstance(self.deployment, Clustered) else None
        return disjoint_data_meshes(comp.count, devices=devices)

    def _replica_cfg(self, comp: TrainerConsumer, idx: int, mesh):
        cfg = comp.cfg
        if comp.count > 1:
            cfg = _dc_replace(cfg, mesh=mesh, seed=cfg.seed + idx)
        # A slab-sharded trainer under a Clustered deployment reads a
        # table living on the DEDICATED db mesh: wire that mesh into the
        # config so the tier resolves to ``slab_sharded_clustered`` and
        # the epoch gathers on the db side (one staged transfer back).
        if isinstance(self.deployment, Clustered) and cfg.slab_sharded \
                and cfg.db_mesh is None:
            cfg = _dc_replace(cfg, db_mesh=self.deployment.db_mesh,
                              db_axis=self.deployment.slab_axis)
        return cfg

    def _spec(self, table: str) -> S.TableSpec:
        for t in self.tables:
            if t.name == table:
                return t
        raise KeyError(table)

    def _serving_consumer_for(self, table: str) -> ServingConsumer | None:
        """The ServingConsumer draining request ``table``, if declared."""
        for c in self.components:
            if isinstance(c, ServingConsumer) and c.table == table:
                return c
        return None

    def _serving_results(self, table: str) -> str:
        """The results table paired with request ``table`` (the draining
        consumer declares it; collectors resolve it from here)."""
        c = self._serving_consumer_for(table)
        if c is None:
            raise ValueError(f"no ServingConsumer drains table {table!r}")
        return c.results

    # -- HLO collective accounting (plan(hlo=True)) -------------------------

    def _producer_collectives(self, comp: Producer, tier: str, chunk: int):
        """Compile one put / one capture chunk against the table's actual
        placement (deployment rule, or the slab-sharded trainer's
        partitioned slab) and count its collective ops.

        Under a clustered deployment the fused put is two programs —
        the client-side collect scan and the db-side masked insert
        (the staged reshard between them is host-driven, not HLO) — so
        both are compiled and their counts summed: the claim covers the
        WHOLE put path, closing the plan's former "no claim" hole."""
        from ..analysis.hlo import COLLECTIVE_OPS, count_ops
        spec = self._spec(comp.table)
        dep = self.deployment
        staged = dep is not None and dep.crosses_mesh
        state = S.init_table(spec, self._table_placement(spec))
        n = min(chunk, comp.steps)
        if tier == "per_verb":
            val = jnp.zeros(spec.shape, spec.dtype)
            if staged:
                val = dep.stage(val, spec)
            txt = jax.jit(lambda st: S.put_impl(
                spec, st, jnp.uint32(1), val)).lower(state).compile()
            counts = count_ops(txt.as_text())
        elif staged:
            single = tier in ("capture_scan", "capture_scan_sharded")
            es = comp.elem_sharding if tier == "capture_scan_sharded" \
                else None
            sf = _single_rank(comp.step_fn) if single else comp.step_fn
            rows = S.capture_rows(n, comp.emit_every)
            if single:
                collect = jax.jit(lambda c: S.capture_scan_collect_impl(
                    spec, sf, c, n, comp.emit_every,
                    elem_sharding=es)).lower(comp.carry).compile()
                chunk_n = rows
            else:
                collect = jax.jit(
                    lambda c: S.capture_scan_collect_multi_impl(
                        spec, sf, c, n, comp.ranks,
                        comp.emit_every)).lower(comp.carry).compile()
                chunk_n = rows * comp.ranks
            keys, vals, mask = dep.stage_chunk(
                jnp.zeros((chunk_n,), S.KEY_DTYPE),
                jnp.zeros((chunk_n, *spec.shape), spec.dtype),
                jnp.zeros((chunk_n,), bool), spec)
            insert = jax.jit(lambda st, k, v, m: S.put_masked_impl(
                spec, st, k, v, m)).lower(state, keys, vals,
                                          mask).compile()
            counts = count_ops(collect.as_text())
            for op, c in count_ops(insert.as_text()).items():
                counts[op] = counts.get(op, 0) + c
        elif tier in ("capture_scan", "capture_scan_sharded"):
            sf = _single_rank(comp.step_fn)
            es = comp.elem_sharding if tier == "capture_scan_sharded" \
                else None
            txt = jax.jit(lambda st, c: S.capture_scan_impl(
                spec, st, sf, c, n, comp.emit_every,
                elem_sharding=es)).lower(state, comp.carry).compile()
            counts = count_ops(txt.as_text())
        else:
            txt = jax.jit(lambda st, c: S.capture_scan_multi_impl(
                spec, st, comp.step_fn, c, n,
                comp.ranks, comp.emit_every)).lower(
                    state, comp.carry).compile()
            counts = count_ops(txt.as_text())
        return tuple((op, counts.get(op, 0)) for op in COLLECTIVE_OPS)

    def _trainer_collectives(self, comp: TrainerConsumer, cfg, tier: str):
        """Compile one epoch of this replica's tier and count collectives
        (the sharded tiers must contain the DDP all-reduce; single-device
        tiers must not; the slab-sharded tier must show NO table
        all-gather).  The dummy table is placed exactly like the live one
        — for the slab-sharded tier that means the slab enters pre-sharded,
        so the compiled HLO is the ground truth for the entry claim."""
        from ..analysis.hlo import COLLECTIVE_OPS, count_ops
        if tier == "per_verb":
            return tuple((op, 0) for op in COLLECTIVE_OPS)
        spec = self._spec(cfg.table)
        levels = ae.coords_pyramid(cfg.ae, comp.coords)
        tx = _opt_for(cfg)
        state = tr.init_state(cfg, jax.random.key(cfg.seed), tx)
        epoch_fn = tr.EPOCH_BUILDERS[tier](cfg, levels, tx, spec)
        dummy = S.init_table(spec, self._table_placement(spec))
        mu = jnp.zeros((spec.shape[0],))
        if tier == "slab_sharded_clustered":
            # two programs: the db-mesh staged gather (shard-local rows +
            # explicit psum) and the client-mesh DDP epoch on the staged
            # batch; the hop between them is a reshard, not HLO — sum
            # both sides so the claim covers the whole read path.
            from jax.sharding import NamedSharding, PartitionSpec
            # the shard-count rule is the DEPLOYMENT's (the same one the
            # server's runtime gather consults) — never recompute it here
            shards = self.deployment.gather_shards(spec) \
                if isinstance(self.deployment, Clustered) else 1
            gather = S.make_clustered_gather(
                spec, cfg.gather, db_mesh=cfg.db_mesh, axis=cfg.db_axis,
                shards=shards)
            counts = count_ops(gather.lower(
                dummy, jax.random.key(0)).compile().as_text())
            vals = jax.device_put(
                jnp.zeros((cfg.gather, *spec.shape), spec.dtype),
                NamedSharding(cfg.mesh, PartitionSpec()))
            train_txt = epoch_fn.train_fn.lower(
                vals, jnp.asarray(True), state, jax.random.key(0), mu,
                mu + 1.0).compile().as_text()
            for op, c in count_ops(train_txt).items():
                counts[op] = counts.get(op, 0) + c
            return tuple((op, counts.get(op, 0)) for op in COLLECTIVE_OPS)
        txt = epoch_fn.lower(dummy, state, jax.random.key(0), mu,
                             mu + 1.0).compile().as_text()
        counts = count_ops(txt)
        return tuple((op, counts.get(op, 0)) for op in COLLECTIVE_OPS)

    def _serving_collectives(self, comp: ServingConsumer, tier: str):
        """Compile one serving drain against the live table placements and
        count its collective ops — the serving leg of the ``plan(hlo=True)``
        tier grid (the collective-budget manifest's measured side).

        The registry model is unknown at plan time (only ``model_key``
        is declared), so the drain compiles with a shape-correct stub
        apply; the claim covers the store plumbing — batched gather,
        vmapped apply harness, masked scatter — which is what must stay
        collective-free (requests, params and responses all sit on the
        store placement).  The bound model's own collectives are the
        trainer's claim, measured where it is compiled."""
        from ..analysis.hlo import COLLECTIVE_OPS, count_ops
        req_spec = self._spec(comp.table)
        res_spec = self._spec(comp.results)
        req_state = S.init_table(req_spec, self._table_placement(req_spec))
        res_state = S.init_table(res_spec, self._table_placement(res_spec))
        if tier == "three_step":
            # unfused baseline: one get off the request table + one put
            # into the results table per request
            key = jnp.uint32(1)
            get_txt = jax.jit(lambda st, k: S.get(
                req_spec, st, k)).lower(req_state, key).compile()
            val = jnp.zeros(res_spec.shape, res_spec.dtype)
            put_txt = jax.jit(lambda st, k, v: S.put_impl(
                res_spec, st, k, v)).lower(res_state, key,
                                           val).compile()
            counts = count_ops(get_txt.as_text())
            for op, c in count_ops(put_txt.as_text()).items():
                counts[op] = counts.get(op, 0) + c
            return tuple((op, counts.get(op, 0)) for op in COLLECTIVE_OPS)

        def stub_apply(params, x):
            # depends on x so the request gather can't be dead-code
            # eliminated out of the compiled drain
            del params
            return jnp.broadcast_to(
                jnp.mean(x).astype(res_spec.dtype), res_spec.shape)

        keys = jnp.zeros((comp.max_batch,), S.KEY_DTYPE)
        mask = jnp.zeros((comp.max_batch,), bool)
        txt = S.serve_batch.lower(req_spec, res_spec, stub_apply,
                                  req_state, res_state, jnp.zeros(()),
                                  keys, mask).compile()
        counts = count_ops(txt.as_text())
        return tuple((op, counts.get(op, 0)) for op in COLLECTIVE_OPS)

    # -- table placement (the slab-sharded data plane) ----------------------

    def _slab_trainer_cfg(self, table: str):
        """The config of the slab-sharded trainer reading ``table``, if
        any (that trainer's mesh owns the table's placement)."""
        for comp in self.components:
            if isinstance(comp, TrainerConsumer) and comp.cfg.slab_sharded \
                    and comp.cfg.table == table:
                return comp.cfg
        return None

    def _table_is_sharded(self, table: str) -> bool:
        """Is this table's slab *placed* partitioned across > 1 device?
        (Drives the placement-dependent collective predictions — a
        trivially-sharded 1-device mesh introduces no collectives.)"""
        sh = self._table_placement(self._spec(table))
        return sh is not None and getattr(sh, "num_devices", 1) > 1 \
            and not sh.is_fully_replicated

    def _table_placement(self, spec: S.TableSpec):
        """Where this table's slab lives: a slab-sharded trainer's table is
        placed pre-partitioned over its mesh (``slab_sharding``); under a
        Clustered deployment the DEPLOYMENT owns placement instead — the
        slab stays on the dedicated db mesh (slot-partitioned when
        ``slab_axis`` is set) and the trainer reaches it through the
        staged gather, never through its own mesh.  Otherwise the
        deployment's rule applies (``None`` = server default)."""
        cfg = self._slab_trainer_cfg(spec.name)
        if cfg is not None and not isinstance(self.deployment, Clustered):
            return slab_sharding(spec, cfg.mesh, cfg.mesh_axis)
        if self.deployment is not None:
            return self.deployment.slab_sharding(spec)
        return None

    def _table_shardings(self) -> dict[str, Any]:
        """Explicit per-table placements for the driver (only tables that
        deviate from the deployment default appear; a Clustered
        deployment's own rule already covers its tables)."""
        out = {}
        if isinstance(self.deployment, Clustered):
            return out
        for t in self.tables:
            cfg = self._slab_trainer_cfg(t.name)
            if cfg is not None:
                out[t.name] = slab_sharding(t, cfg.mesh, cfg.mesh_axis)
        return out

    # -- runtime ------------------------------------------------------------

    def run(self, plan: P.Plan | None = None, max_wall_s: float = 300.0,
            sequential: bool = False, verbose: bool = False,
            preload: Callable[[StoreServer], None] | None = None
            ) -> SessionResult:
        """Execute the session: build the store (deployment + tables),
        spin one thread per component, run them per ``plan``.

        ``sequential=True`` runs components in declaration order instead
        of concurrently (put producers first) — the mode for benchmarks,
        offline produce-then-train flows, and exact per-component dispatch
        attribution.  ``preload`` is called with the fresh server before
        any component starts — stage pre-trained models or metadata there
        (e.g. a pure-inference session registering its model).
        """
        plan = plan or self.plan()
        driver = InSituDriver(deployment=self.deployment, tables=self.tables,
                              straggler=self.straggler,
                              table_shardings=self._table_shardings(),
                              faults=self._fault_plan())
        if preload is not None:
            preload(driver.server)
        fns: dict[str, Callable] = {}
        entry_iter = iter(plan.components)

        def take(kind: str) -> P.ComponentPlan:
            entry = next(entry_iter, None)
            if entry is None or entry.kind != kind:
                raise ValueError(
                    f"plan does not match this session's declaration "
                    f"(expected a {kind!r} entry, got {entry})")
            return entry

        for comp in self.components:
            if isinstance(comp, Producer):
                entry = take("producer")
                fns[entry.name] = self._producer_fn(comp, entry)
            elif isinstance(comp, TrainerConsumer):
                meshes = self._consumer_meshes(comp)
                for i, mesh in enumerate(meshes):
                    entry = take("trainer")
                    cfg = self._replica_cfg(comp, i, mesh)
                    fns[entry.name] = self._trainer_fn(comp, cfg, entry,
                                                       verbose)
            elif isinstance(comp, ServingClients):
                entry = take("clients")
                fns[entry.name] = self._clients_fn(comp, entry, max_wall_s)
            elif isinstance(comp, ServingConsumer):
                entry = take("serving")
                fns[entry.name] = self._serving_fn(comp, entry, max_wall_s)
            else:
                entry = take("inference")
                fns[entry.name] = self._inference_fn(comp, entry,
                                                     max_wall_s)
        res = driver.run(fns, max_wall_s=max_wall_s, sequential=sequential)
        return SessionResult(run=res, plan=plan, server=driver.server,
                             driver=driver)

    # -- component runners --------------------------------------------------

    def _producer_fn(self, comp: Producer, entry: P.ComponentPlan):
        spec = self._spec(comp.table)
        pol = self.straggler or StragglerPolicy()

        if entry.tier == "per_verb":
            def fn(client: Client, stop):
                carry, done = comp.carry, 0
                for t in range(comp.steps):
                    if stop.is_set():
                        break
                    # Declared crash point: a killed rank restarts from the
                    # table watermark (its recovery cursor — the committed
                    # prefix survives in the store, the t0 clock resumes
                    # from it) and retries the same step index.
                    _survive_crash(client, entry.name, t, comp.table)
                    it0 = time.perf_counter()
                    emit = t % comp.emit_every == 0
                    if comp.ranks == 1:
                        # box[0] blocks on the solve INSIDE this bucket so
                        # async dispatch is not mischarged to "send" (the
                        # per-verb tier exists to measure these buckets).
                        with client.timers.time("equation_solution") as box:
                            carry, key, value = comp.step_fn(carry, 0, t)
                            box[0] = value
                        if emit:
                            # through the fault boundary: retried on
                            # transient store-unavailable windows
                            client.put_kv(comp.table, key, value)
                    else:
                        new, sends = [], []
                        with client.timers.time("equation_solution") as box:
                            for r in range(comp.ranks):
                                # slice rank r out of the stacked carry
                                c_r = jax.tree.map(lambda x: x[r], carry)
                                c_r, key, value = comp.step_fn(c_r, r, t)
                                new.append(c_r)
                                sends.append((key, value))
                            carry = jax.tree.map(
                                lambda *xs: jnp.stack(xs), *new)
                            box[0] = [v for _, v in sends]
                        if emit:
                            for key, value in sends:
                                client.put_kv(comp.table, key, value)
                    done += 1
                    if time.perf_counter() - it0 > pol.max_step_s:
                        client.straggler_events += 1
                client.put_metadata("sim_done", True)
                return ProducerOutput(steps=done)
            return fn

        single = entry.tier in ("capture_scan", "capture_scan_sharded")
        es = comp.elem_sharding if entry.tier == "capture_scan_sharded" \
            else None
        step_fn = _single_rank(comp.step_fn) if single else comp.step_fn

        def fn(client: Client, stop):
            carry, done = comp.carry, 0
            chunk = entry.chunk
            if comp.warmup:
                # Pre-compile every executable the chunked loop will need —
                # one per (bucketed) chunk length — on a throwaway table so
                # the timed loop measures enqueue + solve, not compilation.
                # The clustered staged path runs DIFFERENT executables
                # (collect scan + masked insert against the deployment-
                # placed slab), so warm exactly those; staging the warmup
                # chunk goes through the deployment directly, leaving the
                # server's staged-transfer telemetry untouched.
                dep = client.server.deployment
                staged = dep is not None and dep.crosses_mesh
                # An armed FaultPlan routes EVERY deployment through the
                # logged collect → masked-insert path (chunk ids + WAL), so
                # warm exactly those executables; only a genuinely crossing
                # deployment also stages the warmup chunk.
                logged = staged or client.server.wal_enabled
                lengths = {min(chunk, comp.steps - base)
                           for base in range(0, comp.steps, chunk)}
                with client.timers.time("jit_compile"):
                    for k in sorted(lengths):
                        padded, valid = (S.bucket_length(k),
                                         jnp.asarray(k, jnp.int32)) \
                            if entry.bucketed else (k, None)
                        if logged:
                            if single:
                                _, keys, vals, mask = S.capture_scan_collect(
                                    spec, step_fn, carry, padded,
                                    comp.emit_every, t0=0, valid=valid,
                                    elem_sharding=es)
                            else:
                                _, keys, vals, mask = \
                                    S.capture_scan_collect_multi(
                                        spec, step_fn, carry, padded,
                                        comp.ranks, comp.emit_every, t0=0,
                                        valid=valid)
                            if staged:
                                keys, vals, mask = dep.stage_chunk(
                                    keys, vals, mask, spec)
                                placement = dep.slab_sharding(spec)
                            else:
                                placement = client.server.placement(
                                    comp.table)
                            wst = S.put_masked(
                                spec, S.init_table(spec, placement),
                                keys, vals, mask)
                        elif single:
                            # the sharded tier's executable is placement-
                            # sensitive (the constraint must meet the same
                            # slab layout as the live table), so warm
                            # against the deployment placement, not an
                            # unplaced throwaway
                            wst, _ = S.capture_scan(
                                spec,
                                S.init_table(spec, client.server.placement(
                                    comp.table)) if es is not None
                                else S.init_table(spec),
                                step_fn, carry, padded, comp.emit_every,
                                t0=0, valid=valid, elem_sharding=es)
                        else:
                            wst, _ = S.capture_scan_multi(
                                spec, S.init_table(spec), step_fn, carry,
                                padded, comp.ranks, comp.emit_every, t0=0,
                                valid=valid)
                        jax.block_until_ready(wst.count)
            for base in range(0, comp.steps, chunk):
                if stop.is_set():
                    break
                # Declared crash point, indexed by chunk: the restarted
                # producer resumes the t0 clock at the same chunk base and
                # re-dispatches it (the carry is re-derivable from the
                # committed watermark prefix).
                _survive_crash(client, entry.name, base // chunk,
                               comp.table)
                it0 = time.perf_counter()
                k = min(chunk, comp.steps - base)
                # The ring puts ride the solver dispatch (the point of the
                # fused tier): the chunk is charged to equation_solution,
                # "send" counts only enqueue + commit bookkeeping.
                with client.timers.time("equation_solution") as box:
                    carry = client.capture_scan(
                        comp.table, step_fn, carry, k, comp.emit_every,
                        t0=base, n_ranks=None if single else comp.ranks,
                        bucket=entry.bucketed, elem_sharding=es)
                    box[0] = client.server.checkout(comp.table).count
                done += k
                if time.perf_counter() - it0 > pol.max_step_s:
                    client.straggler_events += 1
            # capture end: flush the overlap pipeline's in-flight chunk
            # (the plan's ONE predicted "drain" dispatch; a no-op — and
            # not dispatched — off the overlapped clustered path)
            client.drain_captures(comp.table)
            client.put_metadata("sim_done", True)
            return ProducerOutput(steps=done)
        return fn

    def _trainer_fn(self, comp: TrainerConsumer, cfg, entry: P.ComponentPlan,
                    verbose: bool):
        pol = self.straggler or StragglerPolicy()

        def fn(client: Client, stop):
            user_cb = comp.on_epoch
            if user_cb is None and verbose:
                user_cb = lambda r: print(          # noqa: E731
                    f"  [{entry.name}] epoch {r.epoch:3d} "
                    f"train {r.train_loss:.4f} val {r.val_loss:.4f} "
                    f"relF {r.val_rel_error:.3f}")
            last = [time.perf_counter()]

            def on_epoch(r):
                # epoch-deadline straggler telemetry (the trainer's
                # max_step_s unit is one epoch)
                now = time.perf_counter()
                if now - last[0] > pol.max_step_s:
                    client.straggler_events += 1
                last[0] = now
                if user_cb is not None:
                    user_cb(r)

            # An armed FaultPlan parks (state, rng, history) in the store
            # after every epoch; a declared trainer crash propagates out of
            # insitu_train and the loop below re-enters it, resuming from
            # that checkpoint with the identical rng stream.
            memckpt = MemoryCheckpoint(client.server, key=entry.name) \
                if client.server.wal_enabled else None
            # Hot-swap producer side: publish a versioned checkpoint into
            # the model registry every ``publish_every`` epochs.  The hook
            # fires at the END of an epoch (after its checkpoint save), and
            # a declared trainer crash fires at the TOP of one — so a
            # resumed run never re-publishes a completed epoch's generation
            # and the publish count stays deterministic under chaos.
            on_ckpt = None
            if comp.publish_every is not None:
                pub_levels = ae.coords_pyramid(cfg.ae, comp.coords)

                def _enc(p, f):
                    return ae.encode(p, cfg.ae, pub_levels, f)

                def on_ckpt(epoch, st):
                    if (epoch + 1) % comp.publish_every == 0:
                        client.set_model(comp.model_key, _enc, st.params)
            while True:
                last[0] = time.perf_counter()
                try:
                    state, history, levels, stats = tr.insitu_train(
                        client, comp.coords, cfg, stop_event=stop,
                        on_epoch=on_epoch, tier=entry.tier,
                        memckpt=memckpt, component=entry.name,
                        on_checkpoint=on_ckpt)
                    break
                except InjectedCrash:
                    client.restarts += 1
            if comp.model_key is not None:
                client.set_model(
                    comp.model_key,
                    lambda p, f: ae.encode(p, cfg.ae, levels, f),
                    state.params)
                client.put_metadata("trained", True)
            return TrainerOutput(steps=len(history), state=state,
                                 history=history, levels=levels,
                                 norm_stats=stats)
        return fn

    def _inference_fn(self, comp: InferenceConsumer, entry: P.ComponentPlan,
                      max_wall_s: float):
        def fn(client: Client, stop):
            if comp.wait_meta is not None:
                # Wait in slices so a stopping session interrupts us; the
                # default budget is the session's own wall budget (a long
                # concurrent training run must not starve inference).
                budget = comp.wait_timeout_s if comp.wait_timeout_s \
                    is not None else max_wall_s
                deadline = time.perf_counter() + budget
                while client.get_metadata(comp.wait_meta,
                                          timeout=0.5) is None:
                    if stop.is_set():
                        return InferenceOutput(steps=0, last=None)
                    if time.perf_counter() >= deadline:
                        raise TimeoutError(
                            f"inference {comp.name!r}: metadata "
                            f"{comp.wait_meta!r} never appeared "
                            f"within {budget:.0f}s")
            last, done, made_tables = None, 0, False
            tin, tout = f"{comp.name}_in", f"{comp.name}_out"
            if comp.warmup and comp.steps:
                # one untimed eval: jit compile lands off-clock, so the
                # timed model_eval bucket measures steady-state calls
                x = comp.feed(client, 0)
                jax.block_until_ready(
                    client.server.run_model(comp.model_key, x))
            for step in range(comp.steps):
                if stop.is_set():
                    break
                x = comp.feed(client, step)
                if entry.tier == "fused_registry":
                    last = client.infer(comp.model_key, x)
                else:
                    if not made_tables:
                        y0 = client.server.run_model(comp.model_key, x)
                        client.server.create_table(S.TableSpec(
                            tin, shape=tuple(x.shape), capacity=2,
                            engine="hash"))
                        client.server.create_table(S.TableSpec(
                            tout, shape=tuple(jnp.asarray(y0).shape),
                            capacity=2, engine="hash"))
                        made_tables = True
                    client.put_tensor("x", x, table=tin)
                    client.run_model(comp.model_key, inputs=["x"],
                                     outputs=["y"], table=tin,
                                     out_table=tout)
                    last, _ = client.get_tensor("y", table=tout)
                done += 1
            if last is not None:
                jax.block_until_ready(last)
            return InferenceOutput(steps=done, last=last)
        return fn

    def _clients_fn(self, comp: ServingClients, entry: P.ComponentPlan,
                    max_wall_s: float):
        results = self._serving_results(comp.table) if comp.collect \
            else None
        total = comp.clients * comp.requests

        def fn(client: Client, stop):
            server = client.server
            responses: dict = {}
            submitted = 0
            if comp.submit:
                # Arrival interleave: client-major by default; order_seed
                # shuffles WHICH client submits next while each client's
                # sequence ids stay monotone — the serving loop's
                # round-robin discovery canonicalizes admission order, so
                # the drained batch count is invariant to this shuffle.
                order = [c for _ in range(comp.requests)
                         for c in range(comp.clients)]
                if comp.order_seed is not None:
                    random.Random(comp.order_seed).shuffle(order)
                next_seq = [0] * comp.clients
                for i, c in enumerate(order):
                    if stop.is_set():
                        break
                    s = next_seq[c]
                    # Declared crash point: the committed request prefix
                    # and the submission counters survive in the store;
                    # host submission state survives in this loop — the
                    # retried index re-puts the same request exactly once.
                    _survive_crash(client, entry.name, i, comp.table)
                    value = comp.feed(c, s)
                    client.put_kv(comp.table, request_key(c, s), value)
                    # make the request visible: a host metadata write —
                    # the submission watermark costs zero store dispatches
                    server.put_meta(submitted_meta(comp.table, c), s + 1)
                    next_seq[c] = s + 1
                    submitted += 1
            if comp.collect:
                # The results watermark is the free completion signal;
                # each owned key is then fetched once, in client-major
                # order (one counted get per response).
                server.wait_watermark(results, total, timeout=max_wall_s)
                for c in range(comp.clients):
                    for s in range(comp.requests):
                        if stop.is_set():
                            break
                        v, _found = client.get_kv(results,
                                                  request_key(c, s))
                        responses[(c, s)] = v
            return ServingClientsOutput(requests=submitted,
                                        responses=responses)
        return fn

    def _serving_fn(self, comp: ServingConsumer, entry: P.ComponentPlan,
                    max_wall_s: float):
        def fn(client: Client, stop):
            timeout = comp.wait_timeout_s if comp.wait_timeout_s \
                is not None else max_wall_s
            loop = ServeLoop(
                client, model_key=comp.model_key,
                request_table=comp.table, response_table=comp.results,
                clients=comp.clients, requests=comp.requests,
                max_batch=comp.max_batch, reload_every=comp.reload_every,
                component=entry.name)
            # The loop object is the recovery unit: a declared serving
            # crash propagates out, recover() re-cursors from the results
            # watermark and re-admits the in-flight tail — the adopted
            # model generation survives (no re-bind, no extra swap).
            while True:
                try:
                    if entry.tier == "three_step":
                        loop.run_three_step(stop_event=stop,
                                            timeout=timeout)
                    else:
                        loop.run(stop_event=stop, timeout=timeout)
                    break
                except InjectedCrash:
                    client.restarts += 1
                    loop.recover()
            return ServingOutput(steps=loop.served, batches=loop.batches,
                                 swaps=loop.swaps)
        return fn


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _survive_crash(client: Client, name: str, idx: int, table: str) -> None:
    """Producer-side crash/restart loop.  A declared ``FaultPlan`` crash
    kills the step attempt before anything is dispatched; the restarted
    rank re-reads the table watermark (its recovery cursor — the committed
    prefix survives in the store, a host-counter read costing zero
    dispatches) and retries the same index, which the injector now lets
    pass (each declared crash fires exactly once).  Because the crash fires
    before the step's store ops, the retried step emits byte-identical rows
    and the fault-free dispatch count is preserved."""
    while True:
        try:
            client.fault_point(name, idx)
            return
        except InjectedCrash:
            client.restarts += 1
            client.watermark(table)


def _single_rank(step_fn: Callable) -> Callable:
    """Adapt the declarative (carry, rank, t) step to capture_scan's
    single-producer (carry, t) form."""
    def fn(carry, t):
        return step_fn(carry, 0, t)
    return fn


def _opt_for(cfg):
    from ..train import optimizer as opt
    return opt.adam(cfg.scaled_lr)
