"""Declarative in-situ coupling sessions.

Declare *what* runs (:class:`Producer`, :class:`TrainerConsumer`,
:class:`InferenceConsumer` plus a ``Deployment``); the :class:`Plan`
resolver picks *how* (per-verb vs fused captures, single vs multi-rank,
single-device vs sharded / multi-consumer epochs) and predicts its
dispatch and collective structure; :class:`InSituSession` runs it.

The legacy entry points — ``ml.trainer.insitu_train``'s tier branching,
``launch/insitu``'s hand-wired threads, the three epoch constructors —
are thin shims over this path.
"""

from .components import (InferenceConsumer, InferenceOutput, Producer,
                         ProducerOutput, ServingClients,
                         ServingClientsOutput, ServingConsumer,
                         ServingOutput, TrainerConsumer, TrainerOutput)
from .plan import (ComponentPlan, Plan, inference_tier, producer_tier,
                   serving_tier, trainer_tier)
from .session import InSituSession, SessionResult

__all__ = [
    "InSituSession",
    "SessionResult",
    "Producer",
    "TrainerConsumer",
    "InferenceConsumer",
    "ServingClients",
    "ServingConsumer",
    "ProducerOutput",
    "TrainerOutput",
    "InferenceOutput",
    "ServingClientsOutput",
    "ServingOutput",
    "Plan",
    "ComponentPlan",
    "producer_tier",
    "trainer_tier",
    "inference_tier",
    "serving_tier",
]
