"""Public entry for flash attention: kernel on TPU, oracle elsewhere.

``mha(q, k, v, causal, mode)``:
* mode="pallas"    — compiled Pallas kernel (TPU);
* mode="interpret" — Pallas kernel under interpret=True (CPU tests);
* mode="ref"/None-on-CPU — the jnp oracle (XLA's fusion is the right
  fallback off-TPU).

custom_vjp: forward takes the kernel path and saves (q, k, v, o, LSE);
backward runs the Pallas FlashAttention-2 kernels (``bwd.py``) — the
probabilities are recomputed tile-by-tile from the LSE, so neither pass
materializes O(S²) state, and causal tiles above the diagonal are skipped
in both directions.  GQA backward expands KV to the q-head grid and
group-sums dk/dv (the expansion exists only inside the backward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bwd import flash_attention_bwd
from .kernel import flash_attention
from .ref import mha_ref

__all__ = ["mha", "preferred_mode"]


def preferred_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def mha(q, k, v, causal: bool = True, mode: str | None = None):
    return _fwd(q, k, v, causal, mode)[0]


def _fwd(q, k, v, causal, mode):
    mode = mode or preferred_mode()
    if mode == "ref":
        out = mha_ref(q, k, v, causal)
        return out, (q, k, v, None, None)
    out, lse = flash_attention(q, k, v, causal=causal,
                               interpret=(mode == "interpret"),
                               return_lse=True)
    return out, (q, k, v, out, lse)


def _bwd(causal, mode, res, ct):
    q, k, v, o, lse = res
    mode = mode or preferred_mode()
    if mode == "ref" or o is None:
        _, vjp = jax.vjp(lambda q_, k_, v_: mha_ref(q_, k_, v_, causal),
                         q, k, v)
        return vjp(ct)
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    head_major = lambda t: t.transpose(0, 2, 1, 3).reshape(-1, t.shape[1], dh)
    qh, oh, doh = head_major(q), head_major(o), head_major(ct)
    # expand KV to the q-head grid (GQA backward)
    kexp = jnp.repeat(k, G, axis=2)
    vexp = jnp.repeat(v, G, axis=2)
    kh, vh = head_major(kexp), head_major(vexp)
    lseh = lse.transpose(0, 2, 1).reshape(-1, S)
    dqh, dkh, dvh = flash_attention_bwd(
        qh, kh, vh, oh, doh, lseh, causal=causal,
        interpret=(mode == "interpret"))
    back = lambda t, n: t.reshape(B, n, -1, dh).transpose(0, 2, 1, 3)
    dq = back(dqh, H)
    dk = back(dkh, H).reshape(B, T, K, G, dh).sum(3)
    dv = back(dvh, H).reshape(B, T, K, G, dh).sum(3)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


mha.defvjp(_fwd, _bwd)
