from .ops import mha, preferred_mode
from .ref import mha_ref

__all__ = ["mha", "mha_ref", "preferred_mode"]
