"""Pallas TPU flash attention (forward), causal GQA.

TPU adaptation of the FlashAttention schedule (Dao et al.): streaming
softmax over KV blocks with the running (m, l, acc) statistics held in VMEM
scratch across the innermost grid axis.

* grid = (B·H, S/blk_q, T/blk_k); the KV axis is innermost so each q-tile's
  statistics stay resident while KV tiles stream through VMEM.
* **Causal block skipping**: KV tiles strictly above the diagonal are
  predicated out with ``pl.when`` — Mosaic skips both the DMA and the MXU
  work, recovering the ~2× that the dense-mask fallback wastes (this is the
  kernel the roofline's "attention 2× slack" note refers to).
* GQA: the index map routes query head ``h`` to KV head ``h // G`` — no
  KV repetition is materialized.
* Tiles default to (128, 128): MXU-aligned; VMEM ≈ blk_q·dh + 2·blk_k·dh
  + blk_q·blk_k floats ≈ 0.2 MB — far under the 16 MB budget, leaving
  room for double-buffered KV streams.

Backward runs through the oracle (XLA recompute) via ``ops.py``'s
custom_vjp — the deployable training path keeps the fwd kernel's memory
win; a fused flash backward is a further optimization documented in
EXPERIMENTS §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
            blk_q: int, blk_k: int, n_k: int, causal: bool, scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: the whole KV tile is masked when it starts past the q tile's
    # last row — skip its DMA+compute entirely.
    if causal:
        run = (j * blk_k) <= (i * blk_q + blk_q - 1)
    else:
        run = j >= 0          # traced constant-true

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                        (blk_q, blk_k), 0)
            kpos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                        (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        # logsumexp per query row (consumed by the backward kernel)
        lse_ref[0] = (m_ref[...] + jnp.log(l)).astype(lse_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret", "return_lse"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False, return_lse: bool = False):
    """q: [B,S,H,dh]; k,v: [B,T,K,dh] → [B,S,H,dh] (+ LSE [B,S,H])."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, T)
    if S % blk_q or T % blk_k:
        raise ValueError(f"S={S}/T={T} must divide blocks ({blk_q},{blk_k})")
    n_q, n_k = S // blk_q, T // blk_k
    scale = 1.0 / (dh ** 0.5)

    # layout: fold heads into the leading grid axis
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
    kh = k.transpose(0, 2, 1, 3).reshape(B * K, T, dh)
    vh = v.transpose(0, 2, 1, 3).reshape(B * K, T, dh)

    def kv_index(bh, i, j):
        b = bh // H
        h = bh % H
        return (b * K + h // G, j, 0)

    out, lse = pl.pallas_call(
        functools.partial(_kernel, blk_q=blk_q, blk_k=blk_k, n_k=n_k,
                          causal=causal, scale=scale),
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, blk_k, dh), kv_index),
            pl.BlockSpec((1, blk_k, dh), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, blk_q), lambda bh, i, j: (bh, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, dh), q.dtype),
            jax.ShapeDtypeStruct((B * H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
    if return_lse:
        return out, lse.reshape(B, H, S).transpose(0, 2, 1)
    return out
