"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp
import jax

__all__ = ["mha_ref"]


def mha_ref(q, k, v, causal: bool = True) -> jnp.ndarray:
    """q: [B,S,H,dh]; k,v: [B,T,K,dh] (H = G·K grouped) → [B,S,H,dh]."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) / (dh ** 0.5)
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p.astype(q.dtype), v)
    return out.reshape(B, S, H, dh)
