"""Pallas TPU flash-attention BACKWARD (FlashAttention-2 style).

Recomputes the probabilities from (q, k, LSE) tile-by-tile — no O(S²)
materialization — in two passes with opposite accumulation orders:

* ``_dq_kernel``: grid (BH, i, j), KV innermost; accumulates
  dq_i = scale · Σ_j (p ∘ (do·vᵀ − D)) k_j in a VMEM scratch tile;
* ``_dkv_kernel``: grid (BH, j, i), Q innermost; accumulates
  dv_j = Σ_i pᵀ do_i and dk_j = scale · Σ_i (p ∘ (do·vᵀ − D))ᵀ q_i.

Both skip above-diagonal tiles under the causal mask (same 2× saving as
forward).  D_i = rowsum(do_i ∘ o_i) is a cheap jnp precomputation.  GQA is
handled in ``ops.py`` by expanding KV to the q-head grid and group-summing
dk/dv afterwards (the expansion exists only inside the backward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_bwd"]

NEG_INF = -1e30


def _p_and_ds(q, k, v, do, lse, d_rows, i, j, blk_q, blk_k, causal, scale):
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if causal:
        qpos = i * blk_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (blk_q, blk_k), 0)
        kpos = j * blk_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (blk_q, blk_k), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - d_rows[:, None])
    return p, ds


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dq_ref, acc_ref,
               *, blk_q, blk_k, n_k, causal, scale):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = (j * blk_k) <= (i * blk_q + blk_q - 1) if causal else j >= 0

    @pl.when(run)
    def _body():
        _, ds = _p_and_ds(q_ref[0].astype(jnp.float32),
                          k_ref[0].astype(jnp.float32),
                          v_ref[0].astype(jnp.float32),
                          do_ref[0].astype(jnp.float32),
                          lse_ref[0], d_ref[0], i, j, blk_q, blk_k,
                          causal, scale)
        acc_ref[...] += scale * jax.lax.dot_general(
            ds, k_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == n_k - 1)
    def _store():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, d_ref, dk_ref, dv_ref,
                dk_acc, dv_acc, *, blk_q, blk_k, n_q, causal, scale):
    j, i = pl.program_id(1), pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    run = (j * blk_k) <= (i * blk_q + blk_q - 1) if causal else i >= 0

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        p, ds = _p_and_ds(q, k_ref[0].astype(jnp.float32),
                          v_ref[0].astype(jnp.float32), do,
                          lse_ref[0], d_ref[0], i, j, blk_q, blk_k,
                          causal, scale)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(i == n_q - 1)
    def _store():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "blk_q", "blk_k",
                                             "interpret"))
def flash_attention_bwd(q, k, v, o, do, lse, causal: bool = True,
                        blk_q: int = 128, blk_k: int = 128,
                        interpret: bool = False):
    """All inputs head-major MHA layout: q/k/v/o/do [BH, S, dh],
    lse [BH, S] → (dq, dk, dv) with the input dtypes."""
    BH, S, dh = q.shape
    T = k.shape[1]
    blk_q = min(blk_q, S)
    blk_k = min(blk_k, T)
    if S % blk_q or T % blk_k:
        raise ValueError("block sizes must divide sequence lengths")
    n_q, n_k = S // blk_q, T // blk_k
    scale = 1.0 / (dh ** 0.5)
    d_rows = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)

    common = dict(blk_q=blk_q, blk_k=blk_k, causal=causal, scale=scale)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, n_k=n_k, **common),
        grid=(BH, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, blk_q), lambda b, i, j: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((blk_q, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, d_rows)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, n_q=n_q, **common),
        grid=(BH, n_k, n_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_q, dh), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, blk_q), lambda b, j, i: (b, i)),
            pl.BlockSpec((1, blk_q), lambda b, j, i: (b, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_k, dh), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, dh), k.dtype),
            jax.ShapeDtypeStruct((BH, T, dh), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((blk_k, dh), jnp.float32),
                        pltpu.VMEM((blk_k, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, d_rows)
    return dq, dk, dv
