from .ops import quadconv_contract, preferred_mode
from .ref import quadconv_contract as quadconv_contract_ref

__all__ = ["quadconv_contract", "quadconv_contract_ref", "preferred_mode"]
