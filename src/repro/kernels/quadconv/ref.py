"""Pure-jnp oracle for the QuadConv quadrature contraction.

QuadConv (Doherty et al. 2023, arXiv:2211.05151) approximates a continuous
convolution with a single quadrature sum over non-uniform points:

    out[b, j, o] = sum_i sum_c  w[i] * G[j, i, o, c] * f[b, i, c]

where ``w`` are learned quadrature weights over the I input points, ``G`` is
the MLP-parameterized kernel evaluated at point-pair offsets, f has C input
channels, and the output lives on J (possibly different) points with O
channels.  This contraction is the FLOPs hot spot of the paper's autoencoder
(everything else is small MLPs), hence the Pallas kernel next door.

The contraction is a single GEMM in disguise:

    out[b, (j,o)] = sum_{(i,c)} (w[i] f[b,i,c]) · G^T[(i,c), (j,o)]

which is exactly how both the kernel and this oracle compute it.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["quadconv_contract"]


def quadconv_contract(f: jnp.ndarray, w: jnp.ndarray, g: jnp.ndarray
                      ) -> jnp.ndarray:
    """out[b,j,o] = Σ_{i,c} w[i] G[j,i,o,c] f[b,i,c].

    Args:
      f: [B, I, C] input features on I quadrature points.
      w: [I] quadrature weights.
      g: [J, I, O, C] kernel tensor (MLP(x_j - y_i), compact-support masked).
    Returns:
      [B, J, O]
    """
    return jnp.einsum("i,jioc,bic->bjo", w, g, f,
                      preferred_element_type=jnp.float32).astype(f.dtype)
