"""Pallas TPU kernel for the QuadConv quadrature contraction.

TPU adaptation (vs the paper's CUDA/PyTorch path): the contraction

    out[b, j, o] = Σ_{i,c} w[i] · G[j,i,o,c] · f[b,i,c]

is reshaped into a single GEMM  ``out[B, J·O] = F'[B, I·C] @ Gm[I·C, J·O]``
with the quadrature weighting ``F' = f ⊙ w`` **fused into the LHS load** —
so the weighted field is never materialized in HBM.  The kernel is a
classic MXU-tiled matmul:

* grid = (B/bm, J·O/bn, I·C/bk); the K axis is innermost so each (m, n)
  output tile stays resident in VMEM across the K loop (accumulate in
  fp32), written once on the last K step.
* block shapes default to (128, 128, 512): MXU-aligned 128-lane tiles;
  VMEM footprint = bm·bk (F) + bk·bn (G) + bm·bn (acc) floats
  = (128·512 + 512·128 + 128·128)·4B ≈ 0.6 MB ≪ 16 MB v5e VMEM,
  leaving room for double buffering of the streamed G tiles.
* ``w`` is pre-expanded to the flattened I·C axis by the ops wrapper (a
  [bk] vector per K tile, broadcast-multiplied into the F tile on load —
  one VPU multiply per element, free next to the MXU work).

On CPU the kernel runs under ``interpret=True`` (tests); ``ops.py`` picks
the execution mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["quadconv_matmul"]


def _kernel(f_ref, w_ref, g_ref, out_ref, acc_ref, *, n_k: int):
    """One (m, n, k) grid step: acc += (F ⊙ w)[m, k] @ G[k, n]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    f_blk = f_ref[...].astype(jnp.float32) * w_ref[...].astype(jnp.float32)[None, :]
    g_blk = g_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        f_blk, g_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quadconv_matmul(fm: jax.Array, wk: jax.Array, gm: jax.Array,
                    bm: int = 128, bn: int = 128, bk: int = 512,
                    interpret: bool = False) -> jax.Array:
    """Fused quadrature-weighted GEMM.

    Args:
      fm: [M, K]  flattened features (M = batch, K = I·C).
      wk: [K]     quadrature weights pre-broadcast to the K axis.
      gm: [K, N]  flattened kernel tensor (N = J·O).
    Returns:
      [M, N] = (fm ⊙ wk) @ gm
    """
    m, k = fm.shape
    k2, n = gm.shape
    assert k == k2 and wk.shape == (k,), (fm.shape, wk.shape, gm.shape)
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    if m % bm_ or n % bn_ or k % bk_:
        raise ValueError(
            f"shapes ({m},{n},{k}) must divide block ({bm_},{bn_},{bk_}); "
            "ops.py pads before calling")
    n_k = k // bk_
    grid = (m // bm_, n // bn_, n_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_,), lambda i, j, kk: (kk,)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), fm.dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.float32)],
        interpret=interpret,
    )(fm, wk, gm)
