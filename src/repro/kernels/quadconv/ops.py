"""Public entry point for the QuadConv contraction.

``quadconv_contract(f, w, g)`` computes

    out[b,j,o] = Σ_{i,c} w[i] · G[j,i,o,c] · f[b,i,c]

dispatching to:
* the Pallas kernel (compiled) on TPU backends;
* the Pallas kernel under ``interpret=True`` when ``mode="interpret"``
  (kernel-correctness tests on CPU);
* the pure-jnp oracle otherwise (CPU training runs — XLA's native GEMM is
  the right tool off-TPU).

The wrapper performs the layout work the kernel expects:
  f [B,I,C]   -> fm [B, I·C]           (row-major flatten)
  w [I]       -> wk [I·C]              (repeat each weight C times)
  g [J,I,O,C] -> gm [I·C, J·O]         (transpose to (I,C,J,O), flatten)
and pads every GEMM dim up to the block size (zero padding is exact for a
sum contraction).  A custom VJP reuses the same GEMM for both gradient
contractions, so the backward pass also hits the MXU kernel on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .kernel import quadconv_matmul

__all__ = ["quadconv_contract", "preferred_mode"]


def preferred_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _contract_gemm(fm, wk, gm, mode, bm, bn, bk):
    m, k = fm.shape
    n = gm.shape[1]
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    fm_p = _pad_to(_pad_to(fm, 0, bm_), 1, bk_)
    wk_p = _pad_to(wk, 0, bk_)
    gm_p = _pad_to(_pad_to(gm, 0, bk_), 1, bn_)
    out = quadconv_matmul(fm_p, wk_p, gm_p, bm=bm_, bn=bn_, bk=bk_,
                          interpret=(mode == "interpret"))
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def quadconv_contract(f: jax.Array, w: jax.Array, g: jax.Array,
                      mode: str | None = None, bm: int = 128, bn: int = 128,
                      bk: int = 512) -> jax.Array:
    """out[b,j,o] = Σ_{i,c} w[i] G[j,i,o,c] f[b,i,c].  See module docstring."""
    return _fwd(f, w, g, mode, bm, bn, bk)[0]


def _fwd(f, w, g, mode, bm, bn, bk):
    mode = mode or preferred_mode()
    b, i, c = f.shape
    j, i2, o, c2 = g.shape
    assert (i, c) == (i2, c2) and w.shape == (i,), (f.shape, w.shape, g.shape)
    if mode == "ref":
        return _ref.quadconv_contract(f, w, g), (f, w, g)
    fm = f.reshape(b, i * c)
    wk = jnp.repeat(w, c)
    gm = g.transpose(1, 3, 0, 2).reshape(i * c, j * o)
    out = _contract_gemm(fm, wk, gm, mode, bm, bn, bk)
    return out.reshape(b, j, o), (f, w, g)


def _bwd(mode, bm, bn, bk, res, ct):
    f, w, g = res
    # ct: [B,J,O]
    # df[b,i,c] = w[i] Σ_{j,o} G[j,i,o,c] ct[b,j,o]
    # dw[i]     = Σ_{b,j,o,c} G[j,i,o,c] f[b,i,c] ct[b,j,o]
    # dG[j,i,o,c] = w[i] f[b,i,c] ct[b,j,o] summed over b
    df = jnp.einsum("bjo,jioc,i->bic", ct, g, w).astype(f.dtype)
    dw = jnp.einsum("bjo,jioc,bic->i", ct, g, f).astype(w.dtype)
    dg = jnp.einsum("bjo,bic,i->jioc", ct, f, w).astype(g.dtype)
    return df, dw, dg


quadconv_contract.defvjp(_fwd, _bwd)
