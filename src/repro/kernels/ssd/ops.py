"""Public entry: full chunked SSD scan with the Pallas intra-chunk kernel.

``ssd_scan(xdt, a, b_coef, c_coef, chunk, mode)`` reproduces
``models.ssd.ssd_scan_chunked`` exactly, with the parallel intra-chunk
heavy lifting in the kernel (TPU) and the O(S/chunk) inter-chunk
recurrence as a tiny jnp scan.  mode: "pallas" | "interpret" | "ref".
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernel import ssd_intra
from .ref import ssd_intra_ref

__all__ = ["ssd_scan", "preferred_mode"]


def preferred_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def ssd_scan(xdt, a, b_coef, c_coef, chunk: int, mode: str | None = None,
             h0=None):
    """Same contract as models.ssd.ssd_scan_chunked: returns (y, h_final)."""
    mode = mode or preferred_mode()
    B, S, H, P = xdt.shape
    N = b_coef.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        raise ValueError(f"S={S} must divide chunk={Q} (pad upstream)")
    nc = S // Q
    fold = lambda t: t.reshape(B * nc if False else B, nc, Q, *t.shape[2:]) \
        .reshape(B * nc, Q, *t.shape[2:])
    xdt_c = xdt.reshape(B, nc, Q, H, P).reshape(B * nc, Q, H, P)
    a_c = a.reshape(B, nc, Q, H).reshape(B * nc, Q, H)
    b_c = b_coef.reshape(B, nc, Q, N).reshape(B * nc, Q, N)
    c_c = c_coef.reshape(B, nc, Q, N).reshape(B * nc, Q, N)

    if mode == "ref":
        y_i, states, cum = ssd_intra_ref(xdt_c, a_c, b_c, c_c)
    else:
        y_i, states, cum = ssd_intra(xdt_c, a_c, b_c, c_c,
                                     interpret=(mode == "interpret"))

    y_i = y_i.reshape(B, nc, Q, H, P)
    states = states.reshape(B, nc, H, P, N)
    cum = cum.reshape(B, nc, Q, H)
    c_r = c_coef.reshape(B, nc, Q, N).astype(jnp.float32)
    chunk_decay = jnp.exp(cum[:, :, -1, :])            # [B,nc,H]

    # inter-chunk recurrence: h_c = decay_c · h_{c-1} + S_c  (tiny scan)
    h_init = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None \
        else h0.astype(jnp.float32)

    def step(h, inp):
        s_c, d_c = inp                                  # [B,H,P,N],[B,H]
        h_prev = h
        h = d_c[:, :, None, None] * h + s_c
        return h, h_prev

    h_final, h_prevs = jax.lax.scan(
        step, h_init, (states.transpose(1, 0, 2, 3, 4),
                       chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)          # [B,nc,H,P,N]

    # inter-chunk contribution: y_i += exp(cum) · C_i · h_prev
    y_x = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", c_r, h_prevs, jnp.exp(cum))
    y = (y_i + y_x).reshape(B, S, H, P)
    return y, h_final
