"""Pallas TPU kernel for the SSD intra-chunk contraction (Mamba-2).

One grid step = one (sequence-chunk × head-block): the decay-weighted
"attention-like" matmul ``(C Bᵀ ∘ L) · X`` plus the chunk-state outer
product, all in VMEM:

* grid = (B·n_chunks, H/blk_h); chunks are independent (the sequential
  inter-chunk recurrence stays outside — it is O(S/Q) tiny updates);
* VMEM per step @ Q=128, blk_h=8, P=64, N=128:
  xdt (128·8·64) + scores (128²) + W (128²·8) + y + state ≈ 1.3 MB fp32 —
  double-bufferable against the 16 MB budget;
* the (Q×Q) score matmul and the (Q×Q)@(Q×P) contraction per head hit the
  MXU; cumsum/exp decay math rides the VPU.

Numerics follow the chunked reference exactly (fp32 throughout).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_intra"]


def _kernel(xdt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, cum_ref, *,
            q: int, blk_h: int, p: int):
    xdt = xdt_ref[0].astype(jnp.float32)       # [Q, blk_h, P]
    a = a_ref[0].astype(jnp.float32)           # [Q, blk_h]
    b = b_ref[0].astype(jnp.float32)           # [Q, N]
    c = c_ref[0].astype(jnp.float32)           # [Q, N]
    cum = jnp.cumsum(a, axis=0)                # [Q, blk_h]
    # decay matrix per head: L[i,j,h] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None, :] - cum[None, :, :]   # [Q, Q, blk_h]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    w = jnp.where((ii >= jj)[:, :, None], jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    sw = scores[:, :, None] * w                # [Q, Q, blk_h]
    # y[i,h,p] = Σ_j sw[i,j,h] xdt[j,h,p]  — batched matmul over h
    y = jnp.einsum("ijh,jhp->ihp", sw, xdt,
                   preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)
    # state[h,p,n] = Σ_j xdt[j,h,p] b[j,n] exp(cum_last - cum_j)
    decay_end = jnp.exp(cum[-1:, :] - cum)     # [Q, blk_h]
    xw = xdt * decay_end[:, :, None]
    state = jnp.einsum("jhp,jn->hpn", xw, b,
                       preferred_element_type=jnp.float32)
    state_ref[0] = state.astype(state_ref.dtype)
    cum_ref[0] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_h", "interpret"))
def ssd_intra(xdt: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
              blk_h: int = 8, interpret: bool = False):
    """xdt:[BC,Q,H,P], a:[BC,Q,H], b,c:[BC,Q,N] →
    (y [BC,Q,H,P] f32, state [BC,H,P,N] f32, cum [BC,Q,H] f32)."""
    BC, Q, H, P = xdt.shape
    N = b.shape[-1]
    blk_h = min(blk_h, H)
    if H % blk_h:
        raise ValueError(f"H={H} not divisible by blk_h={blk_h}")
    nh = H // blk_h
    grid = (BC, nh)
    y, state, cum = pl.pallas_call(
        functools.partial(_kernel, q=Q, blk_h=blk_h, p=P),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, blk_h, P), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, Q, blk_h), lambda i, h: (i, 0, h)),
            pl.BlockSpec((1, Q, N), lambda i, h: (i, 0, 0)),
            pl.BlockSpec((1, Q, N), lambda i, h: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, blk_h, P), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((1, blk_h, P, N), lambda i, h: (i, h, 0, 0)),
            pl.BlockSpec((1, Q, blk_h), lambda i, h: (i, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BC, Q, H, P), jnp.float32),
            jax.ShapeDtypeStruct((BC, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((BC, Q, H), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, a, b, c)
    return y, state, cum
