from .ops import preferred_mode, ssd_scan
from .ref import ssd_intra_ref

__all__ = ["ssd_scan", "ssd_intra_ref", "preferred_mode"]
