"""Pure-jnp oracle for the SSD intra-chunk computation.

Per chunk (the Mamba-2 chunked algorithm's parallel part):
    cum_i   = Σ_{l≤i} a_l                          (within-chunk decay)
    Y_i     = Σ_{j≤i} (C_i·B_j) · exp(cum_i−cum_j) · xdt_j   (intra output)
    S       = Σ_j  xdt_j ⊗ B_j · exp(cum_last−cum_j)          (chunk state)
The inter-chunk recurrence (sequential, tiny) stays outside the kernel.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["ssd_intra_ref"]


def ssd_intra_ref(xdt, a, b, c):
    """xdt:[BC,Q,H,P] (B·chunks folded), a:[BC,Q,H], b,c:[BC,Q,N]
    → (y:[BC,Q,H,P], state:[BC,H,P,N], cum:[BC,Q,H])."""
    xdt = xdt.astype(jnp.float32)
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    c = c.astype(jnp.float32)
    Q = xdt.shape[1]
    cum = jnp.cumsum(a, axis=1)
    diff = cum[:, :, None, :] - cum[:, None, :, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    w = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bin,bjn->bij", c, b)
    y = jnp.einsum("bij,bijh,bjhp->bihp", scores, w, xdt)
    decay_end = jnp.exp(cum[:, -1:, :] - cum)
    state = jnp.einsum("bjhp,bjn,bjh->bhpn", xdt, b, decay_end)
    return y, state, cum
