"""Public entry points for the store access kernels.

Mode dispatch follows the repo-wide idiom (attention / quadconv / ssd):

* ``"pallas"``    — compiled TPU kernels (default on TPU backends);
* ``"interpret"`` — the same kernels under the Pallas interpreter
  (CPU parity tests exercise the real BlockSpec machinery);
* ``"ref"``       — the pure-jnp oracle (default off-TPU; XLA's native
  sort/gather are the right tool there).

All three produce bit-identical results: the parity tests in
``tests/test_store_kernels.py`` assert exact equality, and
``core.store`` routes ``get_many`` / ``sample`` through these entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref

__all__ = ["preferred_mode", "probe_slots", "sample_slots", "gather_rows"]


def preferred_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def probe_slots(table_keys: jax.Array, version: jax.Array, query: jax.Array,
                mode: str | None = None):
    """First valid slot per query key → ``(idx i32[n], found bool[n])``.

    ``idx == capacity`` (and ``found == False``) where the key is absent.
    """
    mode = mode or preferred_mode()
    query = jnp.asarray(query, jnp.uint32)
    if mode == "ref":
        return _ref.probe_slots_ref(table_keys, version, query)
    idx = _k.probe(table_keys, version, query,
                   interpret=(mode == "interpret"))
    return idx, idx < table_keys.shape[0]


def sample_slots(version: jax.Array, ranks: jax.Array,
                 mode: str | None = None) -> jax.Array:
    """Slot of the ``r``-th valid entry for each rank (``r`` in [0, nvalid))."""
    mode = mode or preferred_mode()
    if mode == "ref":
        return _ref.sample_slots_ref(version, ranks)
    return _k.sample(version, ranks, interpret=(mode == "interpret"))


def gather_rows(slab: jax.Array, slots: jax.Array,
                mode: str | None = None) -> jax.Array:
    """``slab[slots]`` row gather; ``slots`` must already be in range."""
    mode = mode or preferred_mode()
    if mode == "ref":
        return _ref.gather_rows_ref(slab, slots)
    return _k.gather(slab, slots, interpret=(mode == "interpret"))
