"""Public entry points for the store access kernels.

Mode dispatch follows the repo-wide idiom (attention / quadconv / ssd):

* ``"pallas"``    — compiled TPU kernels (default on TPU backends);
* ``"interpret"`` — the same kernels under the Pallas interpreter
  (CPU parity tests exercise the real BlockSpec machinery);
* ``"ref"``       — the pure-jnp oracle (default off-TPU; XLA's native
  sort/gather are the right tool there).

All three produce bit-identical results: the parity tests in
``tests/test_store_kernels.py`` assert exact equality, and
``core.store`` routes ``get_many`` / ``sample`` through these entries.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernel as _k
from . import ref as _ref

__all__ = ["preferred_mode", "probe_slots", "sample_slots", "gather_rows",
           "gather_rows_sharded"]


def preferred_mode() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def probe_slots(table_keys: jax.Array, version: jax.Array, query: jax.Array,
                mode: str | None = None):
    """First valid slot per query key → ``(idx i32[n], found bool[n])``.

    ``idx == capacity`` (and ``found == False``) where the key is absent.
    """
    mode = mode or preferred_mode()
    query = jnp.asarray(query, jnp.uint32)
    if mode == "ref":
        return _ref.probe_slots_ref(table_keys, version, query)
    idx = _k.probe(table_keys, version, query,
                   interpret=(mode == "interpret"))
    return idx, idx < table_keys.shape[0]


def sample_slots(version: jax.Array, ranks: jax.Array,
                 mode: str | None = None) -> jax.Array:
    """Slot of the ``r``-th valid entry for each rank (``r`` in [0, nvalid))."""
    mode = mode or preferred_mode()
    if mode == "ref":
        return _ref.sample_slots_ref(version, ranks)
    return _k.sample(version, ranks, interpret=(mode == "interpret"))


def gather_rows(slab: jax.Array, slots: jax.Array,
                mode: str | None = None) -> jax.Array:
    """``slab[slots]`` row gather; ``slots`` must already be in range."""
    mode = mode or preferred_mode()
    if mode == "ref":
        return _ref.gather_rows_ref(slab, slots)
    return _k.gather(slab, slots, interpret=(mode == "interpret"))


def gather_rows_sharded(local_slab: jax.Array, slots: jax.Array, offset,
                        mode: str | None = None) -> jax.Array:
    """Shard-local row gather for a slot-axis-sharded slab.

    ``local_slab [Cl, *elem]`` is this rank's slice, ``slots i32[n]`` are
    global slot indices (in ``[0, capacity)``), ``offset`` the rank's
    first global slot.  Returns ``[n, *elem]`` rows with zeros where the
    slot is owned by another shard; summing the per-shard results
    (``lax.psum`` inside a ``shard_map``) reassembles the full batch —
    each global slot has exactly one owner, so the sum is exact.
    """
    mode = mode or preferred_mode()
    slots = jnp.asarray(slots, jnp.int32)
    if mode == "ref":
        return _ref.gather_rows_sharded_ref(local_slab, slots, offset)
    return _k.gather_sharded(local_slab, slots, offset,
                             interpret=(mode == "interpret"))
