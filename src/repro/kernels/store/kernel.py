"""Pallas TPU kernels for fused TensorStore access.

Three kernels, all bounded-memory (no ``[n, capacity]`` materialization):

* ``probe`` — key lookup: one grid step per query block keeps the whole
  (tiny) slot-metadata vectors in VMEM and folds capacity in ``blk_c``
  chunks with a running min-slot accumulator; the transient match tile is
  ``[blk_q, blk_c]``, independent of n and capacity.
* ``sample`` — valid-slot selection: cumulative valid count over the slot
  metadata (VPU cumsum), then the same blocked fold counts
  ``Σ_j [cum_j <= r]`` — a branch-free binary-search equivalent.
* ``gather`` — the slab row fetch: scalar-prefetched slot indices drive
  the input ``BlockSpec`` index map, so each grid step DMAs exactly one
  slab row HBM→VMEM→out (the idiomatic TPU gather; the slab never passes
  through an intermediate).

On CPU the kernels run under ``interpret=True`` (parity tests); ``ops.py``
selects the execution mode and handles padding to block multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["probe", "sample", "gather", "gather_sharded"]

# numpy scalar: inlined as a literal rather than captured as a traced const
_EMPTY = np.uint32(0xFFFFFFFF)


def _pad1(x, mult, fill):
    pad = (-x.shape[0]) % mult
    if pad:
        x = jnp.concatenate([x, jnp.full((pad,), fill, x.dtype)])
    return x


# ---------------------------------------------------------------------------
# probe: first valid slot per query key
# ---------------------------------------------------------------------------

def _probe_kernel(keys_ref, ver_ref, query_ref, idx_ref, *, blk_c: int,
                  n_c: int, capacity: int):
    q = query_ref[0, :]                                   # [blk_q] uint32
    blk_q = q.shape[0]

    def fold(c, best):
        ks = keys_ref[0, pl.ds(c * blk_c, blk_c)]          # [blk_c]
        vs = ver_ref[0, pl.ds(c * blk_c, blk_c)]
        match = (q[:, None] == ks[None, :]) & (vs > 0)[None, :] \
            & (q != _EMPTY)[:, None]                       # [blk_q, blk_c]
        slot = c * blk_c + jax.lax.broadcasted_iota(
            jnp.int32, (blk_q, blk_c), 1)
        cand = jnp.where(match, slot, capacity)
        return jnp.minimum(best, jnp.min(cand, axis=1))

    best = jax.lax.fori_loop(
        0, n_c, fold, jnp.full((blk_q,), capacity, jnp.int32))
    idx_ref[0, :] = best


@functools.partial(jax.jit, static_argnames=("blk_q", "blk_c", "interpret"))
def probe(table_keys: jax.Array, version: jax.Array, query: jax.Array,
          blk_q: int = 128, blk_c: int = 128, interpret: bool = False):
    """keys u32[C], version i32[C], query u32[n] → idx i32[n] (C = absent)."""
    capacity = table_keys.shape[0]
    n = query.shape[0]
    keys_p = _pad1(table_keys.astype(jnp.uint32), blk_c, _EMPTY)[None, :]
    ver_p = _pad1(version.astype(jnp.int32), blk_c, 0)[None, :]
    q_p = _pad1(query.astype(jnp.uint32), blk_q, _EMPTY)
    g = q_p.shape[0] // blk_q
    n_c = keys_p.shape[1] // blk_c
    idx = pl.pallas_call(
        functools.partial(_probe_kernel, blk_c=blk_c, n_c=n_c,
                          capacity=capacity),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, keys_p.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((1, ver_p.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((1, blk_q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, blk_q), jnp.int32),
        interpret=interpret,
    )(keys_p, ver_p, q_p.reshape(g, blk_q))
    return idx.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# sample: slot of the r-th valid entry
# ---------------------------------------------------------------------------

def _sample_kernel(ver_ref, r_ref, out_ref, *, blk_c: int, n_c: int):
    valid = (ver_ref[...] > 0).astype(jnp.int32)           # [1, Cp]
    cum = jnp.cumsum(valid, axis=1)                        # [1, Cp]
    r = r_ref[0, :]                                        # [blk_q]
    blk_q = r.shape[0]

    def fold(c, acc):
        cc = jax.lax.dynamic_slice(cum, (0, c * blk_c), (1, blk_c))[0]
        tile = (cc[None, :] <= r[:, None]).astype(jnp.int32)
        return acc + jnp.sum(tile, axis=1)

    out_ref[0, :] = jax.lax.fori_loop(
        0, n_c, fold, jnp.zeros((blk_q,), jnp.int32))


@functools.partial(jax.jit, static_argnames=("blk_q", "blk_c", "interpret"))
def sample(version: jax.Array, ranks: jax.Array, blk_q: int = 128,
           blk_c: int = 128, interpret: bool = False):
    """version i32[C], ranks i32[n] → slots i32[n] (r-th valid slot)."""
    n = ranks.shape[0]
    ver_p = _pad1(version.astype(jnp.int32), blk_c, 0)[None, :]
    # Padded rank lanes get -1 → slot 0; they are sliced off below.
    r_p = _pad1(ranks.astype(jnp.int32), blk_q, -1)
    g = r_p.shape[0] // blk_q
    n_c = ver_p.shape[1] // blk_c
    slots = pl.pallas_call(
        functools.partial(_sample_kernel, blk_c=blk_c, n_c=n_c),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, ver_p.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((1, blk_q), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((g, blk_q), jnp.int32),
        interpret=interpret,
    )(ver_p, r_p.reshape(g, blk_q))
    return slots.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# gather: slab row fetch via scalar-prefetched indices
# ---------------------------------------------------------------------------

def _gather_kernel(idx_ref, slab_ref, out_ref):
    del idx_ref  # consumed by the BlockSpec index maps
    out_ref[...] = slab_ref[...]


def _gather_sharded_kernel(meta_ref, slab_ref, out_ref, *, local_cap: int):
    # meta = [shard_offset, slot_0, ..., slot_{n-1}] (scalar-prefetched).
    i = pl.program_id(0)
    off = meta_ref[0]
    slot = meta_ref[i + 1]
    owned = (slot >= off) & (slot < off + local_cap)
    row = slab_ref[...]
    out_ref[...] = jnp.where(owned, row, jnp.zeros_like(row))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather(slab: jax.Array, slots: jax.Array, interpret: bool = False):
    """slab [C, *elem], slots i32[n] (in-range) → rows [n, *elem]."""
    capacity = slab.shape[0]
    elem = slab.shape[1:]
    n = slots.shape[0]
    feat = 1
    for d in elem:
        feat *= d
    slab2 = slab.reshape(capacity, max(feat, 1))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, slab2.shape[1]),
                               lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, slab2.shape[1]),
                               lambda i, idx_ref: (i, 0)),
    )
    rows = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, slab2.shape[1]), slab.dtype),
        interpret=interpret,
    )(slots.astype(jnp.int32), slab2)
    return rows.reshape((n, *elem))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_sharded(local_slab: jax.Array, slots: jax.Array, offset,
                   interpret: bool = False):
    """Shard-local row gather for a slot-axis-sharded slab.

    ``local_slab [Cl, *elem]`` is THIS shard's slice of the global
    ``[capacity, *elem]`` slab; ``slots i32[n]`` are *global* slot indices
    (already clamped in ``[0, capacity)``); ``offset`` (traced scalar) is
    the shard's first global slot.  Rows whose slot lives on this shard
    are DMA'd out of the local slab (same scalar-prefetch indexing as
    :func:`gather`, clamped into the local range); rows owned elsewhere
    come out as zeros — the caller ``psum``s across shards to assemble
    the full batch, which is the explicit collective that replaces the
    replicated slab read.
    """
    local_cap = local_slab.shape[0]
    elem = local_slab.shape[1:]
    n = slots.shape[0]
    feat = 1
    for d in elem:
        feat *= d
    slab2 = local_slab.reshape(local_cap, max(feat, 1))
    meta = jnp.concatenate([
        jnp.asarray(offset, jnp.int32).reshape(1),
        slots.astype(jnp.int32)])
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec(
            (1, slab2.shape[1]),
            lambda i, m: (jnp.clip(m[i + 1] - m[0], 0, local_cap - 1), 0))],
        out_specs=pl.BlockSpec((1, slab2.shape[1]),
                               lambda i, m: (i, 0)),
    )
    rows = pl.pallas_call(
        functools.partial(_gather_sharded_kernel, local_cap=local_cap),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, slab2.shape[1]), local_slab.dtype),
        interpret=interpret,
    )(meta, slab2)
    return rows.reshape((n, *elem))
