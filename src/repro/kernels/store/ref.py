"""Pure-jnp oracles for the store access kernels.

These are *also* the production CPU path (like the other kernel packages'
refs), so they must share the kernels' complexity contract: no
``[n, capacity]`` match matrix.  Key probing sorts the slot keys once
(O(capacity log capacity)) and binary-searches the ``n`` queries
(O(n log capacity)); sampling maps uniform ranks onto valid slots through
the cumulative-valid-count vector with the same binary search.

Tie-breaking contract (shared with ``kernel.py``): when several valid
slots hold the same key, the *lowest* slot index wins — the historical
``argmax``-of-match behavior of ``core.store.get_many``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["probe_slots_ref", "sample_slots_ref", "gather_rows_ref",
           "gather_rows_sharded_ref", "EMPTY_KEY"]

EMPTY_KEY = jnp.uint32(0xFFFFFFFF)


def probe_slots_ref(table_keys: jax.Array, version: jax.Array,
                    query: jax.Array):
    """First valid slot holding each query key.

    Args:
      table_keys: uint32[capacity] per-slot keys.
      version:    int32[capacity]; > 0 where the slot is live.
      query:      uint32[n] keys to look up (``EMPTY_KEY`` never matches).
    Returns:
      ``(idx int32[n], found bool[n])`` — ``idx == capacity`` where absent.
    """
    capacity = table_keys.shape[0]
    valid = version > 0
    # Tombstoned/empty slots sort to the end (EMPTY_KEY is the max uint32);
    # stable argsort keeps equal keys in slot order, so side="left" search
    # lands on the lowest matching slot.
    masked = jnp.where(valid, table_keys, EMPTY_KEY)
    order = jnp.argsort(masked)
    sorted_keys = masked[order]
    pos = jnp.searchsorted(sorted_keys, query, side="left", method="scan")
    pos_c = jnp.minimum(pos, capacity - 1)
    found = (sorted_keys[pos_c] == query) & (query != EMPTY_KEY) \
        & (pos < capacity)
    idx = jnp.where(found, order[pos_c], capacity).astype(jnp.int32)
    return idx, found


def sample_slots_ref(version: jax.Array, ranks: jax.Array) -> jax.Array:
    """Slot index of the ``r``-th valid slot for each rank ``r``.

    ``ranks`` must lie in ``[0, nvalid)`` (the caller draws them uniformly);
    out-of-range ranks return ``capacity`` (caller clamps/handles).
    """
    cum = jnp.cumsum((version > 0).astype(jnp.int32))
    return jnp.searchsorted(cum, ranks.astype(jnp.int32), side="right",
                            method="scan").astype(jnp.int32)


def gather_rows_ref(slab: jax.Array, slots: jax.Array) -> jax.Array:
    """Row gather ``slab[slots]`` (slots already clamped in-range)."""
    return jnp.take(slab, slots, axis=0)


def gather_rows_sharded_ref(local_slab: jax.Array, slots: jax.Array,
                            offset) -> jax.Array:
    """Shard-local row gather: ``local_slab [Cl, *elem]`` is one shard of
    the slot-axis-sharded slab, ``slots`` are global indices, ``offset``
    is this shard's first global slot.  Rows owned by other shards come
    out as zeros (the caller psums shards together)."""
    local_cap = local_slab.shape[0]
    offset = jnp.asarray(offset, jnp.int32)
    local = jnp.clip(slots.astype(jnp.int32) - offset, 0, local_cap - 1)
    rows = jnp.take(local_slab, local, axis=0)
    owned = (slots >= offset) & (slots < offset + local_cap)
    mask = owned.reshape((-1,) + (1,) * (local_slab.ndim - 1))
    return jnp.where(mask, rows, jnp.zeros_like(rows))
