"""Fused TensorStore access kernels (probe / sample / gather).

The hot consumer verbs of the in-situ store — ``get_many`` (key lookup)
and ``sample`` (uniform gather of valid slots) — are memory-bound passes
over per-slot metadata followed by a row gather from the slab.  The naive
jnp formulation materializes an ``[n, capacity]`` match matrix (and the
``-inf``-logits ``categorical`` does the same internally); these kernels
replace it with blocked single passes over the slot metadata plus a
scalar-prefetch row gather, O(n + capacity) memory.

Layout mirrors the other kernel packages (attention / quadconv / ssd):
``kernel.py`` (Pallas TPU), ``ref.py`` (pure-jnp oracle, also free of
quadratic intermediates), ``ops.py`` (mode dispatch + padding).
"""

from .ops import (gather_rows, gather_rows_sharded, preferred_mode,
                  probe_slots, sample_slots)

__all__ = ["probe_slots", "sample_slots", "gather_rows",
           "gather_rows_sharded", "preferred_mode"]
