"""Mamba-2 / SSD mixer (state-space duality, arXiv:2405.21060).

The selective state-space recurrence with scalar per-head decay,

    h_t = exp(Δt·A) · h_{t-1} + (Δt·x_t) ⊗ B_t ,   y_t = C_t·h_t + D·x_t,

evaluated with the paper's **chunked (matmul) algorithm**: the sequence is
split into chunks of Q steps; within a chunk the contribution is a masked
"attention-like" matmul ``(C Bᵀ ∘ L) X`` (MXU work), and chunk states are
carried by a short sequential scan — O(S·Q) instead of O(S²), and exactly
equal to the recurrence (tested against the sequential reference).

Block layout follows Mamba-2: in-proj → (z gate | x | B | C | Δt), causal
depthwise conv(4) on x/B/C, SSD core, gated RMSNorm, out-proj.  ``n_groups=1``
(B/C shared across heads).  Decode keeps a conv tail + the [H,P,N] state —
O(1) per token, which is why the SSM/hybrid archs own the ``long_500k`` cell.

Sharding: heads/inner channels over ``model`` (TP); B/C projections are
small and replicated.  Jamba's mamba layers reuse this block unchanged
(Jamba ships Mamba-1; we use the SSD successor as the TPU-native form —
scalar-decay recurrences map to matmul chunks, Mamba-1's per-channel A does
not; recorded in DESIGN.md).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamSpec, shard
from .layers import rmsnorm

__all__ = ["MambaCache", "ssd_specs", "ssd_apply", "ssd_decode",
           "init_mamba_cache", "ssd_scan_ref", "ssd_scan_chunked"]


class MambaCache(NamedTuple):
    conv_x: jax.Array   # [B, k-1, di]
    conv_b: jax.Array   # [B, k-1, N]
    conv_c: jax.Array   # [B, k-1, N]
    state: jax.Array    # [B, H, P, N]


def ssd_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    D, di = cfg.d_model, cfg.ssm_inner
    N, H, K = cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    lay = ("layers",) * len(stacked)
    return {
        "w_z": ParamSpec(stacked + (D, di), lay + ("embed", "mlp")),
        "w_x": ParamSpec(stacked + (D, di), lay + ("embed", "mlp")),
        "w_b": ParamSpec(stacked + (D, N), lay + ("embed", None)),
        "w_c": ParamSpec(stacked + (D, N), lay + ("embed", None)),
        "w_dt": ParamSpec(stacked + (D, H), lay + ("embed", "heads")),
        "conv_x": ParamSpec(stacked + (K, di), lay + (None, "mlp"),
                            "normal", 0.5),
        "conv_b": ParamSpec(stacked + (K, N), lay + (None, None),
                            "normal", 0.5),
        "conv_c": ParamSpec(stacked + (K, N), lay + (None, None),
                            "normal", 0.5),
        "a_log": ParamSpec(stacked + (H,), lay + ("heads",), "zeros"),
        "d": ParamSpec(stacked + (H,), lay + ("heads",), "ones"),
        "dt_bias": ParamSpec(stacked + (H,), lay + ("heads",), "zeros"),
        "gn_scale": ParamSpec(stacked + (di,), lay + ("mlp",), "ones"),
        "w_out": ParamSpec(stacked + (di, D), lay + ("mlp", "embed")),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------

def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: [B,S,C]; w: [K,C] depthwise causal conv (pad left K-1)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # Unrolled taps (K=4): cheaper to compile than grouped conv on CPU and
    # identical HLO shape on TPU after fusion.
    out = sum(xp[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out


def _conv_step(tail: jax.Array, x_new: jax.Array, w: jax.Array):
    """Decode-time conv: tail [B,K-1,C], x_new [B,1,C] → (y [B,1,C], tail')."""
    window = jnp.concatenate([tail, x_new], axis=1)         # [B,K,C]
    y = jnp.einsum("bkc,kc->bc", window, w)[:, None, :]
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_scan_ref(xdt, a, b, c, h0=None):
    """Sequential oracle.  xdt:[B,S,H,P] (Δt·x), a:[B,S,H] (Δt·A, ≤0),
    b,c:[B,S,N] → y:[B,S,H,P], h_final:[B,H,P,N]."""
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    h_init = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else h0

    def step(h, t):
        xdt_t, a_t, b_t, c_t = t
        h = jnp.exp(a_t)[..., None, None] * h \
            + xdt_t[..., :, None] * b_t[:, None, None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = (xdt.transpose(1, 0, 2, 3), a.transpose(1, 0, 2),
          b.transpose(1, 0, 2), c.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h_init, xs)
    return ys.transpose(1, 0, 2, 3), h


def ssd_scan_chunked(xdt, a, c_coef, b_coef, chunk: int, h0=None):
    """Chunked (matmul-form) SSD.  Same contract as ``ssd_scan_ref``.

    Args are fp32-castable; per-chunk work is MXU matmuls; the inter-chunk
    recurrence is a scan over S/Q steps carrying [B,H,P,N].
    """
    xdt, a = xdt.astype(jnp.float32), a.astype(jnp.float32)
    b, c = b_coef.astype(jnp.float32), c_coef.astype(jnp.float32)
    B, S, H, P = xdt.shape
    N = b.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    if S % Q:
        # Zero-pad the tail: xdt=0 adds nothing and a=0 (decay exp(0)=1)
        # leaves the carried state untouched, so h_final stays exact.
        pad = Q - S % Q
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q
    xdt = xdt.reshape(B, nc, Q, H, P)
    a = a.reshape(B, nc, Q, H)
    b = b.reshape(B, nc, Q, N)
    c = c.reshape(B, nc, Q, N)
    h_init = jnp.zeros((B, H, P, N), jnp.float32) if h0 is None else \
        h0.astype(jnp.float32)

    def chunk_step(h, inputs):
        xdt_c, a_c, b_c, c_c = inputs           # [B,Q,H,P],[B,Q,H],[B,Q,N]
        cum = jnp.cumsum(a_c, axis=1)           # inclusive within chunk
        # intra-chunk: W[i,j,h] = exp(cum_i - cum_j) for i ≥ j
        diff = cum[:, :, None, :] - cum[:, None, :, :]      # [B,Q,Q,H]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        w = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", c_c, b_c)        # [B,Q,Q]
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, w, xdt_c)
        # inter-chunk: contribution of carried state
        y = y + jnp.einsum("bin,bhpn,bih->bihp", c_c, h, jnp.exp(cum))
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)         # [B,Q,H]
        s = jnp.einsum("bjhp,bjn,bjh->bhpn", xdt_c, b_c, decay_to_end)
        h = jnp.exp(cum[:, -1, :])[..., None, None] * h + s
        return h, y

    xs = (xdt.transpose(1, 0, 2, 3, 4), a.transpose(1, 0, 2, 3),
          b.transpose(1, 0, 2, 3), c.transpose(1, 0, 2, 3))
    h, ys = jax.lax.scan(chunk_step, h_init, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    return y[:, :S_orig], h


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------

def _projections(params, cfg, x):
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xs = jnp.einsum("bsd,de->bse", x, params["w_x"])
    b = jnp.einsum("bsd,dn->bsn", x, params["w_b"])
    c = jnp.einsum("bsd,dn->bsn", x, params["w_c"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])
    return z, xs, b, c, dt_raw


def ssd_apply(params: dict, cfg, x: jax.Array, return_cache: bool = False):
    """Full-sequence mamba block.  x: [B,S,D] → [B,S,D] (+cache)."""
    B, S, D = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xs, b, c, dt_raw = _projections(params, cfg, x)
    xs_conv_in, b_in, c_in = xs, b, c
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"]))
    b = jax.nn.silu(_causal_conv(b, params["conv_b"]))
    c = jax.nn.silu(_causal_conv(c, params["conv_c"]))
    xs = shard(xs, "batch", "length", "mlp")
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs.reshape(B, S, H, P)
    xdt = xh.astype(jnp.float32) * dt[..., None]
    a = dt * A[None, None, :]
    if cfg.ssd_impl in ("kernel", "kernel_interpret") \
            and S % min(cfg.ssm_chunk, S) == 0:
        from ..kernels.ssd import ssd_scan as _kernel_scan
        mode = "interpret" if cfg.ssd_impl == "kernel_interpret" else None
        y, h = _kernel_scan(xdt, a, b, c, cfg.ssm_chunk, mode=mode)
    else:
        y, h = ssd_scan_chunked(xdt, a, c, b, cfg.ssm_chunk)
    y = y + params["d"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["gn_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    out = shard(out, "batch", "length", None)
    if not return_cache:
        return out
    K = cfg.ssm_conv
    cache = MambaCache(
        conv_x=xs_conv_in[:, S - (K - 1):, :],
        conv_b=b_in[:, S - (K - 1):, :],
        conv_c=c_in[:, S - (K - 1):, :],
        state=h,
    )
    return out, cache


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> MambaCache:
    H, P, N, K = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv
    return MambaCache(
        conv_x=jnp.zeros((batch, K - 1, cfg.ssm_inner), dtype),
        conv_b=jnp.zeros((batch, K - 1, N), dtype),
        conv_c=jnp.zeros((batch, K - 1, N), dtype),
        state=jnp.zeros((batch, H, P, N), jnp.float32),
    )


def ssd_decode(params: dict, cfg, x: jax.Array, cache: MambaCache):
    """One-token decode.  x: [B,1,D] → ([B,1,D], cache')."""
    B = x.shape[0]
    H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, xs, b, c, dt_raw = _projections(params, cfg, x)
    xs_c, tail_x = _conv_step(cache.conv_x, xs, params["conv_x"])
    b_c, tail_b = _conv_step(cache.conv_b, b, params["conv_b"])
    c_c, tail_c = _conv_step(cache.conv_c, c, params["conv_c"])
    xs_c, b_c, c_c = (jax.nn.silu(t) for t in (xs_c, b_c, c_c))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    xh = xs_c.reshape(B, H, P).astype(jnp.float32)
    h = cache.state
    decay = jnp.exp(dt * A[None, :])                        # [B,H]
    h = decay[..., None, None] * h \
        + (dt[..., None] * xh)[..., None] * b_c[:, 0][:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, c_c[:, 0].astype(jnp.float32))
    y = y + params["d"][None, :, None] * xh
    y = y.reshape(B, 1, H * P).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["gn_scale"])
    out = jnp.einsum("bse,ed->bsd", y, params["w_out"])
    return out, MambaCache(conv_x=tail_x, conv_b=tail_b, conv_c=tail_c,
                           state=h)
