"""Assigned-architecture model zoo: generic pattern-based decoder LM
(dense/GQA/MoE/SSM/hybrid), Whisper enc-dec, shared layers."""

from . import layers, lm, moe, ssd, whisper
from .config import ModelConfig

__all__ = ["layers", "lm", "moe", "ssd", "whisper", "ModelConfig"]
