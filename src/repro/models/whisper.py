"""Whisper-style encoder-decoder backbone (whisper-large-v3 assignment).

Per the assignment the conv/mel frontend is a **stub**: ``input_specs``
feeds precomputed frame embeddings [B, 1500, D] straight into the encoder
stack.  The transformer backbone is faithful: pre-LN layernorm blocks,
learned positional embeddings (no RoPE), bidirectional encoder self-attn,
causal decoder self-attn + cross-attention to the encoder output, GELU MLPs.

Serving: ``prefill`` encodes the audio once, precomputes every layer's
cross-attention K/V (they are decode-invariant), and primes the decoder
self-attn KV caches; ``decode_step`` is then one causal decoder step.
Encoder-side "decode" does not exist (see DESIGN §Arch-applicability) —
the decode shapes exercise the decoder against a full cache.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamSpec, shard
from .layers import (KVCache, _full_attention, apply_norm, attention,
                     attention_specs, decode_attention, mlp_apply, mlp_specs,
                     norm_spec, prefill_attention)

__all__ = ["whisper_specs", "encode", "decoder_forward", "whisper_loss",
           "whisper_prefill", "whisper_decode_step", "init_decoder_caches"]


class CrossCache(NamedTuple):
    k: jax.Array    # [n_layers, B, T_enc, K, dh]
    v: jax.Array


def whisper_specs(cfg) -> dict:
    D = cfg.d_model
    enc, dec = cfg.encoder_layers, cfg.n_layers
    return {
        "embed": ParamSpec((cfg.vocab, D), ("vocab", "embed"), "embed"),
        "enc_pos": ParamSpec((cfg.encoder_ctx, D), ("length", None), "embed"),
        "dec_pos": ParamSpec((32776, D), ("length", None), "embed"),
        "encoder": {
            "norm1": norm_spec(D, cfg.norm, (enc,)),
            "attn": attention_specs(cfg, (enc,)),
            "norm2": norm_spec(D, cfg.norm, (enc,)),
            "mlp": mlp_specs(D, cfg.d_ff, cfg.mlp_act, (enc,)),
        },
        "enc_final_norm": norm_spec(D, cfg.norm),
        "decoder": {
            "norm1": norm_spec(D, cfg.norm, (dec,)),
            "self_attn": attention_specs(cfg, (dec,)),
            "norm_x": norm_spec(D, cfg.norm, (dec,)),
            "cross_attn": attention_specs(cfg, (dec,), cross=True),
            "norm2": norm_spec(D, cfg.norm, (dec,)),
            "mlp": mlp_specs(D, cfg.d_ff, cfg.mlp_act, (dec,)),
        },
        "final_norm": norm_spec(D, cfg.norm),
    }


def encode(params: dict, cfg, frames: jax.Array) -> jax.Array:
    """frames: [B, T_enc, D] (stub frontend output) → encoder states."""
    T = frames.shape[1]
    x = frames.astype(cfg.dtype) + params["enc_pos"][:T].astype(cfg.dtype)
    x = shard(x, "batch", "length", None)
    positions = jnp.broadcast_to(jnp.arange(T), x.shape[:2])

    def inner(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attention(p["attn"], cfg, h, positions, causal=False,
                          use_rope=False)
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + mlp_apply(p["mlp"], h, cfg.mlp_act)

    fn = jax.checkpoint(inner) if cfg.remat else inner
    if cfg.encoder_layers <= 2:      # unrolled for dry-run cost extrapolation
        for l in range(cfg.encoder_layers):
            x = fn(x, jax.tree.map(lambda a: a[l], params["encoder"]))
    else:
        def body(x, p):
            return fn(x, p), None
        x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(params["enc_final_norm"], x, cfg.norm)


def decoder_forward(params: dict, cfg, tokens: jax.Array,
                    enc_out: jax.Array) -> jax.Array:
    """Teacher-forced decoder: tokens [B,S] × enc_out [B,T,D] → hidden."""
    S = tokens.shape[1]
    x = params["embed"][tokens].astype(cfg.dtype) \
        + params["dec_pos"][:S].astype(cfg.dtype)
    x = shard(x, "batch", "length", None)
    positions = jnp.broadcast_to(jnp.arange(S), x.shape[:2])

    def inner(x, p):
        h = apply_norm(p["norm1"], x, cfg.norm)
        x = x + attention(p["self_attn"], cfg, h, positions, causal=True,
                          use_rope=False)
        h = apply_norm(p["norm_x"], x, cfg.norm)
        x = x + attention(p["cross_attn"], cfg, h, positions,
                          causal=False, kv=enc_out, use_rope=False)
        h = apply_norm(p["norm2"], x, cfg.norm)
        return x + mlp_apply(p["mlp"], h, cfg.mlp_act)

    fn = jax.checkpoint(inner) if cfg.remat else inner
    if cfg.n_layers <= 2:            # unrolled for dry-run cost extrapolation
        for l in range(cfg.n_layers):
            x = fn(x, jax.tree.map(lambda a: a[l], params["decoder"]))
    else:
        def body(x, p):
            return fn(x, p), None
        x, _ = jax.lax.scan(body, x, params["decoder"])
    return apply_norm(params["final_norm"], x, cfg.norm)


def whisper_loss(params: dict, cfg, frames: jax.Array, tokens: jax.Array,
                 labels: jax.Array):
    """Enc-dec training loss (teacher forcing, CE over decoder positions)."""
    enc_out = encode(params, cfg, frames)
    hidden = decoder_forward(params, cfg, tokens, enc_out)
    w = params["embed"].T
    h, y = hidden[:, :-1], labels[:, 1:]
    mask = (y >= 0).astype(jnp.float32)
    y = jnp.maximum(y, 0)
    logits = jnp.einsum("bsd,dv->bsv", h, w,
                        preferred_element_type=jnp.float32)
    logits = shard(logits, "batch", "length", "vocab")
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return ce, {"ce": ce}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_decoder_caches(cfg, batch: int, t_max: int):
    from .layers import QuantKVCache
    L, kd = cfg.n_layers, cfg.n_kv_heads * cfg.head_dim
    if cfg.kv_cache_quant:
        kv = lambda t: QuantKVCache(
            k=jnp.zeros((L, batch, t, kd), jnp.int8),
            v=jnp.zeros((L, batch, t, kd), jnp.int8),
            k_scale=jnp.zeros((L, batch, t, cfg.n_kv_heads), jnp.float32),
            v_scale=jnp.zeros((L, batch, t, cfg.n_kv_heads), jnp.float32))
    else:
        kv = lambda t: KVCache(
            k=jnp.zeros((L, batch, t, kd), cfg.dtype),
            v=jnp.zeros((L, batch, t, kd), cfg.dtype))
    return {"self": kv(t_max), "cross": kv(cfg.encoder_ctx)}


def whisper_prefill(params: dict, cfg, frames: jax.Array,
                    prompt: jax.Array, t_max: int):
    """Encode audio, precompute cross K/V, prime decoder self caches.

    prompt: [B, S0] decoder prompt tokens.  Returns (logits, caches, pos).
    """
    enc_out = encode(params, cfg, frames)

    def cross_kv(p):
        # stored flattened [B, T_enc, K·dh], matching decode_attention
        k = jnp.einsum("btd,de->bte", enc_out, p["cross_attn"]["wk"])
        v = jnp.einsum("btd,de->bte", enc_out, p["cross_attn"]["wv"])
        return KVCache(k=k, v=v)

    cross = jax.lax.map(cross_kv, params["decoder"])

    S0 = prompt.shape[1]
    x = params["embed"][prompt].astype(cfg.dtype) \
        + params["dec_pos"][:S0].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S0), x.shape[:2])

    def body(x, scanned):
        p, cr = scanned
        h = apply_norm(p["norm1"], x, cfg.norm)
        att, cache = prefill_attention(p["self_attn"], cfg, h, positions,
                                       use_rope=False)
        x = x + att
        h = apply_norm(p["norm_x"], x, cfg.norm)
        # cross attention against precomputed enc K/V
        B, S_, _ = h.shape
        H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        q = jnp.einsum("bsd,de->bse", h, p["cross_attn"]["wq"]) \
            .reshape(B, S_, H, dh)
        T = cr.k.shape[1]
        out = _full_attention(q, cr.k.reshape(B, T, K, dh),
                              cr.v.reshape(B, T, K, dh), causal=False)
        x = x + jnp.einsum("bse,ed->bsd", out.reshape(B, S_, H * dh),
                           p["cross_attn"]["wo"])
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return x, cache

    if cfg.n_layers <= 2:
        per_layer = []
        for l in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(lambda a: a[l],
                                        (params["decoder"], cross)))
            per_layer.append(c)
        self_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        x, self_cache = jax.lax.scan(body, x, (params["decoder"], cross))
    if t_max > S0:
        pad = [(0, 0), (0, 0), (0, t_max - S0), (0, 0)]
        self_cache = KVCache(k=jnp.pad(self_cache.k, pad),
                             v=jnp.pad(self_cache.v, pad))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["embed"].T)
    return logits, {"self": self_cache, "cross": cross}, S0


def whisper_decode_step(params: dict, cfg, caches: dict, token: jax.Array,
                        pos):
    """One decoder step.  token [B,1]; returns (logits [B,V], caches')."""
    pos = jnp.asarray(pos, jnp.int32)
    x = params["embed"][token].astype(cfg.dtype) \
        + params["dec_pos"][pos][None, None, :].astype(cfg.dtype)
    x = shard(x, "batch", "length", None)

    def body(x, scanned):
        p, self_c, cross_c = scanned
        h = apply_norm(p["norm1"], x, cfg.norm)
        att, new_self = decode_attention(p["self_attn"], cfg, h, self_c, pos,
                                         use_rope=False)
        x = x + att
        h = apply_norm(p["norm_x"], x, cfg.norm)
        att2, _ = decode_attention(p["cross_attn"], cfg, h, cross_c,
                                   jnp.asarray(cross_c.k.shape[1] - 1,
                                               jnp.int32),
                                   update_cache=False, use_rope=False)
        x = x + att2
        h = apply_norm(p["norm2"], x, cfg.norm)
        x = x + mlp_apply(p["mlp"], h, cfg.mlp_act)
        return x, new_self

    if cfg.n_layers <= 2:
        per_layer = []
        for l in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(
                lambda a: a[l],
                (params["decoder"], caches["self"], caches["cross"])))
            per_layer.append(c)
        new_self = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], caches["self"], caches["cross"]))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["embed"].T)
    return logits, {"self": new_self, "cross": caches["cross"]}
