"""Generic decoder LM over a repeating block pattern (all 10 assigned archs).

One code path covers dense GQA (starcoder2, phi4, nemotron, llava backbone),
MoE (llama4-scout, qwen3-moe), pure SSM (mamba2) and the Jamba hybrid — the
pattern (tuple of (mixer, ffn) pairs) is data, not code.  Layers are
*scanned over periods*: parameters are stacked [n_periods, ...] per pattern
position, so the HLO contains one block body per position regardless of
depth (96-layer nemotron compiles as fast as 30-layer starcoder2).

Entry points:
  forward      — training/scoring forward to final hidden states (+MoE aux)
  lm_loss      — causal cross-entropy; optional *chunked* CE that never
                 materializes [B,S,V] logits (beyond-paper memory lever)
  prefill      — forward + per-layer KV/Mamba caches for serving
  decode_step  — one-token serve step against the caches
  init_caches  — abstract cache construction (also used by the dry-run)

Multimodal prefix (llava/whisper-style stubs): ``extra_embeds`` [B,P,D] is
concatenated in front of the token embeddings per the assignment's frontend
stub contract.
"""

from __future__ import annotations

from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamSpec, shard
from . import moe as moe_mod
from . import ssd as ssd_mod
from .layers import (KVCache, QuantKVCache, apply_norm, attention,
                     attention_specs, ct_cast, decode_attention,
                     embed_specs, mlp_apply, mlp_specs, norm_spec,
                     prefill_attention)

__all__ = ["lm_specs", "forward", "lm_logits", "lm_loss", "prefill",
           "decode_step", "init_caches"]


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _block_specs(cfg, mixer: str, ffn: str) -> dict:
    stacked = (cfg.n_periods,)
    p: dict[str, Any] = {"norm1": norm_spec(cfg.d_model, cfg.norm, stacked)}
    if mixer == "attn":
        p["attn"] = attention_specs(cfg, stacked)
    elif mixer == "mamba":
        p["mamba"] = ssd_mod.ssd_specs(cfg, stacked)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")
    if ffn != "none":
        p["norm2"] = norm_spec(cfg.d_model, cfg.norm, stacked)
    if ffn == "mlp":
        p["ffn"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_act, stacked)
    elif ffn == "moe":
        p["ffn"] = moe_mod.moe_specs(cfg, stacked)
    return p


def lm_specs(cfg) -> dict:
    return {
        **embed_specs(cfg),
        "blocks": [_block_specs(cfg, m, f) for m, f in cfg.pattern],
        "final_norm": norm_spec(cfg.d_model, cfg.norm),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _apply_block(cfg, mixer: str, ffn: str, p: dict, x, positions,
                 mode: str = "full", cache=None, pos=None,
                 kv_sharded: bool = False):
    """One block.  Returns (x, new_cache, aux)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    new_cache = None
    if mixer == "attn":
        if mode == "full":
            att = attention(p["attn"], cfg, h, positions, causal=True)
        elif mode == "prefill":
            att, new_cache = prefill_attention(p["attn"], cfg, h, positions)
        else:  # decode
            att, new_cache = decode_attention(p["attn"], cfg, h, cache, pos,
                                              kv_sharded=kv_sharded)
    else:  # mamba
        if mode == "full":
            att = ssd_mod.ssd_apply(p["mamba"], cfg, h)
        elif mode == "prefill":
            att, new_cache = ssd_mod.ssd_apply(p["mamba"], cfg, h,
                                               return_cache=True)
        else:
            att, new_cache = ssd_mod.ssd_decode(p["mamba"], cfg, h, cache)
    x = x + att
    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if ffn == "mlp":
            y = mlp_apply(p["ffn"], h2, cfg.mlp_act)
        else:
            y, aux = moe_mod.moe_apply(p["ffn"], cfg, h2)
        x = x + y
    return x, new_cache, aux


def _embed(params, cfg, tokens, extra_embeds=None):
    x = params["embed"][tokens].astype(cfg.dtype)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(cfg.dtype), x], axis=1)
    x = shard(x, "batch", "length", None)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                                 x.shape[:2])
    return x, positions


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _slice_period(blocks, p: int):
    return jax.tree.map(lambda a: a[p], tuple(blocks))


def forward(params: dict, cfg, tokens: jax.Array,
            extra_embeds: jax.Array | None = None):
    """tokens [B,S] (+prefix embeds) → (hidden [B,S_total,D], aux).

    Depth ≤ 2 periods runs UNROLLED (no lax.scan): the dry-run compiles
    1-/2-period variants to extrapolate per-layer HLO costs, and XLA's
    cost/collective accounting only sees unrolled bodies with the right
    multiplicity.  Deeper models scan (compile time ∝ pattern, not depth).
    """
    x, positions = _embed(params, cfg, tokens, extra_embeds)

    def inner(x, block_params):
        a = jnp.zeros((), jnp.float32)
        if cfg.bf16_grads:
            x = ct_cast(x)
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, _, ai = _apply_block(cfg, mixer, ffn, block_params[i], x,
                                    positions)
            a = a + ai
        return x, a

    if cfg.remat and cfg.remat_policy == "dots":
        fn = jax.checkpoint(
            inner, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif cfg.remat:
        fn = jax.checkpoint(inner)
    else:
        fn = inner

    if cfg.n_periods <= 2:
        aux = jnp.zeros((), jnp.float32)
        for p in range(cfg.n_periods):
            x, a = fn(x, _slice_period(params["blocks"], p))
            aux = aux + a
    else:
        def body(carry, block_params):
            x, aux = carry
            x, a = fn(x, block_params)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   tuple(params["blocks"]))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    return x, aux


def _unembed_matrix(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def lm_logits(params: dict, cfg, hidden: jax.Array) -> jax.Array:
    logits = jnp.einsum("bsd,dv->bsv", hidden, _unembed_matrix(params, cfg))
    return shard(logits, "batch", "length", "vocab")


def _ce_full(hidden, w, labels, mask, fp32_gemm: bool = True):
    """Cross entropy.  ``fp32_gemm=False`` runs the unembed GEMM in the
    model dtype and upcasts *after* — the cotangent entering the backward
    pass is then bf16, halving every activation-gradient collective/HBM
    byte through the entire network (§Perf H1.1)."""
    if fp32_gemm:
        logits = jnp.einsum("bsd,dv->bsv", hidden, w,
                            preferred_element_type=jnp.float32)
        logits = shard(logits, "batch", "length", "vocab")
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden, w)
        logits = shard(logits, "batch", "length", "vocab")
        logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def _ce_chunked(hidden, w, labels, mask, chunk: int, fp32_gemm: bool = True):
    """Never materializes [B,S,V]: python-unrolled loop over sequence chunks
    (unrolled, not scanned, so HLO cost analysis counts every chunk and XLA
    can pipeline the unembed GEMMs)."""
    B, S, D = hidden.shape
    if S % chunk:
        return _ce_full(hidden, w, labels, mask, fp32_gemm)
    nc = S // chunk
    nll = jnp.zeros((), jnp.float32)
    cnt = jnp.zeros((), jnp.float32)
    for i in range(nc):
        sl = slice(i * chunk, (i + 1) * chunk)
        n, c = _ce_full(hidden[:, sl], w, labels[:, sl], mask[:, sl],
                        fp32_gemm)
        nll = nll + n
        cnt = cnt + c
    return nll, cnt


def lm_loss(params: dict, cfg, tokens: jax.Array, labels: jax.Array,
            extra_embeds: jax.Array | None = None):
    """Causal LM loss.  labels [B,S_total] aligned to the *full* sequence
    (prefix positions < 0 are masked).  Returns (loss, metrics)."""
    hidden, aux = forward(params, cfg, tokens, extra_embeds)
    w = _unembed_matrix(params, cfg)
    # predict token t+1 from hidden t
    h = hidden[:, :-1]
    y = labels[:, 1:]
    mask = (y >= 0).astype(jnp.float32)
    y = jnp.maximum(y, 0)
    if cfg.ce_chunk:
        nll, cnt = _ce_chunked(h, w, y, mask, cfg.ce_chunk, cfg.ce_fp32)
    else:
        nll, cnt = _ce_full(h, w, y, mask, cfg.ce_fp32)
    ce = nll / jnp.maximum(cnt, 1.0)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux, "tokens": cnt}


# ---------------------------------------------------------------------------
# Serving: caches, prefill, decode
# ---------------------------------------------------------------------------

def init_caches(cfg, batch: int, t_max: int, kv_sharded: bool = False):
    """Abstract cache pytree: one entry per pattern position, leaves stacked
    over periods.  Attention: KVCache [n_periods,B,T,K,dh]; mamba:
    MambaCache."""
    caches = []
    for mixer, _ in cfg.pattern:
        n = cfg.n_periods
        if mixer == "attn":
            shape = (n, batch, t_max, cfg.n_kv_heads * cfg.head_dim)
            if cfg.kv_cache_quant:
                sshape = (n, batch, t_max, cfg.n_kv_heads)
                caches.append(QuantKVCache(
                    k=jnp.zeros(shape, jnp.int8),
                    v=jnp.zeros(shape, jnp.int8),
                    k_scale=jnp.zeros(sshape, jnp.float32),
                    v_scale=jnp.zeros(sshape, jnp.float32)))
                continue
            caches.append(KVCache(k=jnp.zeros(shape, cfg.dtype),
                                  v=jnp.zeros(shape, cfg.dtype)))
        else:
            c = ssd_mod.init_mamba_cache(cfg, batch, cfg.dtype)
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), c))
    return caches


def prefill(params: dict, cfg, tokens: jax.Array,
            extra_embeds: jax.Array | None = None, t_max: int | None = None):
    """Process the prompt; returns (last-position logits, caches, next_pos).

    ``t_max`` pads attention KV caches to a serving budget (default: prompt
    length, which is what the assigned ``prefill_32k`` cell lowers).
    """
    x, positions = _embed(params, cfg, tokens, extra_embeds)
    S = x.shape[1]

    def body(x, block_params):
        new_caches = []
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, cache, _ = _apply_block(cfg, mixer, ffn, block_params[i], x,
                                       positions, mode="prefill")
            new_caches.append(cache)
        return x, tuple(new_caches)

    if cfg.n_periods <= 2:
        per_period = []
        for p in range(cfg.n_periods):
            x, cs = body(x, _slice_period(params["blocks"], p))
            per_period.append(cs)
        caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        x, caches = jax.lax.scan(body, x, tuple(params["blocks"]))
    if t_max is not None and t_max > S:
        def pad_kv(c):
            if isinstance(c, KVCache):
                pad = [(0, 0), (0, 0), (0, t_max - S), (0, 0)]
                return KVCache(k=jnp.pad(c.k, pad), v=jnp.pad(c.v, pad))
            return c
        caches = tuple(pad_kv(c) if isinstance(c, KVCache) else c
                       for c in caches)
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], _unembed_matrix(params, cfg))
    return logits, list(caches), S


def decode_step(params: dict, cfg, caches, token: jax.Array, pos,
                kv_sharded: bool = False):
    """One serve step: token [B,1] at position ``pos`` (scalar int32).

    Returns (logits [B,V], new caches).  ``kv_sharded`` turns on
    sequence-parallel KV (long_500k cells).
    """
    x = params["embed"][token].astype(cfg.dtype)
    x = shard(x, "batch", "length", None)
    pos = jnp.asarray(pos, jnp.int32)

    def body(x, scanned):
        block_params, cache = scanned
        new_caches = []
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, c, _ = _apply_block(cfg, mixer, ffn, block_params[i], x,
                                   None, mode="decode", cache=cache[i],
                                   pos=pos, kv_sharded=kv_sharded)
            new_caches.append(c)
        return x, tuple(new_caches)

    if cfg.n_periods <= 2:
        per_period = []
        for p in range(cfg.n_periods):
            x, cs = body(x, (_slice_period(params["blocks"], p),
                             _slice_period(caches, p)))
            per_period.append(cs)
        new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_period)
    else:
        x, new_caches = jax.lax.scan(
            body, x, (tuple(params["blocks"]), tuple(caches)))
    x = apply_norm(params["final_norm"], x, cfg.norm)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], _unembed_matrix(params, cfg))
    return logits, list(new_caches)
