"""Mixture-of-Experts FFN: token-choice top-k routing with capacity,
expert-parallel over the ``model`` mesh axis.

Implementation (TPU-friendly, GShard/MaxText lineage):
  1. router logits → softmax → top-k experts per token, gates renormalized;
  2. slot assignment: position-in-expert via a cumulative count over the
     token axis; tokens beyond ``capacity = ceil(S·k/E · capacity_factor)``
     are dropped (standard capacity discipline — keeps every shape static);
  3. dispatch: scatter-add token vectors into per-expert buffers
     [B, E, C, D] (vmapped over batch rows — indices stay local);
  4. expert compute: batched einsum over the expert axis (sharded over
     ``model`` → each device runs its resident experts: EP);
  5. combine: gather back per token, weight by gates, sum the k copies.

Auxiliary load-balance loss (Switch-style): ``E · Σ_e f_e·P_e`` where f is
the routed-token fraction and P the mean router prob.  A Llama-4-style
always-on shared expert is supported (``cfg.shared_expert``).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamSpec, shard
from .layers import mlp_apply, mlp_specs

__all__ = ["moe_specs", "moe_apply", "capacity"]


def capacity(cfg, tokens_per_group: int) -> int:
    cap = math.ceil(tokens_per_group * cfg.top_k / cfg.n_experts
                    * cfg.capacity_factor)
    return max(8, min(cap, tokens_per_group))


def moe_specs(cfg, stacked: tuple[int, ...] = ()) -> dict:
    D = cfg.d_model
    F = cfg.d_ff_moe or cfg.d_ff
    E = cfg.n_experts
    lay = ("layers",) * len(stacked)
    p = {
        "router": ParamSpec(stacked + (D, E), lay + ("embed", None)),
        "w_up": ParamSpec(stacked + (E, D, F), lay + ("expert", "embed", "mlp")),
        "w_down": ParamSpec(stacked + (E, F, D), lay + ("expert", "mlp", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        p["w_gate"] = ParamSpec(stacked + (E, D, F),
                                lay + ("expert", "embed", "mlp"))
    if cfg.shared_expert:
        p["shared"] = mlp_specs(D, F, cfg.mlp_act, stacked)
    return p


def moe_apply(params: dict, cfg, x: jax.Array):
    """x: [B, S, D] → (y [B,S,D], aux_loss scalar)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)

    router_logits = jnp.einsum("bsd,de->bse", x, params["router"],
                               preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)            # [B,S,E] f32
    gate_vals, expert_idx = jax.lax.top_k(probs, k)           # [B,S,k]
    gates = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ---- slot assignment ---------------------------------------------------
    flat_e = expert_idx.reshape(B, S * k)                     # [B,S·k]
    flat_g = gates.reshape(B, S * k).astype(x.dtype)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)       # [B,S·k,E]
    pos = jnp.einsum("bte,bte->bt", jnp.cumsum(onehot, axis=1), onehot) - 1
    keep = (pos < C)
    dest = flat_e * C + jnp.clip(pos, 0, C - 1)               # [B,S·k]

    # ---- dispatch (vmapped scatter-add keeps indices batch-local) ----------
    x_rep = jnp.repeat(x, k, axis=1)                          # [B,S·k,D]
    x_rep = x_rep * keep[..., None].astype(x.dtype)

    def _scatter(buf_rows, idx, rows):
        return buf_rows.at[idx].add(rows)

    buf = jnp.zeros((B, E * C, D), x.dtype)
    buf = jax.vmap(_scatter)(buf, dest, x_rep)
    buf = buf.reshape(B, E, C, D)
    buf = shard(buf, "batch", "expert", None, None)

    # ---- expert compute (EP: expert axis sharded over `model`) -------------
    h = jnp.einsum("becd,edf->becf", buf, params["w_up"])
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
        h = jax.nn.silu(g) * h
    elif cfg.mlp_act == "gelu":
        h = jax.nn.gelu(h)
    elif cfg.mlp_act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    h = shard(h, "batch", "expert", None, "mlp")
    y_buf = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y_buf = shard(y_buf, "batch", "expert", None, None)

    # ---- combine ------------------------------------------------------------
    y_tok = jax.vmap(lambda rows, idx: rows[idx])(
        y_buf.reshape(B, E * C, D), dest)                     # [B,S·k,D]
    y_tok = y_tok * (flat_g * keep.astype(x.dtype))[..., None]
    y = y_tok.reshape(B, S, k, D).sum(axis=2)
    y = shard(y, "batch", "length", None)

    # ---- Switch-style load-balance auxiliary loss ----------------------------
    frac_routed = jnp.mean(onehot.astype(jnp.float32), axis=(0, 1)) * S * k / S
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac_routed / k * mean_prob)

    if cfg.shared_expert:
        y = y + mlp_apply(params["shared"], x, cfg.mlp_act)
    return y, aux.astype(jnp.float32)
