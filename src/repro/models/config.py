"""ModelConfig: one dataclass describing every assigned architecture.

A model is a stack of ``n_layers`` blocks following a repeating ``pattern``
of (mixer, ffn) pairs — e.g. dense GQA = ``(("attn","mlp"),)``, Qwen3-MoE =
``(("attn","moe"),)``, Mamba-2 = ``(("mamba","none"),)``, Jamba's period-8
hybrid = 7 mamba + 1 attention with MoE every other layer.  Encoder-decoder
(Whisper) adds an encoder stack + cross-attention.  Modality frontends
(audio/vision) are stubs per the assignment: ``input_specs`` feeds
precomputed frame/patch embeddings.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None          # default d_model // n_heads
    # block pattern: tuple of (mixer, ffn); mixer in {attn, mamba};
    # ffn in {mlp, moe, none}; len(pattern) must divide n_layers
    pattern: tuple[tuple[str, str], ...] = (("attn", "mlp"),)
    mlp_act: str = "swiglu"            # swiglu | gelu | squared_relu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 1
    d_ff_moe: int | None = None        # expert hidden dim (defaults to d_ff)
    shared_expert: bool = False        # Llama-4 style always-on shared expert
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    encoder_ctx: int = 1500            # audio frame positions
    # modality frontend stub: None | "audio" | "vision"
    frontend: str | None = None
    frontend_tokens: int = 0           # vision: image-patch prefix length
    # training / numerics
    dtype: Any = jnp.bfloat16
    ce_chunk: int = 0                  # 0 = full-logits CE; else chunked
    attn_chunk: int = 512              # q/kv chunking for long sequences
    remat: bool = True
    optimizer: str = "adamw"           # adamw | adafactor (giant archs)
    grad_accum: int = 1                # microbatches per step (activation
    #                                    memory ∝ 1/grad_accum; ZeRO weight
    #                                    gathers ∝ grad_accum)
    # ---- perf levers (EXPERIMENTS §Perf; defaults = paper-faithful baseline)
    ce_fp32: bool = True               # False: bf16 logits GEMM -> bf16
    #                                    cotangents through the whole bwd
    bf16_grads: bool = False           # ct_cast at block boundaries: pins
    #                                    activation cotangents to bf16
    remat_policy: str = "full"         # full | dots | none — what the
    #                                    layer checkpoint saves
    pad_heads: bool = False            # pad head count to the TP degree
    #                                    (kills GSPMD involuntary reshards)
    attn_impl: str = "xla"             # "flash": Pallas kernel on TPU
    #                                    (causal block skip: ~2x attn FLOPs)
    ssd_impl: str = "xla"              # "kernel": Pallas intra-chunk SSD
    kv_cache_quant: bool = False       # int8 KV cache (decode memory term)
    moe_ep: bool = True                # False: no expert-parallel axis —
    #                                    experts replicated over `model`-TP'd
    #                                    d_ff; kills the EP token all-to-all
    #                                    at the cost of per-layer weight
    #                                    gathers (wins when experts are many
    #                                    and small, e.g. qwen3's 128×1536)
    serve_replicate_params: bool = False  # decode: params replicated over
    #                                    `data` (no per-step FSDP gathers;
    #                                    trades HBM capacity+reads for the
    #                                    collective term)
    serve_2d_tp: bool = False          # decode: batch replicated, weights
    #                                    stationary 2D TP (data=contraction,
    #                                    model=output) — zero weight
    #                                    gathers, tiny activation ARs
    # metadata
    family: str = "dense"              # dense|moe|ssm|hybrid|audio|vlm
    notes: str = ""

    def __post_init__(self):
        if self.n_layers % len(self.pattern):
            raise ValueError(
                f"{self.name}: n_layers={self.n_layers} not divisible by "
                f"pattern length {len(self.pattern)}")

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return all(m != "attn" for m, _ in self.pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve a 500k-token context?  (SSM/hybrid: the
        mamba state is O(1) and the few attention layers are decode-linear.)"""
        return any(m == "mamba" for m, _ in self.pattern)

    def param_count(self) -> int:
        """Total parameters (analytic)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        dh = self.head_dim
        total = V * D                                     # embedding
        if not self.tie_embeddings:
            total += V * D                                # unembedding
        per_pattern = 0
        for mixer, ffn in self.pattern:
            per_pattern += D                              # pre-mixer norm
            if mixer == "attn":
                per_pattern += D * self.n_heads * dh      # q
                per_pattern += 2 * D * self.n_kv_heads * dh   # k,v
                per_pattern += self.n_heads * dh * D      # o
            elif mixer == "mamba":
                di, N, H = self.ssm_inner, self.ssm_state, self.ssm_heads
                conv_ch = di + 2 * N
                per_pattern += D * (2 * di + 2 * N + H)   # in_proj
                per_pattern += conv_ch * self.ssm_conv    # conv1d
                per_pattern += 3 * H + di                 # A, D, dt_bias, gnorm
                per_pattern += di * D                     # out_proj
            if ffn != "none":
                per_pattern += D                          # pre-ffn norm
            if ffn == "mlp":
                mult = 3 if self.mlp_act == "swiglu" else 2
                per_pattern += mult * D * F
            elif ffn == "moe":
                Fm = self.d_ff_moe or F
                mult = 3 if self.mlp_act == "swiglu" else 2
                per_pattern += self.n_experts * mult * D * Fm
                per_pattern += D * self.n_experts         # router
                if self.shared_expert:
                    per_pattern += mult * D * Fm
        total += per_pattern * self.n_periods
        total += D                                        # final norm
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            enc = self.encoder_layers * (
                2 * D + D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh
                + self.n_heads * dh * D
                + (3 if self.mlp_act == "swiglu" else 2) * D * F)
            cross = self.n_layers * (
                D + D * self.n_heads * dh + 2 * D * self.n_kv_heads * dh
                + self.n_heads * dh * D)
            total += enc + cross + D
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        Fm = self.d_ff_moe or self.d_ff
        mult = 3 if self.mlp_act == "swiglu" else 2
        moe_layers = sum(1 for _, f in self.pattern if f == "moe") \
            * self.n_periods
        inactive = (self.n_experts - self.top_k) * mult * self.d_model * Fm
        return int(self.param_count() - moe_layers * inactive)
