"""Shared neural layers: norms, RoPE, GQA attention (train/prefill/decode),
dense MLP variants, embeddings — all pure functions with logical-axis
sharding annotations (``parallel.sharding.shard``).

Attention offers two execution plans:
* ``full``   — materialize [B,H,S,T] scores (short sequences, encoders);
* ``chunked``— streaming-softmax over KV blocks with q-blocking
  (memory-bounded for 32k prefill; the pure-JAX fallback of the Pallas
  flash kernel in ``repro.kernels.attention``).

Decode attends one query against a fixed-capacity KV cache with a length
mask.  All softmax statistics accumulate in fp32.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..parallel.sharding import ParamSpec, shard, shard_fit

__all__ = ["rmsnorm", "layernorm", "norm_spec", "apply_norm", "rope",
           "attention_specs", "attention", "decode_attention", "KVCache",
           "mlp_specs", "mlp_apply", "embed_specs"]

NEG_INF = -1e30


@jax.custom_vjp
def ct_cast(x):
    """Identity forward; casts the COTANGENT to x's dtype on the way back.

    §Perf H1.1': fp32 sneaks into the backward pass through the norm
    layers' fp32 variance paths (any fp32 contribution promotes the whole
    accumulated cotangent), doubling every activation-gradient collective
    and HBM byte.  Inserting this at block boundaries pins the residual
    stream's cotangent to bf16.  Gradient *values* change only by bf16
    rounding of the cotangent (weight grads still accumulate in fp32 inside
    the einsum transposes).
    """
    return x


def _ct_cast_fwd(x):
    # residual must be a JAX type: carry the dtype as a 0-sized array
    return x, jnp.zeros((0,), x.dtype)


def _ct_cast_bwd(res, ct):
    return (ct.astype(res.dtype),)


ct_cast.defvjp(_ct_cast_fwd, _ct_cast_bwd)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(d: int, kind: str, stacked: tuple[int, ...] = ()) -> dict:
    axes = ("layers",) * len(stacked)
    p = {"scale": ParamSpec(stacked + (d,), axes + (None,), "ones")}
    if kind == "layernorm":
        p["bias"] = ParamSpec(stacked + (d,), axes + (None,), "zeros")
    return p


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale


def layernorm(x, scale, bias, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(x.dtype) * scale + bias


def apply_norm(params: dict, x, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"])
    return layernorm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [B,S,H,dh]; positions: [B,S] (int).  Rotates pairs (even, odd)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs    # [B,S,half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([
        x1 * cos - x2 * sin,
        x2 * cos + x1 * sin,
    ], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    k: jax.Array     # [B, T, K·dh] (flattened; see attention_specs)
    v: jax.Array


class QuantKVCache(NamedTuple):
    """int8 KV cache with per-(token, kv-head) scales (§Perf H3.1).

    Halves the decode memory term vs bf16 — the dominant roofline term for
    every ``decode_32k`` cell.  Quantization error ≤ scale/254 per element;
    accuracy checked against the bf16 path in tests.
    """

    k: jax.Array         # int8 [B, T, K·dh]
    v: jax.Array
    k_scale: jax.Array   # f32 [B, T, K]
    v_scale: jax.Array


def attention_specs(cfg, stacked: tuple[int, ...] = (), cross: bool = False
                    ) -> dict:
    """Projection weights stored with FLATTENED head dims ([D, H·dh]):
    H·dh is 16-divisible for every assigned arch even when H is not (e.g.
    36 heads), so jit *input* shardings stay exact; activations reshape to
    [.., H, dh] and rely on GSPMD padding for uneven head counts."""
    D, H, K, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    lay = ("layers",) * len(stacked)
    p = {
        "wq": ParamSpec(stacked + (D, H * dh), lay + ("embed", "heads")),
        "wk": ParamSpec(stacked + (D, K * dh), lay + ("embed", "kv_heads")),
        "wv": ParamSpec(stacked + (D, K * dh), lay + ("embed", "kv_heads")),
        "wo": ParamSpec(stacked + (H * dh, D), lay + ("heads", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamSpec(stacked + (dh,), lay + (None,), "ones")
        p["k_norm"] = ParamSpec(stacked + (dh,), lay + (None,), "ones")
    return p


def _tp_degree() -> int:
    from ..parallel.sharding import current_mesh
    mesh = current_mesh()
    return mesh.shape.get("model", 1) if mesh is not None else 1


def _maybe_pad_heads(q, k, v, cfg):
    """§Perf H1.2: pad head counts to the TP degree.

    Uneven head counts (36 q-heads over a 16-way TP axis) make GSPMD fall
    back to "involuntary full rematerialization" reshards.  Padding with
    zero heads keeps every attention einsum exactly sharded; padded heads'
    outputs are sliced away before the out-projection (cost: H_pad/H ×
    attention FLOPs, accounted in the roofline).

    GQA grouping is preserved: q pads *within* each KV group (G → G_pad),
    MHA (G=1) pads q and kv together.  Returns (q, k, v, unpad_fn).
    """
    ident = lambda out: out
    if not cfg.pad_heads:
        return q, k, v, ident
    tp = _tp_degree()
    B, S, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    if H % tp == 0:
        return q, k, v, ident
    if G == 1:
        Hp = H + (-H) % tp
        padw = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        q2, k2, v2 = (jnp.pad(t, padw) for t in (q, k, v))

        def unpad(out):
            return out[:, :, :H]
        return q2, k2, v2, unpad
    gp = G
    while (K * gp) % tp:
        gp += 1
    q5 = q.reshape(B, S, K, G, dh)
    q5 = jnp.pad(q5, ((0, 0), (0, 0), (0, 0), (0, gp - G), (0, 0)))
    q2 = q5.reshape(B, S, K * gp, dh)

    def unpad(out):
        B_, S_ = out.shape[0], out.shape[1]
        return out.reshape(B_, S_, K, gp, dh)[:, :, :, :G] \
            .reshape(B_, S_, H, dh)
    return q2, k, v, unpad


def _project_qkv(params, cfg, x, positions, use_rope=True):
    B, S, _ = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, H, dh)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(B, S, K, dh)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(B, S, K, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = shard_fit(q, "batch", "length", "heads", None)
    k = shard_fit(k, "batch", "length", "kv_heads", None)
    v = shard_fit(v, "batch", "length", "kv_heads", None)
    return q, k, v


def _full_attention(q, k, v, causal: bool, kv_offset: int = 0):
    """q:[B,S,H,dh] k,v:[B,T,K,dh] → [B,S,H,dh] (scores materialized)."""
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, S, K, G, dh)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32) / (dh ** 0.5)
    if causal:
        qpos = kv_offset + jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        logits = jnp.where((qpos >= kpos)[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v)
    return out.reshape(B, S, H, dh)


def _chunked_attention(q, k, v, causal: bool, chunk: int):
    """Streaming-softmax attention over q/kv blocks (flash-style in jnp).

    Causal block skipping: kv blocks strictly above the diagonal are
    masked; their compute is still issued (dense scan) — the Pallas kernel
    removes it on TPU; the roofline counts this as the documented 2×
    attention-FLOP slack of the fallback.
    """
    B, S, H, dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if S % chunk or T % chunk:
        return _full_attention(q, k, v, causal)
    nq, nk = S // chunk, T // chunk
    qg = q.reshape(B, nq, chunk, K, G, dh)

    def q_block(_, i):
        qi = qg[:, i]                                    # [B,c,K,G,dh]

        def kv_block(acc, j):
            m, s, o = acc
            kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
            logits = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj,
                                preferred_element_type=jnp.float32) / (dh ** 0.5)
            if causal:
                qpos = i * chunk + jnp.arange(chunk)[:, None]
                kpos = j * chunk + jnp.arange(chunk)[None, :]
                logits = jnp.where((qpos >= kpos)[None, None, None],
                                   logits, NEG_INF)
            mn = jnp.maximum(m, logits.max(-1))
            p = jnp.exp(logits - mn[..., None])
            corr = jnp.exp(m - mn)
            s2 = s * corr + p.sum(-1)
            o2 = o * corr[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p, vj.astype(jnp.float32))
            return (mn, s2, o2), None

        m0 = jnp.full((B, K, G, chunk), NEG_INF, jnp.float32)
        s0 = jnp.zeros((B, K, G, chunk), jnp.float32)
        o0 = jnp.zeros((B, K, G, chunk, dh), jnp.float32)
        (m, s, o), _ = jax.lax.scan(kv_block, (m0, s0, o0), jnp.arange(nk))
        out = (o / jnp.maximum(s[..., None], 1e-30)).astype(q.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)        # [B,c,K,G,dh]

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    outs = outs.transpose(1, 0, 2, 3, 4, 5)              # [B,nq,c,K,G,dh]
    return outs.reshape(B, S, H, dh)


def _out_proj(params, out, cfg):
    B, S = out.shape[0], out.shape[1]
    flat = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    y = jnp.einsum("bse,ed->bsd", flat, params["wo"])
    return shard(y, "batch", "length", None)


def attention(params: dict, cfg, x: jax.Array, positions: jax.Array,
              causal: bool = True, kv: jax.Array | None = None,
              use_rope: bool = True) -> jax.Array:
    """Self- (or cross-, via ``kv``) attention over a full sequence.

    x: [B,S,D].  Returns [B,S,D].  Chunked plan picked for long sequences.
    """
    B, S, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if kv is None:
        q, k, v = _project_qkv(params, cfg, x, positions, use_rope)
    else:
        q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(B, S, H, dh)
        if cfg.qk_norm:
            q = rmsnorm(q, params["q_norm"])
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
        T = kv.shape[1]
        k = jnp.einsum("btd,de->bte", kv, params["wk"]).reshape(B, T, K, dh)
        v = jnp.einsum("btd,de->bte", kv, params["wv"]).reshape(B, T, K, dh)
        if cfg.qk_norm:
            k = rmsnorm(k, params["k_norm"])
    out = _attention_core(q, k, v, causal, cfg)
    out = shard_fit(out, "batch", "length", "heads", None)
    return _out_proj(params, out, cfg)


def _attention_core(q, k, v, causal, cfg):
    """Dispatch the attention plan: Pallas flash kernel / chunked / full,
    with optional TP head padding around the core."""
    q, k, v, unpad = _maybe_pad_heads(q, k, v, cfg)
    if q.shape[2] != cfg.n_heads:        # padded: exact head sharding now
        q = shard_fit(q, "batch", "length", "heads", None)
    S, T = q.shape[1], k.shape[1]
    if cfg.attn_impl in ("flash", "flash_interpret"):
        from ..kernels.attention import mha
        mode = "interpret" if cfg.attn_impl == "flash_interpret" else None
        out = mha(q, k, v, causal, mode)
    elif S > cfg.attn_chunk and S % cfg.attn_chunk == 0 \
            and T % cfg.attn_chunk == 0:
        out = _chunked_attention(q, k, v, causal, cfg.attn_chunk)
    else:
        out = _full_attention(q, k, v, causal)
    return unpad(out)


def prefill_attention(params: dict, cfg, x, positions, use_rope: bool = True):
    """Like ``attention`` but also returns the KV cache for decode.

    Cache K/V stored flattened [B, S, K·dh] (16-divisible input sharding)."""
    q, k, v = _project_qkv(params, cfg, x, positions, use_rope)
    B, S = x.shape[0], x.shape[1]
    out = _attention_core(q, k, v, True, cfg)
    kd = cfg.n_kv_heads * cfg.head_dim
    cache = KVCache(k=k.reshape(B, S, kd), v=v.reshape(B, S, kd))
    return _out_proj(params, out, cfg), cache


def _quantize_kv(x: jax.Array, K: int, dh: int):
    """x [B,1,K,dh] → (int8 [B,1,K·dh], scale f32 [B,1,K])."""
    B = x.shape[0]
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q.reshape(B, 1, K * dh), scale


def decode_attention(params: dict, cfg, x: jax.Array, cache,
                     pos: jax.Array, kv_sharded: bool = False,
                     update_cache: bool = True, use_rope: bool = True):
    """One-token decode: x [B,1,D], cache [B,T,K·dh] (flattened), pos scalar.

    Writes the new K/V at ``pos`` and attends over positions ≤ pos.
    ``kv_sharded``: annotate the cache time axis as ``kv_length`` (long-
    context SP — partial attention per shard merged by XLA's reductions).
    Accepts a bf16 ``KVCache`` or an int8 ``QuantKVCache``.
    """
    B, _, D = x.shape
    H, K, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, cfg, x, positions, use_rope)
    kd = K * dh
    t_axis = "kv_length" if kv_sharded else "length"
    quant = isinstance(cache, QuantKVCache)
    if quant:
        kq, ks = _quantize_kv(k_new, K, dh)
        vq, vs = _quantize_kv(v_new, K, dh)
        dus = jax.lax.dynamic_update_slice_in_dim
        new_cache = QuantKVCache(
            k=dus(cache.k, kq, pos, 1) if update_cache else cache.k,
            v=dus(cache.v, vq, pos, 1) if update_cache else cache.v,
            k_scale=dus(cache.k_scale, ks, pos, 1) if update_cache
            else cache.k_scale,
            v_scale=dus(cache.v_scale, vs, pos, 1) if update_cache
            else cache.v_scale)
        k_flat = shard(new_cache.k, "batch", t_axis, "kv_heads")
        v_flat = shard(new_cache.v, "batch", t_axis, "kv_heads")
        T = k_flat.shape[1]
        k = (k_flat.reshape(B, T, K, dh).astype(cfg.dtype)
             * new_cache.k_scale[..., None].astype(cfg.dtype))
        v = (v_flat.reshape(B, T, K, dh).astype(cfg.dtype)
             * new_cache.v_scale[..., None].astype(cfg.dtype))
    else:
        if update_cache:
            k_flat = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k_new.reshape(B, 1, kd).astype(cache.k.dtype),
                pos, 1)
            v_flat = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v_new.reshape(B, 1, kd).astype(cache.v.dtype),
                pos, 1)
        else:
            k_flat, v_flat = cache.k, cache.v
        new_cache = KVCache(k=k_flat, v=v_flat)
        k_flat = shard(k_flat, "batch", t_axis, "kv_heads")
        v_flat = shard(v_flat, "batch", t_axis, "kv_heads")
        T = k_flat.shape[1]
        k = k_flat.reshape(B, T, K, dh)
        v = v_flat.reshape(B, T, K, dh)
    G = H // K
    qg = q.reshape(B, K, G, dh)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                        preferred_element_type=jnp.float32) / (dh ** 0.5)
    mask = jnp.arange(T)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(q.dtype), v)
    out = out.reshape(B, 1, H, dh)
    return _out_proj(params, out, cfg), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(d: int, f: int, act: str, stacked: tuple[int, ...] = ()) -> dict:
    lay = ("layers",) * len(stacked)
    p = {
        "w_up": ParamSpec(stacked + (d, f), lay + ("embed", "mlp")),
        "w_down": ParamSpec(stacked + (f, d), lay + ("mlp", "embed")),
    }
    if act == "swiglu":
        p["w_gate"] = ParamSpec(stacked + (d, f), lay + ("embed", "mlp"))
    return p


def mlp_apply(params: dict, x: jax.Array, act: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, params["w_up"])
    if act == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = jax.nn.silu(g) * h
    elif act == "gelu":
        h = jax.nn.gelu(h)
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(f"unknown activation {act!r}")
    h = shard(h, "batch", "length", "mlp") if h.ndim == 3 else h
    y = jnp.einsum("...f,fd->...d", h, params["w_down"])
    return shard(y, "batch", "length", None) if y.ndim == 3 else y


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def embed_specs(cfg) -> dict:
    p = {"embed": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                            "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ParamSpec((cfg.d_model, cfg.vocab),
                                 ("embed", "vocab"))
    return p
