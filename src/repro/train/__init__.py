"""Training substrate: optimizers, train states, checkpointing."""

from . import checkpoint, optimizer, train_state
from .train_state import TrainState, make_tx

__all__ = ["checkpoint", "optimizer", "train_state", "TrainState", "make_tx"]
