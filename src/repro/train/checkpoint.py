"""Fault-tolerant checkpointing: async sharded saves, resharding restore,
and in-memory (store-resident) checkpoints.

Three tiers, matching what a 1000-node fleet actually needs:

1. **Durable sharded checkpoints** (`save` / `restore`): every leaf is
   written as an .npy blob under a step directory with a JSON manifest
   (tree structure, shapes, dtypes).  ``save_async`` hands the device→host
   copy and file I/O to a background thread so the train loop only blocks
   for the on-device snapshot (the JAX arrays are immutable — an O(1)
   "copy").  Restore reshards: the restored arrays are ``device_put`` to
   whatever sharding the *current* mesh wants, so a checkpoint written on
   (16,16) restores onto (2,16,16) or a shrunken elastic mesh unchanged.

2. **In-memory checkpoints** (`MemoryCheckpoint`): the train state is
   parked in the co-located TensorStore between steps — the paper's
   database doubling as a Gemini-style in-RAM checkpoint.  Restart after a
   worker failure costs one store read instead of a filesystem round-trip.

3. **Retention policy**: ``keep`` newest checkpoints are preserved;
   ``save`` returns the path so launchers can symlink "latest".
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "Checkpointer",
           "MemoryCheckpoint"]


def _flatten_with_paths(tree):
    # jax.tree.flatten_with_path is newer than some supported jax versions;
    # jax.tree_util.tree_flatten_with_path is the long-stable spelling.
    flatten = getattr(jax.tree, "flatten_with_path",
                      jax.tree_util.tree_flatten_with_path)
    flat, treedef = flatten(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str | Path, step: int, state: Any, keep: int = 3) -> Path:
    """Synchronous sharded save.  Returns the checkpoint path."""
    ckpt_dir = Path(ckpt_dir)
    path = ckpt_dir / f"step_{step:010d}"
    tmp = ckpt_dir / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, _ = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        manifest["leaves"].append(
            {"key": key, "file": f"leaf_{i:05d}.npy",
             "shape": list(arr.shape), "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if path.exists():
        shutil.rmtree(path)
    tmp.rename(path)                     # atomic publish
    _apply_retention(ckpt_dir, keep)
    return path


def _apply_retention(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | Path) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    steps = sorted(int(p.name.split("_")[1]) for p in ckpt_dir.glob("step_*"))
    return steps[-1] if steps else None


def restore(ckpt_dir: str | Path, like: Any, step: int | None = None) -> Any:
    """Restore into the structure/shardings of ``like`` (elastic reshard:
    arrays are device_put to ``like``'s shardings when it has any)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((path / "manifest.json").read_text())
    flat_like, treedef = _flatten_with_paths(like)
    by_key = {m["key"]: m for m in manifest["leaves"]}
    leaves = []
    for key, leaf in flat_like:
        m = by_key.get(key)
        if m is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(path / m["file"])
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        val = jnp.asarray(arr, dtype=target_dtype)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "mesh"):
            val = jax.device_put(val, sharding)
        leaves.append(val)
    return jax.tree.unflatten(treedef, leaves)


class Checkpointer:
    """Async checkpoint manager: ``maybe_save`` snapshots on-device state
    immediately and writes in the background, overlapping I/O with the
    next train steps.  One in-flight save at a time (a newer save waits)."""

    def __init__(self, ckpt_dir: str | Path, interval_steps: int = 100,
                 keep: int = 3):
        self.dir = Path(ckpt_dir)
        self.interval = interval_steps
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.saves = 0
        self.errors: list[str] = []

    def maybe_save(self, step: int, state: Any, force: bool = False) -> bool:
        if not force and (self.interval <= 0 or step % self.interval):
            return False
        self.wait()
        # Snapshot = the immutable arrays themselves (O(1)); the background
        # thread does the device→host transfer + file writes.
        snapshot = state

        def _run():
            try:
                save(self.dir, step, snapshot, keep=self.keep)
                self.saves += 1
            except Exception as e:  # noqa: BLE001
                self.errors.append(repr(e))

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()
        return True

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _copy_array_leaves(tree: Any) -> Any:
    """Deep-copy the device arrays of a pytree; pass other leaves through.

    Checkpointed train states can be *donated* to the next epoch's jitted
    dispatch — a parked reference to the same buffers would dangle.  Copying
    at save time makes the parked image immune to donation.
    """
    def _copy(x):
        return jnp.copy(x) if isinstance(x, jax.Array) else x
    return jax.tree.map(_copy, tree)


class MemoryCheckpoint:
    """Train-state checkpoints parked in the in-memory TensorStore.

    The paper's database stores "data and ML models in memory for the
    duration of the run"; parking the optimizer state there gives
    MegaScale-style in-RAM restart for transient worker failures.

    ``key`` namespaces the checkpoint so several components can park state
    in one store (``None`` keeps the legacy unnamespaced metadata names).
    Saves go through :func:`_copy_array_leaves` so a state the train loop
    later donates stays restorable.  Metadata puts/gets are host-side KV
    traffic — checkpointing never perturbs the store's op counters.
    """

    def __init__(self, server, key: str | None = None):
        self.server = server
        self._prefix = "__memckpt" if key is None else f"__memckpt_{key}"
        self._slot = None

    def save(self, step: int, state: Any) -> None:
        self.server.put_meta(f"{self._prefix}_state",
                             _copy_array_leaves(state))
        self.server.put_meta(f"{self._prefix}_step", int(step))

    def restore(self) -> tuple[int, Any] | None:
        step = self.server.get_meta(f"{self._prefix}_step")
        if step is None:
            return None
        return int(step), self.server.get_meta(f"{self._prefix}_state")
