"""Generic train state + optimizer wiring for the LM substrate.

Builds the optimizer from the arch config (AdamW for ≤35B, Adafactor for
the 340B/398B giants — factored second moments are what make them fit),
and provides *abstract* state constructors (ShapeDtypeStruct + shardings)
for the dry-run: optimizer state inherits the ZeRO sharding of the params
it tracks, with Adafactor's factored vectors dropping the reduced axis.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..parallel.sharding import ParamSpec, fitted_sharding, spec_for
from . import optimizer as opt

__all__ = ["TrainState", "make_tx", "abstract_train_state", "init_train_state"]


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def make_tx(cfg, total_steps: int = 100_000) -> opt.GradientTransformation:
    sched = opt.warmup_cosine(3e-4 if cfg.optimizer != "adafactor" else 1e-2,
                              warmup_steps=min(2000, total_steps // 10),
                              total_steps=total_steps)
    if cfg.optimizer == "adafactor":
        inner = opt.adafactor(lr=sched)
    else:
        inner = opt.adamw(lr=sched, b1=0.9, b2=0.95, weight_decay=0.1)
    return opt.chain(opt.clip_by_global_norm(1.0), inner)


def init_train_state(key, cfg, specs, tx, dtype=None) -> TrainState:
    from ..parallel.sharding import init_params
    params = init_params(key, specs, dtype or cfg.dtype)
    return TrainState(params=params, opt_state=tx.init(params),
                      step=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# Abstract state (dry-run)
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, axes, rules=None):
    sh = fitted_sharding(mesh, shape, axes, rules)
    return jax.ShapeDtypeStruct(tuple(shape), dtype, sharding=sh)


def _is_spec(x):
    return isinstance(x, ParamSpec)


def abstract_params(specs, mesh, dtype, rules=None):
    return jax.tree.map(
        lambda s: _sds(s.shape, dtype, mesh, s.axes, rules),
        specs, is_leaf=_is_spec)


def _abstract_adam(specs, mesh, rules):
    mu = jax.tree.map(lambda s: _sds(s.shape, jnp.float32, mesh, s.axes,
                                     rules), specs, is_leaf=_is_spec)
    nu = jax.tree.map(lambda s: _sds(s.shape, jnp.float32, mesh, s.axes,
                                     rules), specs, is_leaf=_is_spec)
    return opt.AdamState(step=_sds((), jnp.int32, mesh, ()), mu=mu, nu=nu)


def _abstract_adafactor(specs, mesh, rules):
    def rows(s):
        if len(s.shape) >= 2:
            return _sds(s.shape[:-1], jnp.float32, mesh, s.axes[:-1], rules)
        return _sds(s.shape, jnp.float32, mesh, s.axes, rules)

    def cols(s):
        if len(s.shape) >= 2:
            return _sds(s.shape[:-2] + s.shape[-1:], jnp.float32, mesh,
                        s.axes[:-2] + s.axes[-1:], rules)
        return _sds((), jnp.float32, mesh, ())

    return opt.AdafactorState(
        step=_sds((), jnp.int32, mesh, ()),
        vr=jax.tree.map(rows, specs, is_leaf=_is_spec),
        vc=jax.tree.map(cols, specs, is_leaf=_is_spec))


def abstract_train_state(cfg, specs, mesh, rules=None) -> TrainState:
    """ShapeDtypeStruct TrainState matching ``make_tx(cfg)``'s structure."""
    params = abstract_params(specs, mesh, cfg.dtype, rules)
    if cfg.optimizer == "adafactor":
        inner = _abstract_adafactor(specs, mesh, rules)
    else:
        inner = _abstract_adam(specs, mesh, rules)
    # chain(clip, inner) state = ((), inner_state)
    return TrainState(params=params, opt_state=((), inner),
                      step=_sds((), jnp.int32, mesh, ()))
