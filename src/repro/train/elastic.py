"""Elastic scaling: carry a training job across mesh-size changes.

A 1000-node fleet loses nodes; the job must continue on whatever mesh the
scheduler can re-assemble.  Two supported paths:

* **restart-reshard** (`reshard_state`): the durable checkpoint is restored
  with ``device_put`` onto the *new* mesh's shardings (``checkpoint.restore``
  does this transparently — leaves carry their target shardings);
* **live remesh** (`remesh`): an in-memory state pytree is moved onto a new
  mesh directly (survivor-to-survivor reshard; on hardware this is the
  cheap path after a partial failure when HBM contents survive).

``plan_mesh`` picks the largest (data, model) grid that fits the surviving
device count while preserving the model-parallel degree (TP degree is a
property of the checkpoint's layout efficiency, DP shrinks freely).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from ..parallel.sharding import spec_for

__all__ = ["plan_mesh", "remesh", "reshard_state"]


def plan_mesh(n_devices: int, model_degree: int = 1,
              axis_names=("data", "model")) -> Mesh:
    """Largest (data, model) mesh for the surviving devices."""
    if model_degree > n_devices:
        raise ValueError(f"model degree {model_degree} > {n_devices} devices")
    data = n_devices // model_degree
    devices = jax.devices()[: data * model_degree]
    import numpy as np
    return Mesh(np.array(devices).reshape(data, model_degree), axis_names)


def remesh(tree, axes_tree, new_mesh: Mesh, rules=None):
    """Move a live pytree onto ``new_mesh`` (axes_tree: logical axes per
    leaf, same structure)."""
    def _move(x, axes):
        sh = NamedSharding(new_mesh, spec_for(axes, new_mesh, rules))
        return jax.device_put(x, sh)
    return jax.tree.map(_move, tree, axes_tree)


def reshard_state(ckpt_dir, like_state, step=None):
    """Restore a checkpoint onto the current mesh (thin alias with intent:
    ``like_state`` was built for the *new* mesh)."""
    from . import checkpoint as ck
    return ck.restore(ckpt_dir, like_state, step=step)
