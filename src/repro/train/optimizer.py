"""Optimizers + schedules (optax-style GradientTransformations, no deps).

Provides what both consumers need:
* the paper's autoencoder training: Adam, MSE, lr 1e-4 scaled linearly with
  the number of ranks (paper §4);
* the LM substrate: AdamW with decoupled weight decay, global-norm clipping,
  warmup+cosine schedules, and a memory-lean Adafactor-style option for the
  100B+ configs (factored second moment so optimizer state ≈ params instead
  of 3×).

Optimizer states inherit the sharding of the params they track (ZeRO: pjit
propagates the param PartitionSpec through ``init``), so FSDP-sharded params
get FSDP-sharded moments for free.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "GradientTransformation", "adam", "adamw", "adafactor", "sgd",
    "clip_by_global_norm", "chain", "scale_by_schedule",
    "warmup_cosine", "constant_schedule", "global_norm", "apply_updates",
]


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        # (step+1)/warmup: the first optimizer step gets a nonzero lr
        warm = peak_lr * (step + 1.0) / max(1, warmup_steps)
        prog = jnp.clip((step - warmup_steps)
                        / max(1, total_steps - warmup_steps), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return sched


# ---------------------------------------------------------------------------
# Transforms
# ---------------------------------------------------------------------------

def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params):
        norm = global_norm(grads)
        scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return GradientTransformation(init, update)


def scale_by_schedule(sched) -> GradientTransformation:
    class State(NamedTuple):
        step: jax.Array

    def init(params):
        return State(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        lr = sched(state.step)
        return (jax.tree.map(lambda g: -lr * g, grads),
                State(step=state.step + 1))

    return GradientTransformation(init, update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adam(lr: float | Callable = 1e-4, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         mu_dtype=jnp.float32) -> GradientTransformation:
    """Adam / AdamW (decoupled decay).  ``lr`` may be a schedule."""
    sched = lr if callable(lr) else constant_schedule(lr)

    def init(params):
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dtype), params),
            nu=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = sched(state.step)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(m.dtype),
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def _upd(m, v, p):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v / bc2
            u = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return -lr_t * u

        updates = jax.tree.map(_upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def adamw(lr=1e-4, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          mu_dtype=jnp.float32) -> GradientTransformation:
    return adam(lr, b1, b2, eps, weight_decay, mu_dtype)


class AdafactorState(NamedTuple):
    step: jax.Array
    vr: Any   # row second-moment (factored) or full v (unfactored leaves)
    vc: Any   # col second-moment ("" placeholder for unfactored)


def adafactor(lr: float | Callable = 1e-2, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              decay_pow: float = 0.8) -> GradientTransformation:
    """Memory-factored second-moment optimizer (Shazeer & Stern 2018).

    For ≥2-D params, stores row+col second-moment vectors instead of the full
    matrix — the state for a [d1,d2] weight is d1+d2 floats.  <2-D params
    fall back to full AdaGrad-style second moments.  No first moment:
    optimizer state ≈ ⅓ of Adam's — what makes the 340B/398B configs fit.
    """
    sched = lr if callable(lr) else constant_schedule(lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def rows(p):
            return (jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p)
                    else jnp.zeros(p.shape, jnp.float32))

        def cols(p):
            return (jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                    if _factored(p) else jnp.zeros((), jnp.float32))

        return AdafactorState(step=jnp.zeros((), jnp.int32),
                              vr=jax.tree.map(rows, params),
                              vc=jax.tree.map(cols, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay_pow)
        lr_t = sched(state.step)

        def _upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr_n = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc_n = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                u = g * jax.lax.rsqrt(vr_n[..., None] + eps) \
                      * jax.lax.rsqrt(vc_n[..., None, :] + eps) \
                      * jnp.sqrt(jnp.mean(vr_n, axis=-1, keepdims=True)
                                 + eps)[..., None]
                new = (vr_n, vc_n)
            else:
                v_n = beta * vr + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v_n + eps)
                new = (v_n, vc)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, new

        flat_g, tree = jax.tree.flatten(grads)
        flat_vr = jax.tree.leaves(state.vr)
        flat_vc = jax.tree.leaves(state.vc)
        flat_p = jax.tree.leaves(params)
        ups, news = [], []
        for g, vr, vc, p in zip(flat_g, flat_vr, flat_vc, flat_p):
            u, new = _upd(g, vr, vc, p)
            ups.append(u)
            news.append(new)
        updates = jax.tree.unflatten(tree, ups)
        vr_new = jax.tree.unflatten(tree, [n[0] for n in news])
        vc_new = jax.tree.unflatten(tree, [n[1] for n in news])
        return updates, AdafactorState(step=step, vr=vr_new, vc=vc_new)

    return GradientTransformation(init, update)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0
        ) -> GradientTransformation:
    sched = lr if callable(lr) else constant_schedule(lr)

    class State(NamedTuple):
        step: jax.Array
        mu: Any

    def init(params):
        mu = (jax.tree.map(jnp.zeros_like, params) if momentum else ())
        return State(step=jnp.zeros((), jnp.int32), mu=mu)

    def update(grads, state, params):
        lr_t = sched(state.step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
        else:
            mu = ()
            upd = jax.tree.map(lambda g: -lr_t * g, grads)
        return upd, State(step=state.step + 1, mu=mu)

    return GradientTransformation(init, update)
