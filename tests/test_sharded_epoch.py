"""Sharded fused epoch: one shard_map per epoch over a forced multi-device
CPU mesh must (a) train on exactly the same data stream as the
single-device fused tier and land on the same parameters, (b) stay one
dispatch per epoch, (c) contain the DDP all-reduce in its compiled HLO,
and (d) be bit-deterministic across runs."""

import textwrap

import pytest

from conftest import run_subprocess


def _run(body: str, n_devices: int = 2):
    """Concatenate the shared setup and a test body at indent 0 (the two
    literals have different indents, so dedent each before joining)."""
    run_subprocess(textwrap.dedent(_SETUP) + textwrap.dedent(body),
                   n_devices=n_devices)


_SETUP = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import StoreServer, TableSpec, Client
    from repro.core import store as S
    from repro.ml import autoencoder as ae, trainer as tr
    from repro.parallel.sharding import data_mesh
    from repro.sim import flatplate as fp
    from repro.train import optimizer as opt

    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    n = fcfg.n_points
    spec = TableSpec("field", shape=(4, n), capacity=16, engine="ring")
    st = S.init_table(spec)
    for i in range(10):
        st = S.put(spec, st, S.make_key(0, i),
                   fp.snapshot(fcfg, jax.random.key(0), i))
    aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
    levels = ae.coords_pyramid(aecfg, fp.grid_coords(fcfg))
    tx = opt.adam(1e-3)
    mu, sd = jnp.zeros((4,)), jnp.ones((4,))
"""


@pytest.mark.slow
def test_sharded_epoch_matches_single_device():
    """Mesh-2 epoch ≡ single-device fused epoch on the same table/rng
    (identical data stream, params equal to float-reduction-order noise),
    and repeated mesh runs are bitwise identical."""
    _run("""
        mesh = data_mesh(2)
        cfg1 = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4, lr=1e-3)
        cfg2 = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4, lr=1e-3,
                                mesh=mesh)
        state0 = tr.init_state(cfg1, jax.random.key(0), tx)
        ep1 = tr.make_fused_epoch(cfg1, levels, tx, spec)
        ep2 = tr.make_sharded_fused_epoch(cfg2, levels, tx, spec)
        rng = jax.random.key(7)
        s1, m1 = ep1(st, state0, rng, mu, sd)
        s2, m2 = ep2(st, state0, rng, mu, sd)
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(float(m1[0]), float(m2[0]), rtol=1e-5)
        np.testing.assert_allclose(float(m1[1]), float(m2[1]), rtol=1e-4)
        assert int(s2.step) == int(s1.step)

        # bit-determinism of the sharded tier
        s2b, _ = ep2(st, state0, rng, mu, sd)
        for a, b in zip(jax.tree.leaves(s2.params),
                        jax.tree.leaves(s2b.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # the DDP all-reduce is structurally present in the compiled HLO
        from repro.analysis.hlo import count_ops
        txt = ep2.lower(st, state0, rng, mu, sd).compile().as_text()
        assert count_ops(txt).get("all-reduce", 0) > 0, "no DDP all-reduce"
        print("SHARDED_PARITY_OK")
    """)


@pytest.mark.slow
def test_sharded_epoch_one_dispatch_per_epoch():
    """insitu_train on a mesh: O(1) server dispatches per epoch and a
    decreasing loss — the paper's scaling claim as a structural invariant."""
    _run("""
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)
        for i in range(10):
            client.send_step("field", i, fp.snapshot(fcfg,
                                                     jax.random.key(0), i))
        cfg = tr.TrainerConfig(ae=aecfg, epochs=6, gather=6, batch_size=4,
                               lr=1e-3, fused=True, mesh=data_mesh(2))
        ops_before = srv.op_count
        state, hist, _, _ = tr.insitu_train(client, fp.grid_coords(fcfg),
                                            cfg)
        assert len(hist) == 6
        head = np.mean([h.train_loss for h in hist[:2]])
        tail = np.mean([h.train_loss for h in hist[-2:]])
        assert tail < head, (head, tail)
        # 1 capture per epoch + norm-stats bootstrap + warmup
        assert srv.op_count - ops_before <= cfg.epochs + 2
        print("SHARDED_DISPATCH_OK")
    """)


@pytest.mark.slow
def test_int8_ddp_tracks_exact_psum():
    """The compressed gradient wire must track the exact psum path at the
    loss level (per-step int8 bias stays small), with and without the
    in-carry error feedback."""
    _run("""
        mesh = data_mesh(2)
        outs = {}
        for ddp, ef in (("psum", False), ("int8", False), ("int8", True)):
            cfg = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh, ddp=ddp,
                                   ddp_error_feedback=ef)
            ep = tr.make_sharded_fused_epoch(cfg, levels, tx, spec)
            state0 = tr.init_state(cfg, jax.random.key(0), tx)
            state, m = ep(st, state0, jax.random.key(7), mu, sd)
            assert all(np.isfinite(float(x)) for x in m[:3])
            outs[(ddp, ef)] = float(m[0])
        ref = outs[("psum", False)]
        for k, v in outs.items():
            rel = abs(v - ref) / (abs(ref) + 1e-9)
            assert rel < 0.02, (k, outs)
        print("INT8_DDP_OK", outs)
    """)


@pytest.mark.slow
def test_int8_error_feedback_in_scan_carry():
    """The error-feedback residual must actually ride the scan carry
    (params differ from the no-feedback wire) and the fused tier must
    stay bit-deterministic with it threaded (ROADMAP follow-up: the
    host-side ErrorFeedback could not ride the fused epoch)."""
    _run("""
        mesh = data_mesh(2)
        params = {}
        for ef in (True, False):
            cfg = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh, ddp="int8",
                                   ddp_error_feedback=ef)
            ep = tr.make_sharded_fused_epoch(cfg, levels, tx, spec)
            state0 = tr.init_state(cfg, jax.random.key(0), tx)
            s1, _ = ep(st, state0, jax.random.key(7), mu, sd)
            s2, _ = ep(st, state0, jax.random.key(7), mu, sd)
            # bit-determinism on the forced 2-device mesh
            for a, b in zip(jax.tree.leaves(s1.params),
                            jax.tree.leaves(s2.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            params[ef] = s1.params
        # the residual is threaded: with-EF parameters differ from without
        assert any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params[True]),
                            jax.tree.leaves(params[False])))
        print("INT8_EF_OK")
    """)


@pytest.mark.slow
def test_slab_sharded_entry_bitwise_parity_and_cache():
    """The slab-sharded data plane (tier ``slab_sharded``): the table
    enters the epoch's shard_map pre-partitioned on the slot axis, the
    gather runs shard-local + one psum — and the final TrainState must be
    BIT-identical to the replicated-entry sharded tier on the same table.
    The compiled executable must also be reused across epochs (no
    per-epoch recompiles from sharding mismatches), and a non-divisible
    capacity is rejected up front."""
    _run("""
        from dataclasses import replace
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.parallel.sharding import slab_sharding

        mesh = data_mesh(2)
        cfg_rep = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh)
        cfg_slab = replace(cfg_rep, slab_sharded=True)
        state0 = tr.init_state(cfg_rep, jax.random.key(0), tx)
        ep_rep = tr.EPOCH_BUILDERS["sharded_fused"](cfg_rep, levels, tx,
                                                    spec)
        ep_slab = tr.EPOCH_BUILDERS["slab_sharded"](cfg_slab, levels, tx,
                                                    spec)

        # place the SAME table contents slab-sharded (slot axis split,
        # metadata replicated)
        sh = slab_sharding(spec, mesh)
        rep = NamedSharding(mesh, P())
        st_sh = S.TableState(
            slab=jax.device_put(st.slab, sh),
            keys=jax.device_put(st.keys, rep),
            version=jax.device_put(st.version, rep),
            ptr=jax.device_put(st.ptr, rep),
            count=jax.device_put(st.count, rep))

        rng = jax.random.key(7)
        s1, m1 = ep_rep(st, state0, rng, mu, sd)
        s2, m2 = ep_slab(st_sh, state0, rng, mu, sd)
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(m1[0]), np.asarray(m2[0]))

        # one executable serves every epoch (same input shardings)
        s3, _ = ep_slab(st_sh, state0, jax.random.key(8), mu, sd)
        assert ep_slab._cache_size() == 1, ep_slab._cache_size()

        # non-divisible capacity is rejected at build time
        bad = TableSpec("bad", shape=(4, n), capacity=15, engine="ring")
        try:
            tr.EPOCH_BUILDERS["slab_sharded"](cfg_slab, levels, tx, bad)
            raise SystemExit("capacity 15 over 2 ranks was accepted")
        except ValueError:
            pass
        print("SLAB_PARITY_OK")
    """)


@pytest.mark.slow
def test_slab_sharded_insitu_train_dispatches():
    """End to end through the server: the table is *placed* slab-sharded
    at creation, ``insitu_train`` resolves the slab_sharded tier, and the
    epoch loop stays exactly one store dispatch per epoch (plus the
    norm-stats bootstrap) — the O(1)-dispatch invariant with the sharded
    data plane.  The bucketed producer capture against the sharded table
    must also keep compiling once per (table, bucket), not per tail."""
    _run("""
        from repro.parallel.sharding import slab_sharding
        mesh = data_mesh(2)
        srv = StoreServer()
        srv.create_table(spec, slab_sharding=slab_sharding(spec, mesh))
        client = Client(srv)

        # fused producer against the sharded slab: distinct tail lengths
        # inside one bucket range still compile at most two executables
        def pstep(c, t):
            val = jnp.broadcast_to(t.astype(jnp.float32), (4, n))
            return c, S.make_key(0, t), val
        c0 = S.capture_scan._cache_size()
        for t0, k in [(0, 5), (5, 7), (12, 9), (21, 12), (33, 6)]:
            client.capture_scan("field", pstep, jnp.zeros(()), k, 1,
                                t0=t0, bucket=True)
        assert S.capture_scan._cache_size() - c0 <= 2, \\
            S.capture_scan._cache_size() - c0

        # refill with real snapshots for training
        for i in range(10):
            client.send_step("field", i,
                             fp.snapshot(fcfg, jax.random.key(0), i))
        cfg = tr.TrainerConfig(ae=aecfg, epochs=5, gather=6, batch_size=4,
                               lr=1e-3, mesh=mesh, slab_sharded=True)
        from repro.insitu.plan import trainer_tier
        assert trainer_tier(cfg) == "slab_sharded"
        ops_before = srv.op_count
        state, hist, _, _ = tr.insitu_train(client, fp.grid_coords(fcfg),
                                            cfg)
        assert len(hist) == 5
        assert all(np.isfinite(h.train_loss) for h in hist)
        # exactly: 1 norm-stats bootstrap sample + 1 capture per epoch
        assert srv.op_count - ops_before == cfg.epochs + 1, \\
            srv.op_count - ops_before
        print("SLAB_DISPATCH_OK")
    """)


def test_config_validation():
    from repro.ml import autoencoder as ae
    from repro.ml import trainer as tr

    aecfg = ae.AEConfig(n_points=256)
    with pytest.raises(ValueError):
        tr.TrainerConfig(ae=aecfg, ddp="fp8")
    with pytest.raises(ValueError):
        tr.TrainerConfig(ae=aecfg, mesh=object(), fused=False)
    with pytest.raises(ValueError):
        tr.TrainerConfig(ae=aecfg, slab_sharded=True)   # needs a mesh
