"""Sharded fused epoch: one shard_map per epoch over a forced multi-device
CPU mesh must (a) train on exactly the same data stream as the
single-device fused tier and land on the same parameters, (b) stay one
dispatch per epoch, (c) contain the DDP all-reduce in its compiled HLO,
and (d) be bit-deterministic across runs."""

import textwrap

import pytest

from conftest import run_subprocess


def _run(body: str, n_devices: int = 2):
    """Concatenate the shared setup and a test body at indent 0 (the two
    literals have different indents, so dedent each before joining)."""
    run_subprocess(textwrap.dedent(_SETUP) + textwrap.dedent(body),
                   n_devices=n_devices)


_SETUP = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import StoreServer, TableSpec, Client
    from repro.core import store as S
    from repro.ml import autoencoder as ae, trainer as tr
    from repro.parallel.sharding import data_mesh
    from repro.sim import flatplate as fp
    from repro.train import optimizer as opt

    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    n = fcfg.n_points
    spec = TableSpec("field", shape=(4, n), capacity=16, engine="ring")
    st = S.init_table(spec)
    for i in range(10):
        st = S.put(spec, st, S.make_key(0, i),
                   fp.snapshot(fcfg, jax.random.key(0), i))
    aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
    levels = ae.coords_pyramid(aecfg, fp.grid_coords(fcfg))
    tx = opt.adam(1e-3)
    mu, sd = jnp.zeros((4,)), jnp.ones((4,))
"""


@pytest.mark.slow
def test_sharded_epoch_matches_single_device():
    """Mesh-2 epoch ≡ single-device fused epoch on the same table/rng
    (identical data stream, params equal to float-reduction-order noise),
    and repeated mesh runs are bitwise identical."""
    _run("""
        mesh = data_mesh(2)
        cfg1 = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4, lr=1e-3)
        cfg2 = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4, lr=1e-3,
                                mesh=mesh)
        state0 = tr.init_state(cfg1, jax.random.key(0), tx)
        ep1 = tr.make_fused_epoch(cfg1, levels, tx, spec)
        ep2 = tr.make_sharded_fused_epoch(cfg2, levels, tx, spec)
        rng = jax.random.key(7)
        s1, m1 = ep1(st, state0, rng, mu, sd)
        s2, m2 = ep2(st, state0, rng, mu, sd)
        for a, b in zip(jax.tree.leaves(s1.params),
                        jax.tree.leaves(s2.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(float(m1[0]), float(m2[0]), rtol=1e-5)
        np.testing.assert_allclose(float(m1[1]), float(m2[1]), rtol=1e-4)
        assert int(s2.step) == int(s1.step)

        # bit-determinism of the sharded tier
        s2b, _ = ep2(st, state0, rng, mu, sd)
        for a, b in zip(jax.tree.leaves(s2.params),
                        jax.tree.leaves(s2b.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # the DDP all-reduce is structurally present in the compiled HLO
        from repro.analysis.hlo import count_ops
        txt = ep2.lower(st, state0, rng, mu, sd).compile().as_text()
        assert count_ops(txt).get("all-reduce", 0) > 0, "no DDP all-reduce"
        print("SHARDED_PARITY_OK")
    """)


@pytest.mark.slow
def test_sharded_epoch_one_dispatch_per_epoch():
    """insitu_train on a mesh: O(1) server dispatches per epoch and a
    decreasing loss — the paper's scaling claim as a structural invariant."""
    _run("""
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)
        for i in range(10):
            client.send_step("field", i, fp.snapshot(fcfg,
                                                     jax.random.key(0), i))
        cfg = tr.TrainerConfig(ae=aecfg, epochs=6, gather=6, batch_size=4,
                               lr=1e-3, fused=True, mesh=data_mesh(2))
        ops_before = srv.op_count
        state, hist, _, _ = tr.insitu_train(client, fp.grid_coords(fcfg),
                                            cfg)
        assert len(hist) == 6
        head = np.mean([h.train_loss for h in hist[:2]])
        tail = np.mean([h.train_loss for h in hist[-2:]])
        assert tail < head, (head, tail)
        # 1 capture per epoch + norm-stats bootstrap + warmup
        assert srv.op_count - ops_before <= cfg.epochs + 2
        print("SHARDED_DISPATCH_OK")
    """)


@pytest.mark.slow
def test_int8_ddp_tracks_exact_psum():
    """The compressed gradient wire must track the exact psum path at the
    loss level (per-step int8 bias stays small), with and without the
    in-carry error feedback."""
    _run("""
        mesh = data_mesh(2)
        outs = {}
        for ddp, ef in (("psum", False), ("int8", False), ("int8", True)):
            cfg = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh, ddp=ddp,
                                   ddp_error_feedback=ef)
            ep = tr.make_sharded_fused_epoch(cfg, levels, tx, spec)
            state0 = tr.init_state(cfg, jax.random.key(0), tx)
            state, m = ep(st, state0, jax.random.key(7), mu, sd)
            assert all(np.isfinite(float(x)) for x in m[:3])
            outs[(ddp, ef)] = float(m[0])
        ref = outs[("psum", False)]
        for k, v in outs.items():
            rel = abs(v - ref) / (abs(ref) + 1e-9)
            assert rel < 0.02, (k, outs)
        print("INT8_DDP_OK", outs)
    """)


@pytest.mark.slow
def test_int8_error_feedback_in_scan_carry():
    """The error-feedback residual must actually ride the scan carry
    (params differ from the no-feedback wire) and the fused tier must
    stay bit-deterministic with it threaded (ROADMAP follow-up: the
    host-side ErrorFeedback could not ride the fused epoch)."""
    _run("""
        mesh = data_mesh(2)
        params = {}
        for ef in (True, False):
            cfg = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh, ddp="int8",
                                   ddp_error_feedback=ef)
            ep = tr.make_sharded_fused_epoch(cfg, levels, tx, spec)
            state0 = tr.init_state(cfg, jax.random.key(0), tx)
            s1, _ = ep(st, state0, jax.random.key(7), mu, sd)
            s2, _ = ep(st, state0, jax.random.key(7), mu, sd)
            # bit-determinism on the forced 2-device mesh
            for a, b in zip(jax.tree.leaves(s1.params),
                            jax.tree.leaves(s2.params)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            params[ef] = s1.params
        # the residual is threaded: with-EF parameters differ from without
        assert any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(params[True]),
                            jax.tree.leaves(params[False])))
        print("INT8_EF_OK")
    """)


def test_config_validation():
    from repro.ml import autoencoder as ae
    from repro.ml import trainer as tr

    aecfg = ae.AEConfig(n_points=256)
    with pytest.raises(ValueError):
        tr.TrainerConfig(ae=aecfg, ddp="fp8")
    with pytest.raises(ValueError):
        tr.TrainerConfig(ae=aecfg, mesh=object(), fused=False)
