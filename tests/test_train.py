"""Training substrate: optimizers, schedules, checkpointing, data pipeline."""

import math
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ck
from repro.train import optimizer as opt


def _quadratic_problem():
    target = jnp.array([1.0, -2.0, 3.0])

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    return {"w": jnp.zeros(3)}, loss, target


class TestOptimizers:
    @pytest.mark.parametrize("make,tol", [
        (lambda: opt.adam(5e-2), 1e-2),
        (lambda: opt.adamw(5e-2, weight_decay=0.0), 1e-2),
        # adafactor's relative-update clipping hovers near the optimum
        (lambda: opt.adafactor(5e-1), 2e-1),
        (lambda: opt.sgd(1e-1, momentum=0.9), 1e-2),
    ])
    def test_converges_on_quadratic(self, make, tol):
        params, loss, target = _quadratic_problem()
        tx = make()
        state = tx.init(params)
        for _ in range(300):
            grads = jax.grad(loss)(params)
            updates, state = tx.update(grads, state, params)
            params = opt.apply_updates(params, updates)
        assert float(loss(params)) < tol

    def test_adam_matches_reference_step(self):
        """First Adam step = -lr·sign-ish update with bias correction."""
        tx = opt.adam(1e-1, b1=0.9, b2=0.999, eps=1e-8)
        params = {"w": jnp.array([1.0])}
        grads = {"w": jnp.array([0.5])}
        state = tx.init(params)
        updates, _ = tx.update(grads, state, params)
        # mhat = g, vhat = g², update = -lr·g/(|g|+eps) ≈ -lr
        np.testing.assert_allclose(np.asarray(updates["w"]), [-0.1],
                                   rtol=1e-4)

    def test_clip_by_global_norm(self):
        tx = opt.clip_by_global_norm(1.0)
        grads = {"a": jnp.full(4, 10.0)}
        clipped, _ = tx.update(grads, tx.init(grads), grads)
        assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5

    def test_chain_order(self):
        tx = opt.chain(opt.clip_by_global_norm(1.0), opt.sgd(1.0))
        grads = {"a": jnp.full(4, 10.0)}
        state = tx.init(grads)
        updates, _ = tx.update(grads, state, grads)
        assert abs(float(opt.global_norm(updates)) - 1.0) < 1e-5

    def test_adafactor_memory_factored(self):
        tx = opt.adafactor()
        params = {"w": jnp.zeros((64, 32))}
        state = tx.init(params)
        assert state.vr["w"].shape == (64,)
        assert state.vc["w"].shape == (32,)

    def test_warmup_cosine(self):
        sched = opt.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
        assert float(sched(0)) == pytest.approx(0.1)   # first step nonzero
        assert abs(float(sched(10)) - 1.0) < 1e-6
        assert float(sched(4)) == pytest.approx(0.5)
        assert float(sched(100)) == pytest.approx(0.1, abs=1e-3)


class TestCheckpoint:
    def _state(self, k=0):
        return {"params": {"w": jnp.arange(6.0).reshape(2, 3) + k},
                "step": jnp.int32(10 + k)}

    def test_roundtrip(self, tmp_path):
        ck.save(tmp_path, 10, self._state())
        restored = ck.restore(tmp_path, self._state(99))
        np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                                   np.arange(6).reshape(2, 3))
        assert int(restored["step"]) == 10

    def test_latest_and_retention(self, tmp_path):
        for s in (1, 2, 3, 4):
            ck.save(tmp_path, s, self._state(s), keep=2)
        assert ck.latest_step(tmp_path) == 4
        kept = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(kept) == 2

    def test_restore_missing_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            ck.restore(tmp_path, self._state())

    def test_async_checkpointer(self, tmp_path):
        c = ck.Checkpointer(tmp_path, interval_steps=2, keep=5)
        for step in range(1, 7):
            c.maybe_save(step, self._state(step))
        c.wait()
        assert c.saves == 3 and not c.errors
        assert ck.latest_step(tmp_path) == 6

    def test_dtype_cast_on_restore(self, tmp_path):
        ck.save(tmp_path, 1, {"w": jnp.ones(3, jnp.float32)})
        like = {"w": jnp.zeros(3, jnp.bfloat16)}
        restored = ck.restore(tmp_path, like)
        assert restored["w"].dtype == jnp.bfloat16


class TestDataPipeline:
    def test_token_stream_learnable_structure(self):
        from repro.data.pipeline import TokenStream
        it = iter(TokenStream(vocab=97, batch=2, seq_len=64, structure=1.0))
        b = next(it)
        t = b["tokens"]
        assert t.shape == (2, 64) and t.dtype == np.int32
        # fully structured: next = (prev*31+7) mod V everywhere
        np.testing.assert_array_equal(t[:, 1:], (t[:, :-1] * 31 + 7) % 97)

    def test_prefetch_iterator(self):
        from repro.data.pipeline import PrefetchIterator

        def gen():
            for i in range(5):
                yield {"x": np.full((2,), i)}

        out = [int(b["x"][0]) for b in PrefetchIterator(gen(), buffer_size=2)]
        assert out == [0, 1, 2, 3, 4]
