"""Serving substrate: batcher logic + generate/serve loops + AE trainer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm
from repro.parallel.sharding import init_params
from repro.serve.batching import Batcher
from repro.serve.decode import greedy_generate, serve_loop


class TestBatcher:
    def test_admit_and_retire(self):
        b = Batcher(max_batch=2)
        r1 = b.submit([1, 2], max_new_tokens=2)
        r2 = b.submit([3], max_new_tokens=1)
        r3 = b.submit([4], max_new_tokens=1)
        placed = b.admit()
        assert len(placed) == 2 and b.queue
        b.record_tokens(np.array([7, 8]))
        b.record_tokens(np.array([9, 0]))
        assert r2.done and r1.done
        assert r1.tokens == [7, 9]
        placed = b.admit()          # r3 takes a freed slot
        assert placed and placed[0][1] is r3

    def test_eos_stops(self):
        b = Batcher(max_batch=1, eos_id=0)
        r = b.submit([5], max_new_tokens=10)
        b.admit()
        b.record_tokens(np.array([3]))
        b.record_tokens(np.array([0]))
        assert r.done and r.tokens == [3, 0]

    def test_idle(self):
        b = Batcher(max_batch=1)
        assert b.idle
        b.submit([1], max_new_tokens=1)
        assert not b.idle

    def test_admit_empty_queue_is_noop(self):
        b = Batcher(max_batch=2)
        assert b.admit() == []
        assert all(slot is None for slot in b.slots)
        r = b.submit([1], max_new_tokens=1)
        b.admit()
        # queue drained: a second admit places nothing and moves nothing
        before = list(b.slots)
        assert b.admit() == []
        assert b.slots == before and not r.done

    def test_slot_churn_at_max_batch(self):
        """2*max_batch+1 requests through max_batch slots: admission
        never exceeds max_batch live slots and every request retires."""
        b = Batcher(max_batch=3)
        reqs = [b.submit([i], max_new_tokens=1) for i in range(7)]
        rounds = 0
        while not (b.idle and all(r.done for r in reqs)):
            placed = b.admit()
            assert len(placed) <= 3
            live = [s for s in b.slots if s is not None and not s.done]
            assert 0 < len(live) <= 3
            b.record_tokens(np.zeros(3, np.int64))
            rounds += 1
            assert rounds <= 7, "batcher failed to drain"
        assert rounds == 3          # ceil(7 / 3) drains
        assert all(r.done and len(r.tokens) == 1 for r in reqs)

    def test_eos_retirement_frees_slot_for_queued(self):
        """An eos mid-stream retires ONLY that slot; the freed slot goes
        to the queued request while the other slot keeps decoding."""
        b = Batcher(max_batch=2, eos_id=0)
        r1 = b.submit([1], max_new_tokens=4)
        r2 = b.submit([2], max_new_tokens=4)
        r3 = b.submit([3], max_new_tokens=4)
        b.admit()
        b.record_tokens(np.array([5, 0]))       # r2 hits eos
        assert r2.done and r2.tokens == [0]
        assert not r1.done and r1.tokens == [5]
        placed = b.admit()
        assert len(placed) == 1 and placed[0][1] is r3
        assert placed[0][0] == b.slots.index(r3)
        # r1 continues decoding in its original slot
        b.record_tokens(np.array([7, 9]) if b.slots[0] is r1
                        else np.array([9, 7]))
        assert r1.tokens == [5, 7]

    def test_idle_transitions_through_drain(self):
        b = Batcher(max_batch=2)
        assert b.idle
        r = b.submit([1], max_new_tokens=2)
        assert not b.idle           # queued
        b.admit()
        assert not b.idle           # active in a slot
        b.record_tokens(np.array([4, 0]))
        assert not b.idle
        b.record_tokens(np.array([5, 0]))
        assert r.done and b.idle    # retired: queue and slots empty


@pytest.mark.slow
class TestGenerate:
    def test_greedy_matches_stepwise_forward(self):
        """Greedy decode == argmax over teacher-forced forward each step."""
        cfg = get_smoke_config("phi4_mini_3_8b")
        params = init_params(jax.random.key(0), lm.lm_specs(cfg), cfg.dtype)
        prompt = jax.random.randint(jax.random.key(1), (1, 6), 0, cfg.vocab)
        out = greedy_generate(params, cfg, prompt, max_new=4)
        # reference: extend by full forward each step
        seq = prompt
        ref = []
        for _ in range(4):
            hid, _ = lm.forward(params, cfg, seq)
            w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
            nxt = jnp.argmax(hid[:, -1] @ w, -1)[:, None].astype(jnp.int32)
            ref.append(nxt)
            seq = jnp.concatenate([seq, nxt], axis=1)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.concatenate(ref, 1)))

    def test_serve_loop_completes(self):
        cfg = get_smoke_config("starcoder2_3b")
        params = init_params(jax.random.key(0), lm.lm_specs(cfg), cfg.dtype)
        b = Batcher(max_batch=2)
        for i in range(4):
            b.submit([i + 1, i + 2], max_new_tokens=3)
        completed, steps, tps = serve_loop(params, cfg, b, t_max=32,
                                           max_steps=200)
        assert len(completed) == 4
        assert all(len(r.tokens) == 3 for r in completed)


@pytest.mark.slow
def test_insitu_trainer_loss_decreases():
    """Store-fed trainer: loss decreases on a static snapshot set."""
    from repro.core import Client, StoreServer, TableSpec
    from repro.ml import autoencoder as ae
    from repro.ml import trainer as tr
    from repro.sim import flatplate as fp
    fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
    server = StoreServer()
    server.create_table(TableSpec("field", shape=(4, fcfg.n_points),
                                  capacity=16, engine="ring"))
    client = Client(server)
    for step in range(10):
        client.send_step("field", step, fp.snapshot(fcfg, jax.random.key(0),
                                                    step))
    cfg = tr.TrainerConfig(
        ae=ae.AEConfig(n_points=fcfg.n_points, mode="ref", latent=16,
                       mlp_width=16),
        epochs=10, gather=6, batch_size=4, lr=1e-3)
    state, history, levels, stats = tr.insitu_train(
        client, fp.grid_coords(fcfg), cfg)
    head = np.mean([h.train_loss for h in history[:2]])
    tail = np.mean([h.train_loss for h in history[-2:]])
    assert tail < head, (head, tail)
    # validation metric sane
    assert 0 < history[-1].val_rel_error < 2.0
