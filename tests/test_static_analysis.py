"""repro-lint self-tests: every rule fires on a minimal violating
fixture and stays silent on the repaired twin; the real tree passes
clean; the LockTracker runtime witness builds an acyclic lock-order
graph on a live server and catches synthetic inversions; and the real
findings this PR fixed (unlogged delete tombstones, unlocked recovery
bookkeeping) have regression tests."""

import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from lint.budgets import BudgetRow, match_cells  # noqa: E402
from lint.engine import lint_source, lint_tree  # noqa: E402
from lint.rules_locks import LockHoldsRule  # noqa: E402
from lint.rules_parity import (check_fault_parity,  # noqa: E402
                               check_verb_parity)


def _ids(findings):
    return sorted({f.rule for f in findings})


def _fires(src: str, rule_id: str):
    findings = lint_source(textwrap.dedent(src), "fixture.py")
    assert rule_id in _ids(findings), \
        f"expected {rule_id} to fire, got {findings}"
    return findings


def _silent(src: str, rule_id: str = None):
    findings = lint_source(textwrap.dedent(src), "fixture.py")
    if rule_id is None:
        assert findings == [], findings
    else:
        assert rule_id not in _ids(findings), findings


# -- lock-mutation ----------------------------------------------------------

_LOCK_VIOLATION = """
    import threading

    class Server:
        def __init__(self):
            self._table_locks = {}
            self._state = {}

        def put(self, table, value):
            self._state[table] = value
"""

_LOCK_REPAIRED = """
    import threading

    class Server:
        def __init__(self):
            self._table_locks = {}
            self._state = {}

        def put(self, table, value):
            with self._table_locks[table]:
                self._state[table] = value
"""


class TestLockMutation:
    def test_fires_outside_context(self):
        _fires(_LOCK_VIOLATION, "lock-mutation")

    def test_silent_on_repaired_twin(self):
        _silent(_LOCK_REPAIRED)

    def test_mutator_method_call_fires(self):
        _fires(_LOCK_VIOLATION.replace(
            "self._state[table] = value",
            "self._acked.add(value)"), "lock-mutation")

    def test_registry_lock_also_guards(self):
        _silent(_LOCK_REPAIRED.replace(
            "with self._table_locks[table]:", "with self._lock:"))

    def test_holds_lock_marker_exempts(self):
        _silent(_LOCK_VIOLATION.replace(
            "def put(self, table, value):",
            "def put(self, table, value):  # lint: holds-lock"))

    def test_suppression_comment(self):
        _silent(_LOCK_VIOLATION.replace(
            "self._state[table] = value",
            "self._state[table] = value  # lint: disable=lock-mutation"))

    def test_plain_class_out_of_scope(self):
        _silent("""
            class NotAServer:
                def put(self, table, value):
                    self._state[table] = value
        """)


# -- lock-order -------------------------------------------------------------

_ORDER_VIOLATION = """
    class Server:
        def __init__(self):
            self._table_locks = {}

        def serve(self, a, b):
            with self._table_locks[a], self._table_locks[b]:
                pass
"""

_ORDER_REPAIRED = """
    class Server:
        def __init__(self):
            self._table_locks = {}

        def serve(self, a, b):
            first, second = sorted((a, b))
            with self._table_locks[first], self._table_locks[second]:
                pass
"""


class TestLockOrder:
    def test_fires_on_unsorted_pair(self):
        _fires(_ORDER_VIOLATION, "lock-order")

    def test_silent_on_canonical_twin(self):
        _silent(_ORDER_REPAIRED)

    def test_fires_on_swapped_sorted_names(self):
        _fires(_ORDER_REPAIRED.replace(
            "self._table_locks[first], self._table_locks[second]",
            "self._table_locks[second], self._table_locks[first]"),
            "lock-order")

    def test_fires_on_nested_acquisition(self):
        _fires("""
            class Server:
                def __init__(self):
                    self._table_locks = {}

                def serve(self, a, b):
                    with self._table_locks[a]:
                        with self._table_locks[b]:
                            pass
        """, "lock-order")

    def test_fires_on_literal_indices(self):
        _fires("""
            class Server:
                def __init__(self):
                    self._table_locks = {}

                def serve(self):
                    with self._table_locks["req"], self._table_locks["res"]:
                        pass
        """, "lock-order")


# -- lock-leaf --------------------------------------------------------------

class TestLockLeaf:
    def test_fires_on_nesting_inside_ops_lock(self):
        _fires("""
            class Server:
                def bump(self):
                    with self._ops_lock:
                        with self._lock:
                            self.op_count += 1
        """, "lock-leaf")

    def test_silent_on_leaf_use(self):
        _silent("""
            class Server:
                def bump(self):
                    with self._ops_lock:
                        self.op_count += 1
        """)


# -- lock-holds -------------------------------------------------------------

def _holds_findings(src: str):
    import ast
    src = textwrap.dedent(src)
    return LockHoldsRule().check_modules(
        [("fixture.py", src, ast.parse(src))])


class TestLockHolds:
    FIXTURE = """
        class Server:
            # lint: holds-lock
            def apply_chunk(self, table, txn):
                self._acked.add(table)

        def caller(server, table, txn):
            server.apply_chunk(table, txn)
    """

    def test_fires_on_unlocked_call(self):
        findings = _holds_findings(self.FIXTURE)
        assert _ids(findings) == ["lock-holds"]

    def test_silent_inside_capture(self):
        assert _holds_findings("""
            class Server:
                # lint: holds-lock
                def apply_chunk(self, table, txn):
                    self._acked.add(table)

            def caller(server, table):
                with server.capture(table) as txn:
                    server.apply_chunk(table, txn)
        """) == []


# -- trace-host -------------------------------------------------------------

_TRACE_VIOLATION = """
    import time
    from jax import lax

    def producer(carry, xs):
        def body(c, x):
            t = time.perf_counter()
            return c + t, x
        return lax.scan(body, carry, xs)
"""


class TestTraceHost:
    def test_fires_on_time_in_scan_body(self):
        _fires(_TRACE_VIOLATION, "trace-host")

    def test_silent_on_pure_twin(self):
        _silent(_TRACE_VIOLATION.replace(
            "            t = time.perf_counter()\n"
            "            return c + t, x",
            "            return c + 1.0, x"))

    def test_fires_on_np_random(self):
        _fires("""
            import numpy as np
            from jax import lax

            def producer(carry, xs):
                def body(c, x):
                    return c + np.random.normal(), x
                return lax.scan(body, carry, xs)
        """, "trace-host")

    def test_fires_on_item_host_sync(self):
        _fires("""
            from jax import lax

            def producer(carry, xs):
                def body(c, x):
                    if c.item() > 0:
                        return c, x
                    return c, x
                return lax.scan(body, carry, xs)
        """, "trace-host")

    def test_fires_on_float_of_traced_arg(self):
        _fires("""
            from jax import lax

            def producer(carry, xs):
                def body(c, x):
                    return c + float(x), x
                return lax.scan(body, carry, xs)
        """, "trace-host")

    def test_jax_random_is_fine(self):
        _silent("""
            from jax import lax, random

            def producer(carry, xs):
                def body(c, x):
                    return c + random.normal(random.key(0)), x
                return lax.scan(body, carry, xs)
        """)

    def test_shard_map_and_pallas_bodies_covered(self):
        _fires("""
            import threading
            from jax.experimental.shard_map import shard_map

            def kernel(x):
                threading.Event()
                return x

            def run(mesh, x):
                return shard_map(kernel, mesh=mesh)(x)
        """, "trace-host")


# -- parity -----------------------------------------------------------------

_SERVER_FIXTURE = """
    class StoreServer:
        def put(self, table, key, value):
            self._bump_ops()

        def frobnicate(self, table):
            self._bump_ops()
"""

_PLAN_FIXTURE = """
    VERB_CAUSES = {"put": ("put",)}
    UNPLANNED_VERBS = ()

    def producer_dispatches(tier, steps):
        return (("put", steps),)
"""


class TestParity:
    def test_uncounted_verb_fires(self):
        findings = check_verb_parity(
            textwrap.dedent(_SERVER_FIXTURE),
            textwrap.dedent(_PLAN_FIXTURE))
        assert any("frobnicate" in f.message for f in findings), findings

    def test_declared_twin_is_silent(self):
        plan = _PLAN_FIXTURE.replace(
            "UNPLANNED_VERBS = ()",
            'UNPLANNED_VERBS = ("frobnicate",)')
        assert check_verb_parity(
            textwrap.dedent(_SERVER_FIXTURE),
            textwrap.dedent(plan)) == []

    def test_stale_declaration_fires(self):
        plan = _PLAN_FIXTURE.replace(
            "UNPLANNED_VERBS = ()",
            'UNPLANNED_VERBS = ("frobnicate", "gone")')
        findings = check_verb_parity(
            textwrap.dedent(_SERVER_FIXTURE),
            textwrap.dedent(plan))
        assert any("gone" in f.message for f in findings), findings

    def test_unknown_cause_fires(self):
        plan = _PLAN_FIXTURE.replace(
            '{"put": ("put",)}', '{"put": ("teleport",)}').replace(
            "UNPLANNED_VERBS = ()",
            'UNPLANNED_VERBS = ("frobnicate",)')
        findings = check_verb_parity(
            textwrap.dedent(_SERVER_FIXTURE),
            textwrap.dedent(plan))
        assert any("teleport" in f.message for f in findings), findings

    def test_fault_walk_gap_fires(self):
        client = """
            class Client:
                def put_kv(self, table, key, value):
                    self._call_verb("put", table, lambda: None)

                def sample(self, table):
                    self._call_verb("sample", table, lambda: None)
        """
        faults = """
            def simulate_overhead(plan, schedule):
                def _verb(o, verb, table):
                    pass
                _verb(None, "put", None)
        """
        findings = check_fault_parity(textwrap.dedent(client),
                                      textwrap.dedent(faults))
        assert any("sample" in f.message for f in findings), findings
        faults_fixed = faults + '    _verb(None, "sample", None)\n'
        assert check_fault_parity(textwrap.dedent(client),
                                  textwrap.dedent(faults_fixed)) == []


# -- collective budgets -----------------------------------------------------

class TestBudgets:
    MANIFEST = (BudgetRow("clustered", "trainer", "sharded_fused",
                          budget={"all-reduce": 2}),)

    def test_overrun_fires(self):
        cells = [("clustered", "trainer", "sharded_fused",
                  (("all-reduce", 3), ("all-gather", 0)))]
        findings = match_cells(cells, self.MANIFEST)
        assert _ids(findings) == ["budget-collective"]
        assert "exceeds budget 2" in findings[0].message

    def test_within_budget_silent(self):
        cells = [("clustered", "trainer", "sharded_fused",
                  (("all-reduce", 2), ("all-gather", 0)))]
        assert match_cells(cells, self.MANIFEST) == []

    def test_unbudgeted_op_defaults_to_zero(self):
        cells = [("clustered", "trainer", "sharded_fused",
                  (("all-reduce", 1), ("all-gather", 1)))]
        findings = match_cells(cells, self.MANIFEST)
        assert findings and "all-gather" in findings[0].message

    def test_missing_row_fires(self):
        cells = [("local", "producer", "capture_scan", (("all-reduce", 0),))]
        findings = match_cells(cells, self.MANIFEST)
        assert any("no manifest row" in f.message for f in findings)

    def test_stale_row_fires(self):
        findings = match_cells([], self.MANIFEST)
        assert any("not exercised" in f.message for f in findings)


# -- the real tree ----------------------------------------------------------

def test_tree_passes_clean():
    """The AST phases run clean over src/repro and tools — the acceptance
    bar `python tools/run_static_analysis.py` enforces in CI (the compiled
    budget phase is exercised by the grid itself and in CI)."""
    assert lint_tree(REPO) == []


def test_real_server_verbs_are_declared():
    """The live parity contract: every op_count verb on the real
    StoreServer is declared in the real plan.py."""
    from lint.rules_parity import extract_bump_verbs, \
        extract_plan_declarations
    verbs = extract_bump_verbs(
        (REPO / "src/repro/core/server.py").read_text())
    causes, unplanned, _ = extract_plan_declarations(
        (REPO / "src/repro/insitu/plan.py").read_text())
    assert verbs
    assert verbs == set(causes) | set(unplanned)


# -- LockTracker runtime witness --------------------------------------------

class TestLockTracker:
    def test_synthetic_cycle_detected(self):
        from repro.core.locktrack import LockCycleError, LockTracker
        tracker = LockTracker()
        tracker.note_acquire("A")
        tracker.note_acquire("B")
        tracker.note_release("B")
        tracker.note_release("A")
        tracker.assert_acyclic()    # A -> B alone is fine
        tracker.note_acquire("B")
        tracker.note_acquire("A")   # inversion: completes the cycle
        tracker.note_release("A")
        tracker.note_release("B")
        with pytest.raises(LockCycleError, match="A -> B|B -> A"):
            tracker.assert_acyclic()

    def test_live_server_graph_is_acyclic(self):
        """Drive a real StoreServer (verbs, metadata Condition, the
        two-lock serving drain in both argument orders, a recovery
        replay) under the witness: the realised graph must be acyclic
        and must contain the canonical table->ops edge."""
        import jax.numpy as jnp

        from repro.core import TableSpec
        from repro.core import store as S
        from repro.core.faults import FaultPlan
        from repro.core.locktrack import LockTracker
        from repro.core.server import StoreServer

        with LockTracker.instrument() as tracker:
            srv = StoreServer(faults=FaultPlan())
            srv.create_table(TableSpec("a", shape=(2,), capacity=8,
                                       engine="hash"))
            srv.create_table(TableSpec("b", shape=(2,), capacity=8,
                                       engine="hash"))
            srv.put("a", S.name_key("x"), jnp.ones((2,)))
            srv.get("a", S.name_key("x"))
            srv.put_meta("ready", 1)
            assert srv.get_meta("ready") == 1
            apply_fn = lambda p, x: x  # noqa: E731
            keys = jnp.asarray([S.name_key("x")], S.KEY_DTYPE)
            mask = jnp.asarray([True])
            # both argument orders must realise the SAME lock order
            srv.serve_batch("a", "b", keys, mask, apply_fn, None)
            srv.serve_batch("b", "a", keys, mask, apply_fn, None)
            srv._restart_and_recover()    # replay bumps under table lock
        tracker.assert_acyclic()
        edges = tracker.edges()
        assert any(k.startswith("table:") and "server._ops_lock" in v
                   for k, v in edges.items()), edges
        # canonical two-lock order: a before b, never b before a
        assert "table:b" in edges.get("table:a", ())
        assert "table:a" not in edges.get("table:b", ())

    def test_instrument_restores_init(self):
        from repro.core.locktrack import LockTracker
        from repro.core.server import StoreServer
        orig = StoreServer.__init__
        with LockTracker.instrument():
            assert StoreServer.__init__ is not orig
        assert StoreServer.__init__ is orig


# -- regression tests for the real findings fixed in this PR ----------------

class TestFixedFindings:
    def test_delete_is_wal_logged_and_replayed(self):
        """The unlogged-delete recovery bug: a restart used to replay
        the put log but skip tombstones, resurrecting deleted keys."""
        import jax.numpy as jnp

        from repro.core import TableSpec
        from repro.core import store as S
        from repro.core.faults import FaultPlan
        from repro.core.server import StoreServer

        srv = StoreServer(faults=FaultPlan())    # arms the WAL
        srv.create_table(TableSpec("t", shape=(2,), capacity=8,
                                   engine="hash"))
        srv.put("t", S.name_key("keep"), jnp.ones((2,)))
        srv.put("t", S.name_key("dead"), 2 * jnp.ones((2,)))
        srv.delete("t", S.name_key("dead"))
        assert not srv.poll("t", S.name_key("dead"))
        srv._restart_and_recover()
        assert srv.poll("t", S.name_key("keep"))
        assert not srv.poll("t", S.name_key("dead")), \
            "restart resurrected a deleted key: delete was not replayed"
        value, found = srv.get("t", S.name_key("keep"))
        assert bool(found)
        assert jnp.allclose(value, jnp.ones((2,)))

    def test_snapshot_truncates_replay_floor(self):
        """Recovery-snapshot bookkeeping (now published under _lock):
        the floor must equal the WAL length at snapshot time, so
        pre-snapshot commits never replay twice."""
        import jax.numpy as jnp

        from repro.core import TableSpec
        from repro.core import store as S
        from repro.core.faults import FaultPlan
        from repro.core.server import StoreServer

        srv = StoreServer(faults=FaultPlan())
        srv.create_table(TableSpec("t", shape=(2,), capacity=8,
                                   engine="hash"))
        srv.put("t", S.name_key("a"), jnp.ones((2,)))
        srv._take_recovery_snapshot()
        assert srv._wal_base["t"] == len(srv._wal["t"]) == 1
        srv.put("t", S.name_key("b"), 2 * jnp.ones((2,)))
        before = srv.op_count
        srv._restart_and_recover()
        # exactly ONE entry (the post-snapshot put) replayed
        assert srv.op_count == before + 1
        for name in ("a", "b"):
            assert srv.poll("t", S.name_key(name))
