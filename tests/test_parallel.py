"""Distribution machinery: sharding rules, pipeline parallelism (multi-
device via subprocess), gradient compression, co-located zero-collective
proof, clustered transfer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import axis_types_kw
from repro.parallel import sharding as shd
from repro.parallel.compress import (ErrorFeedback, dequantize_int8,
                                     quantize_int8)

from conftest import run_subprocess


class TestShardingRules:
    def test_spec_for_filters_missing_axes(self):
        mesh = jax.make_mesh((1,), ("data",), **axis_types_kw(1))
        spec = shd.spec_for(("batch", "heads"), mesh)
        assert tuple(spec) == ("data", None)       # no pod/model in mesh

    def test_no_axis_reuse(self):
        mesh = jax.make_mesh((1,), ("data",), **axis_types_kw(1))
        spec = shd.spec_for(("batch", "embed"), mesh)   # both want "data"
        used = [s for s in tuple(spec) if s is not None]
        assert len(used) == len(set(used)) <= 1

    def test_fitted_sharding_keeps_divisible(self):
        mesh = jax.make_mesh((1,), ("model",), **axis_types_kw(1))
        sh = shd.fitted_sharding(mesh, (7,), ("vocab",))
        assert tuple(sh.spec) == ("model",)     # 7 % 1 == 0
        # non-divisible drop is exercised at 16-way in the dry-run tests

    def test_param_spec_init(self):
        spec = {"w": shd.ParamSpec((4, 8), ("embed", "mlp")),
                "b": shd.ParamSpec((8,), (None,), "zeros")}
        params = shd.init_params(jax.random.key(0), spec, jnp.float32)
        assert params["w"].shape == (4, 8)
        assert float(jnp.abs(params["b"]).sum()) == 0.0

    def test_shard_noop_without_mesh(self):
        x = jnp.ones((4, 4))
        assert shd.shard(x, "batch", None) is x


@pytest.mark.slow
def test_pipeline_matches_sequential():
    """2-stage GPipe over ppermute == plain sequential stack (fwd + grads)."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import axis_types_kw
        from repro.parallel.pipeline import pipeline_forward, split_stages
        mesh = jax.make_mesh((2,), ("pod",), **axis_types_kw(1))
        P_layers, D, M, mb = 4, 8, 4, 2
        key = jax.random.key(0)
        w = jax.random.normal(key, (P_layers, D, D)) * (0.5 / D**0.5)

        def layer(wi, x):
            return x + jnp.tanh(x @ wi)

        def stage_fn(w_stage, x):       # w_stage [P/2, D, D]
            def body(x, wi):
                return layer(wi, x), None
            x, _ = jax.lax.scan(body, x, w_stage)
            return x

        x = jax.random.normal(jax.random.key(1), (M, mb, D))
        # sequential reference
        ref = x
        def body(c, wi):
            return layer(wi, c), None
        ref, _ = jax.lax.scan(body, x.reshape(M*mb, D), w)
        ref = ref.reshape(M, mb, D)

        staged = split_stages(w, 2)
        out = pipeline_forward(stage_fn, staged, x, mesh, stage_axis="pod")
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)

        # grads flow through the pipeline
        def loss_pipe(w_staged):
            return jnp.sum(pipeline_forward(stage_fn, w_staged, x, mesh,
                                            stage_axis="pod") ** 2)
        def loss_ref(w_):
            h, _ = jax.lax.scan(body, x.reshape(M*mb, D), w_)
            return jnp.sum(h ** 2)
        g_pipe = jax.grad(loss_pipe)(staged).reshape(w.shape)
        g_ref = jax.grad(loss_ref)(w)
        np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                                   atol=2e-4)
        print("PIPELINE_OK")
    """, n_devices=2)


@pytest.mark.slow
def test_colocated_put_has_zero_collectives():
    """THE paper claim, structurally: a co-located (sharding-aligned) store
    put compiles to zero collective ops; a clustered (misaligned) staging
    transfer does not."""
    run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import store as S
        from repro.core.store import TableSpec
        from repro.analysis.hlo import collective_bytes, count_ops
        from repro.launch.mesh import axis_types_kw
        mesh = jax.make_mesh((8,), ("data",), **axis_types_kw(1))
        spec = TableSpec("f", shape=(64, 128), capacity=4, engine="ring")
        slab_sh = NamedSharding(mesh, P(None, "data", None))
        elem_sh = NamedSharding(mesh, P("data", None))
        state = S.init_table(spec, slab_sh)
        val = jax.ShapeDtypeStruct((64, 128), jnp.float32, sharding=elem_sh)
        key = jax.ShapeDtypeStruct((), jnp.uint32)
        st_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=a.sharding),
            state)
        lowered = jax.jit(lambda st, k, v: S.put(spec, st, k, v),
                          donate_argnums=0).lower(st_abs, key, val)
        txt = lowered.compile().as_text()
        cb = collective_bytes(txt)
        assert cb.get("total", 0) == 0, f"co-located put has collectives: {cb}"

        # clustered: element resharded from data-sharded to replicated
        # (the dedicated-DB hop) — must show collective traffic
        lowered2 = jax.jit(lambda v: v,
                           out_shardings=NamedSharding(mesh, P())
                           ).lower(val)
        cb2 = collective_bytes(lowered2.compile().as_text())
        assert cb2.get("total", 0) > 0, f"clustered stage shows none: {cb2}"
        print("ZERO_COLLECTIVE_OK", cb, cb2)
    """, n_devices=8)


@pytest.mark.slow
def test_colocated_fused_put_path_collective_free():
    """Extends the zero-collective proof to the FUSED tier: a whole
    ``capture_scan`` chunk (k solver steps + k ring puts in one dispatch)
    against a co-located slab-sharded table must also compile to zero
    collectives — fusing the producer must not introduce any resharding."""
    run_subprocess("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import store as S
        from repro.core.store import TableSpec
        from repro.analysis.hlo import assert_collective_free
        from repro.launch.mesh import axis_types_kw
        mesh = jax.make_mesh((8,), ("data",), **axis_types_kw(1))
        spec = TableSpec("f", shape=(64, 128), capacity=4, engine="ring")
        slab_sh = NamedSharding(mesh, P(None, "data", None))
        state = S.init_table(spec, slab_sh)
        elem_sh = NamedSharding(mesh, P("data", None))

        def step_fn(carry, t):
            # element dims carry the SAME sharding as the slab (co-located)
            snap = jax.lax.with_sharding_constraint(
                carry * (1.0 + t.astype(jnp.float32)), elem_sh)
            return carry, S.make_key(0, t), snap

        st_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                           sharding=a.sharding), state)
        carry = jax.ShapeDtypeStruct((64, 128), jnp.float32,
                                     sharding=elem_sh)
        lowered = jax.jit(
            lambda st, c: S.capture_scan_impl(spec, st, step_fn, c, 8, 2),
            donate_argnums=0).lower(st_abs, carry)
        assert_collective_free(lowered.compile().as_text(),
                               "co-located fused capture_scan")
        print("FUSED_ZERO_COLLECTIVE_OK")
    """, n_devices=8)


@pytest.mark.slow
def test_slab_sharded_epoch_no_table_allgather():
    """The slab-sharded data plane's structural claims, from compiled HLO:

    1. the slab-sharded epoch (tier ``slab_sharded``) contains NO
       all-gather — the table enters the shard_map pre-partitioned and the
       batch is reassembled by an explicit psum (all-reduce), so the
       collective moved from an implicit whole-slab gather to an explicit
       per-epoch batch sum;
    2. the *contrast*: the replicated-entry tier fed the same sharded
       table MUST all-gather the slab on entry — proving assertion 1 is
       not vacuous;
    3. the co-located fused put path (a whole capture_scan chunk) stays
       collective-free even when the slab it writes is slot-axis sharded.
    """
    run_subprocess("""
        import jax, jax.numpy as jnp
        from dataclasses import replace
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.analysis.hlo import assert_collective_free, count_ops
        from repro.core import store as S
        from repro.core.store import TableSpec
        from repro.ml import autoencoder as ae, trainer as tr
        from repro.parallel.sharding import data_mesh, slab_sharding
        from repro.sim import flatplate as fp
        from repro.train import optimizer as opt

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        spec = TableSpec("field", shape=(4, n), capacity=16, engine="ring")
        mesh = data_mesh(2)
        sh = slab_sharding(spec, mesh)
        st = S.init_table(spec, sh)

        aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16)
        levels = ae.coords_pyramid(aecfg, fp.grid_coords(fcfg))
        tx = opt.adam(1e-3)
        cfg_rep = tr.TrainerConfig(ae=aecfg, gather=6, batch_size=4,
                                   lr=1e-3, mesh=mesh)
        cfg_slab = replace(cfg_rep, slab_sharded=True)
        state0 = tr.init_state(cfg_rep, jax.random.key(0), tx)
        mu, sd = jnp.zeros((4,)), jnp.ones((4,))
        args = (st, state0, jax.random.key(7), mu, sd)

        # 1) slab-sharded entry: zero all-gather, DDP + gather all-reduces
        ep_slab = tr.EPOCH_BUILDERS["slab_sharded"](cfg_slab, levels, tx,
                                                    spec)
        c = count_ops(ep_slab.lower(*args).compile().as_text())
        assert c.get("all-gather", 0) == 0, c
        assert c.get("all-reduce", 0) >= 2, c

        # 2) contrast: replicated entry on the same sharded table
        #    all-gathers the slab
        ep_rep = tr.EPOCH_BUILDERS["sharded_fused"](cfg_rep, levels, tx,
                                                    spec)
        c2 = count_ops(ep_rep.lower(*args).compile().as_text())
        assert c2.get("all-gather", 0) > 0, c2

        # 3) the fused put path stays collective-free against the
        #    slot-axis-sharded slab
        def step_fn(carry, t):
            return carry, S.make_key(0, t), \\
                jnp.broadcast_to(t.astype(jnp.float32), (4, n))
        st_abs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                           sharding=a.sharding), st)
        lowered = jax.jit(
            lambda s, c: S.capture_scan_impl(spec, s, step_fn, c, 8, 2),
            donate_argnums=0).lower(st_abs, jnp.zeros(()))
        assert_collective_free(lowered.compile().as_text(),
                               "fused put into slot-sharded slab")
        print("SLAB_HLO_OK", c, c2)
    """, n_devices=2)


@pytest.mark.slow
def test_compressed_allreduce_matches_mean():
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import axis_types_kw
        from repro.parallel.compress import compressed_allreduce
        mesh = jax.make_mesh((4,), ("data",), **axis_types_kw(1))
        g = jax.random.normal(jax.random.key(0), (4, 33))   # 4 ranks
        out = compressed_allreduce({"w": g}, mesh, axis="data")["w"]
        ref = g.mean(0)
        err = float(jnp.max(jnp.abs(out - ref)))
        rel = err / float(jnp.max(jnp.abs(ref)))
        assert rel < 0.15, rel          # int8 wire: ~1% typical, 15% bound
        print("COMPRESS_ALLREDUCE_OK", rel)
    """, n_devices=4)


class TestCompression:
    def test_int8_roundtrip_error_bound(self):
        x = jax.random.normal(jax.random.key(0), (1000,))
        qt = quantize_int8(x, block=128)
        y = dequantize_int8(qt, x.shape)
        err = float(jnp.max(jnp.abs(x - y)))
        scale = float(jnp.max(jnp.abs(x)))
        assert err <= scale / 127.0 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        """Sum of compressed grads + final residual == sum of true grads."""
        ef = ErrorFeedback()
        true_sum = jnp.zeros(64)
        comp_sum = jnp.zeros(64)
        for i in range(20):
            g = {"w": jax.random.normal(jax.random.key(i), (64,)) * 0.01}
            true_sum = true_sum + g["w"]
            _, deq = ef.compress(g)
            comp_sum = comp_sum + deq["w"]
        total_err = float(jnp.max(jnp.abs(
            true_sum - comp_sum - ef.residual["w"])))
        assert total_err < 1e-4

    def test_compression_ratio(self):
        from repro.parallel.compress import compression_ratio
        x = jnp.zeros(4096)
        assert compression_ratio(x) > 3.5


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save sharded state on a (4,) mesh, restore onto a (2,) mesh —
    the survivor path after losing half the fleet."""
    run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.train import checkpoint as ck
        from repro.train.elastic import plan_mesh

        mesh4 = plan_mesh(4, model_degree=1)
        sh4 = NamedSharding(mesh4, P("data"))
        state = {"w": jax.device_put(jnp.arange(16.0), sh4),
                 "step": jnp.int32(5)}
        d = tempfile.mkdtemp()
        ck.save(d, 5, state)

        mesh2 = plan_mesh(2, model_degree=1)
        sh2 = NamedSharding(mesh2, P("data"))
        like = {"w": jax.device_put(jnp.zeros(16), sh2),
                "step": jnp.int32(0)}
        restored = ck.restore(d, like)
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.arange(16.0))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """, n_devices=4)
