"""Fused in-situ pipeline: per-table concurrency, cached watermark,
capture transactions, and the fused trainer epoch."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Client, StoreServer, TableSpec
from repro.core import store as S


def _val(x, shape=(3,)):
    return jnp.full(shape, float(x), jnp.float32)


def _two_table_server():
    srv = StoreServer()
    srv.create_table(TableSpec("a", shape=(3,), capacity=16, engine="ring"))
    srv.create_table(TableSpec("b", shape=(3,), capacity=16, engine="ring"))
    return srv


class TestPerTableLocks:
    def test_no_cross_table_contention(self):
        """A producer writing table 'a' must not block while a consumer
        holds table 'b' (the old global RLock serialized them)."""
        srv = _two_table_server()
        done = threading.Event()

        def writer():
            for i in range(10):
                srv.put("a", S.make_key(0, i), _val(i))
            done.set()

        with srv.table_lock("b"):       # consumer camps on table b
            t = threading.Thread(target=writer, daemon=True)
            t.start()
            assert done.wait(10.0), \
                "puts to table 'a' blocked by table 'b' lock"
            t.join(5.0)
        assert srv.watermark("a") == 10

    def test_watermark_lock_free_under_held_lock(self):
        """Watermark polling must not need any table lock (cached host
        counter) — it works even while the producer holds the lock."""
        srv = _two_table_server()
        srv.put("a", 1, _val(1))
        got = []

        def poller():
            got.append(srv.watermark("a"))
            got.append(srv.wait_watermark("a", 1, timeout=1.0))

        with srv.table_lock("a"):
            t = threading.Thread(target=poller, daemon=True)
            t.start()
            t.join(5.0)
        assert got == [1, True]

    def test_same_table_still_serialized(self):
        srv = _two_table_server()
        order = []

        def writer():
            srv.put("a", 99, _val(9))
            order.append("put")

        with srv.table_lock("a"):
            t = threading.Thread(target=writer, daemon=True)
            t.start()
            time.sleep(0.05)
            order.append("holder")
        t.join(5.0)
        assert order == ["holder", "put"]


class TestCachedWatermark:
    def test_matches_device_after_mixed_ops(self):
        srv = _two_table_server()
        srv.put("a", S.make_key(0, 0), _val(0))
        srv.put_many("a", S.make_key(jnp.arange(3), jnp.ones(3, jnp.int32)),
                     jnp.ones((3, 3)))
        srv.put_stream("a",
                       S.make_key(jnp.arange(2)[:, None].repeat(2, 1),
                                  jnp.arange(2)[None, :].repeat(2, 0) + 5),
                       jnp.ones((2, 2, 3)))
        srv.delete("a", S.make_key(0, 0))    # tombstone ≠ watermark change
        assert srv.watermark("a") == 8 == srv.watermark_device("a")

    def test_capture_commit_bumps_watermark(self):
        srv = _two_table_server()
        spec = srv.spec("a")

        def step_fn(c, t):
            return c, S.make_key(0, t), jnp.full((3,), t.astype(jnp.float32))

        with srv.capture("a") as txn:
            txn.state, _ = S.capture_scan(spec, txn.state, step_fn,
                                          jnp.zeros(()), 9, 3)
            txn.puts = S.capture_emit_count(9, 3)
        assert srv.watermark("a") == 3 == srv.watermark_device("a")

    def test_readonly_capture_leaves_state(self):
        srv = _two_table_server()
        srv.put("a", 5, _val(5))
        with srv.capture("a") as txn:
            vals, founds = S.get_many(spec := srv.spec("a"), txn.state,
                                      jnp.array([5], jnp.uint32))
        assert bool(np.asarray(founds)[0])
        assert srv.watermark("a") == 1

    def test_capture_error_without_assignment_leaves_table(self):
        srv = _two_table_server()
        srv.put("a", 5, _val(5))
        with pytest.raises(RuntimeError):
            with srv.capture("a") as txn:
                raise RuntimeError("failed before dispatching anything")
        v, found = srv.get("a", 5)
        assert bool(found) and srv.watermark("a") == 1

    def test_capture_error_after_assignment_still_commits(self):
        """Fused ops donate the checked-out state, so an assigned
        txn.state must commit even when the body then raises — rolling
        back would leave the table on deleted buffers."""
        srv = _two_table_server()
        srv.put("a", 5, _val(5))
        spec = srv.spec("a")
        with pytest.raises(RuntimeError):
            with srv.capture("a") as txn:
                txn.state = S.put(spec, txn.state, jnp.uint32(6), _val(6))
                txn.puts = 1
                raise RuntimeError("raised after a donating dispatch")
        v, found = srv.get("a", 6)
        assert bool(found) and srv.watermark("a") == 2
        # the donated pre-put state must not be live anywhere
        v5, found5 = srv.get("a", 5)
        assert bool(found5) and np.allclose(v5, 5.0)

    def test_restore_rederives_watermark(self):
        srv = _two_table_server()
        srv.put("a", 1, _val(1))
        snap = srv.snapshot()
        srv.put("a", 2, _val(2))
        assert srv.watermark("a") == 2
        srv.restore(snap)
        assert srv.watermark("a") == 1 == srv.watermark_device("a")


class TestBackoff:
    def test_wait_watermark_backoff_still_bounded(self):
        srv = _two_table_server()
        t0 = time.perf_counter()
        assert not srv.wait_watermark("a", 1, timeout=0.1, strict=False)
        assert time.perf_counter() - t0 < 1.0
        srv.put("a", 1, _val(0))
        assert srv.wait_watermark("a", 1, timeout=0.1)

    def test_wait_watermark_wakes_promptly(self):
        srv = _two_table_server()

        def late_put():
            time.sleep(0.05)
            srv.put("a", 1, _val(0))

        threading.Thread(target=late_put, daemon=True).start()
        t0 = time.perf_counter()
        assert srv.wait_watermark("a", 1, timeout=5.0)
        # exponential backoff is capped, so the wake lag stays small
        assert time.perf_counter() - t0 < 1.0

    def test_poll_tensor_backoff(self):
        srv = _two_table_server()
        client = Client(srv)

        def late_put():
            time.sleep(0.05)
            client.put_tensor("x", _val(1), table="a")

        threading.Thread(target=late_put, daemon=True).start()
        assert client.poll_tensor("x", table="a", timeout=5.0)
        assert not client.poll_tensor("missing", table="a", timeout=0.1,
                                      strict=False)


class TestFusedTrainer:
    def test_fused_epoch_one_dispatch_and_converges(self):
        from repro.ml import autoencoder as ae
        from repro.ml import trainer as tr
        from repro.sim import flatplate as fp

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        srv = StoreServer()
        srv.create_table(TableSpec("field", shape=(4, n), capacity=16,
                                   engine="ring"))
        client = Client(srv)
        key = jax.random.key(0)
        for i in range(10):
            client.send_step("field", i, fp.snapshot(fcfg, key, i))

        cfg = tr.TrainerConfig(
            ae=ae.AEConfig(n_points=n, mode="ref", latent=16, mlp_width=16),
            epochs=6, gather=6, batch_size=4, lr=1e-3, fused=True)
        ops_before = srv.op_count
        state, hist, levels, stats = tr.insitu_train(
            client, fp.grid_coords(fcfg), cfg)
        assert len(hist) == 6
        head = np.mean([h.train_loss for h in hist[:2]])
        tail = np.mean([h.train_loss for h in hist[-2:]])
        assert tail < head, (head, tail)
        # O(1) server dispatches per epoch: 1 capture each, plus the
        # norm-stats bootstrap sample and the fused-epoch warmup.
        assert srv.op_count - ops_before <= cfg.epochs + 2

    def test_fused_and_per_verb_agree_on_semantics(self):
        """Both tiers hold out one tensor, train on the rest, and report
        finite, decreasing-ish losses from the same store contents."""
        from repro.ml import autoencoder as ae
        from repro.ml import trainer as tr
        from repro.sim import flatplate as fp

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        histories = {}
        for fused in (True, False):
            srv = StoreServer()
            srv.create_table(TableSpec("field", shape=(4, n), capacity=16,
                                       engine="ring"))
            client = Client(srv)
            key = jax.random.key(0)
            for i in range(10):
                client.send_step("field", i, fp.snapshot(fcfg, key, i))
            cfg = tr.TrainerConfig(
                ae=ae.AEConfig(n_points=n, mode="ref", latent=16,
                               mlp_width=16),
                epochs=3, gather=6, batch_size=4, lr=1e-3, fused=fused)
            _, hist, _, _ = tr.insitu_train(client, fp.grid_coords(fcfg),
                                            cfg)
            histories[fused] = hist
        for hist in histories.values():
            assert len(hist) == 3
            assert all(np.isfinite(h.train_loss) and
                       np.isfinite(h.val_loss) for h in hist)
