"""Store access kernels: Pallas (interpret) ≡ ref parity + complexity.

The fused probe/sample/gather kernels must produce *bit-identical*
results in every mode, on both engines, and neither the kernels nor the
routed store ops may materialize an ``[n, capacity]`` intermediate
(asserted structurally on the jaxpr).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import store as S
from repro.core.store import TableSpec

MODES = ("ref", "interpret")


def _filled(engine: str, capacity: int = 12, n_put: int = 7, shape=(3,)):
    """Keys 1..n_put — distinct mod capacity, so both engines keep all."""
    spec = TableSpec("t", shape=shape, capacity=capacity, engine=engine)
    st = S.init_table(spec)
    for i in range(n_put):
        st = S.put(spec, st, jnp.uint32(i + 1), jnp.full(shape, 10.0 + i))
    return spec, st


@pytest.mark.parametrize("engine", ["hash", "ring"])
def test_get_many_parity_both_engines(engine):
    spec, st = _filled(engine)
    # present, absent and reserved keys, in mixed order
    q = jnp.concatenate([
        jnp.arange(1, 8, dtype=jnp.uint32),
        jnp.arange(100, 103, dtype=jnp.uint32),
        jnp.array([S.EMPTY_KEY], jnp.uint32),
    ])
    outs = {m: S.get_many(spec, st, q, m) for m in MODES}
    v_ref, f_ref = outs["ref"]
    v_int, f_int = outs["interpret"]
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_int))
    np.testing.assert_array_equal(np.asarray(f_ref), np.asarray(f_int))
    # semantics: the 7 present keys found with their values, rest absent
    assert np.asarray(f_ref).tolist() == [True] * 7 + [False] * 4
    np.testing.assert_allclose(np.asarray(v_ref)[:7, 0],
                               10.0 + np.arange(7))
    np.testing.assert_allclose(np.asarray(v_ref)[7:], 0.0)


@pytest.mark.parametrize("engine", ["hash", "ring"])
def test_get_many_after_delete_parity(engine):
    spec, st = _filled(engine)
    st = S.delete(spec, st, jnp.uint32(4))
    q = jnp.arange(1, 8, dtype=jnp.uint32)
    outs = {m: S.get_many(spec, st, q, m) for m in MODES}
    np.testing.assert_array_equal(np.asarray(outs["ref"][1]),
                                  np.asarray(outs["interpret"][1]))
    founds = np.asarray(outs["ref"][1])
    assert not founds[3] and founds.sum() == 6


def test_get_many_duplicate_key_lowest_slot():
    """Ring tables can hold one key in several slots; both paths must
    agree on the historical tie-break (lowest slot index)."""
    spec = TableSpec("t", shape=(2,), capacity=8, engine="ring")
    st = S.init_table(spec)
    k = S.make_key(0, 5)
    st = S.put(spec, st, k, jnp.array([1.0, 1.0]))     # slot 0
    st = S.put(spec, st, k, jnp.array([2.0, 2.0]))     # slot 1, same key
    for m in MODES:
        v, f = S.get_many(spec, st, jnp.array([k]), m)
        assert bool(np.asarray(f)[0])
        np.testing.assert_allclose(np.asarray(v)[0], [1.0, 1.0]), m


@pytest.mark.parametrize("engine", ["hash", "ring"])
def test_sample_parity_both_engines(engine):
    spec, st = _filled(engine)
    rng = jax.random.key(7)
    outs = {m: S.sample(spec, st, rng, 16, m) for m in MODES}
    v_ref, k_ref, ok_ref = outs["ref"]
    v_int, k_int, ok_int = outs["interpret"]
    np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v_int))
    np.testing.assert_array_equal(np.asarray(k_ref), np.asarray(k_int))
    assert bool(ok_ref) == bool(ok_int) is True
    # all sampled values come from live slots
    assert set(np.asarray(v_ref)[:, 0].tolist()) <= set(
        (10.0 + np.arange(7)).tolist())


def test_empty_key_reserved_consistently():
    """A slot holding the reserved EMPTY_KEY reads as absent through
    every lookup verb (get, poll and the batched probe agree)."""
    spec = TableSpec("t", shape=(2,), capacity=4, engine="ring")
    st = S.init_table(spec)
    st = S.put(spec, st, jnp.uint32(S.EMPTY_KEY), jnp.ones(2))
    _, found = S.get(spec, st, S.EMPTY_KEY)
    assert not bool(found)
    assert not bool(S.poll(spec, st, S.EMPTY_KEY))
    for m in MODES:
        _, founds = S.get_many(spec, st, jnp.array([S.EMPTY_KEY],
                                                   jnp.uint32), m)
        assert not bool(np.asarray(founds)[0])


@pytest.mark.parametrize("mode", MODES)
def test_sample_empty_table(mode):
    spec = TableSpec("t", shape=(3,), capacity=4, engine="ring")
    st = S.init_table(spec)
    vals, keys, ok = S.sample(spec, st, jax.random.key(0), 4, mode)
    assert not bool(ok)
    np.testing.assert_allclose(np.asarray(vals), 0.0)


# ---------------------------------------------------------------------------
# Sharded gather (the slab-sharded data plane's shard-local fetch)
# ---------------------------------------------------------------------------

class TestShardedGather:
    """``gather_rows_sharded``: each shard fetches only the slots it owns
    (zeros elsewhere); summing the shard results reassembles the global
    gather bit-exactly.  Parity across ref and interpret modes."""

    def _slab(self, capacity=16, shape=(3, 5)):
        return jax.random.normal(jax.random.key(0), (capacity, *shape))

    @pytest.mark.parametrize("mode", MODES)
    def test_shards_sum_to_global_gather(self, mode):
        from repro.kernels.store import ops as kops
        slab = self._slab()
        slots = jnp.array([0, 3, 7, 8, 11, 15, 2, 9, 8, 0], jnp.int32)
        full = kops.gather_rows(slab, slots, mode)
        for n_shards in (2, 4):
            cl = slab.shape[0] // n_shards
            parts = [kops.gather_rows_sharded(slab[i * cl:(i + 1) * cl],
                                              slots, i * cl, mode)
                     for i in range(n_shards)]
            np.testing.assert_array_equal(
                np.asarray(sum(parts)), np.asarray(full))
            # exactly one shard owns each row
            owned = sum((np.abs(np.asarray(p)).sum(axis=(1, 2)) > 0)
                        .astype(int) for p in parts)
            assert (owned <= 1).all()

    def test_ref_interpret_parity(self):
        from repro.kernels.store import ops as kops
        slab = self._slab(capacity=8)
        slots = jnp.array([7, 0, 3, 4, 5, 1], jnp.int32)
        for off in (0, 4):
            local = slab[off:off + 4]
            r = kops.gather_rows_sharded(local, slots, off, "ref")
            k = kops.gather_rows_sharded(local, slots, off, "interpret")
            np.testing.assert_array_equal(np.asarray(r), np.asarray(k))

    @pytest.mark.parametrize("mode", MODES)
    def test_traced_offset(self, mode):
        """The shard offset is a traced scalar inside shard_map
        (``axis_index * local_cap``); both paths must accept it."""
        from repro.kernels.store import ops as kops
        slab = self._slab(capacity=8)
        slots = jnp.array([1, 6, 3], jnp.int32)

        out = jax.jit(lambda off: kops.gather_rows_sharded(
            slab[4:], slots, off, mode))(jnp.int32(4))
        ref = kops.gather_rows_sharded(slab[4:], slots, 4, "ref")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("mode", MODES)
    def test_sample_sharded_psum_equals_sample(self, mode):
        """``store.sample_sharded_impl`` under a real 1-axis shard_map on
        the available devices must reproduce ``sample_impl`` bit-exactly
        (on 1 device the shard owns everything — the degenerate identity;
        multi-device equality is covered by the subprocess tests)."""
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel.sharding import data_mesh

        spec, st = _filled("ring")
        mesh = data_mesh(len(jax.devices()))
        rng = jax.random.key(11)
        want = S.sample_impl(spec, st, rng, 6, mode)

        body = partial(S.sample_sharded_impl, spec, n=6, axis="data",
                       mode=mode)
        got = jax.jit(shard_map(
            lambda state, k: body(state, k),
            mesh=mesh,
            in_specs=(S.TableState(slab=P("data"), keys=P(), version=P(),
                                   ptr=P(), count=P()), P()),
            out_specs=(P(), P(), P()), check_rep=False))(st, rng)
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Complexity: no [n, capacity] intermediate anywhere in the routed ops
# ---------------------------------------------------------------------------

def _all_eqn_shapes(jaxpr, acc):
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                acc.add(tuple(aval.shape))
        for p in eqn.params.values():
            for sub in (p if isinstance(p, (list, tuple)) else [p]):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    _all_eqn_shapes(inner, acc)
                elif hasattr(sub, "eqns"):
                    _all_eqn_shapes(sub, acc)
    return acc


@pytest.mark.parametrize("engine", ["hash", "ring"])
def test_no_quadratic_intermediates(engine):
    n, cap = 32, 512
    spec = TableSpec("t", shape=(4,), capacity=cap, engine=engine)
    st = S.init_table(spec)
    keys = S.make_key(jnp.zeros(n, jnp.int32), jnp.arange(n))

    shapes = _all_eqn_shapes(
        jax.make_jaxpr(lambda s, k: S.get_many_impl(spec, s, k))(st, keys)
        .jaxpr, set())
    shapes |= _all_eqn_shapes(
        jax.make_jaxpr(
            lambda s, r: S.sample_impl(spec, s, r, n))(st, jax.random.key(0))
        .jaxpr, set())

    bad = {sh for sh in shapes if (n, cap) == sh or (cap, n) == sh
           or (n in sh and cap in sh)}
    assert not bad, f"quadratic [n, capacity] intermediates found: {bad}"


# ---------------------------------------------------------------------------
# Fused producer/consumer ops
# ---------------------------------------------------------------------------

def test_capture_scan_equals_sequential_puts():
    spec = TableSpec("t", shape=(3,), capacity=8, engine="ring")

    def step_fn(carry, t):
        return carry + 1.0, S.make_key(0, t), \
            jnp.full((3,), t.astype(jnp.float32))

    a, carry = S.capture_scan(spec, S.init_table(spec), step_fn,
                              jnp.zeros(()), 7, 2)
    b = S.init_table(spec)
    for t in range(7):
        if t % 2 == 0:
            b = S.put(spec, b, S.make_key(0, t), jnp.full((3,), float(t)))
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), name)
    assert float(carry) == 7.0
    assert S.capture_emit_count(7, 2) == 4 == int(a.count)


def test_capture_scan_t0_offsets_chunks():
    """Chunked capture (traced t0) ≡ one long capture."""
    spec = TableSpec("t", shape=(2,), capacity=16, engine="ring")

    def step_fn(carry, t):
        return carry, S.make_key(1, t), jnp.full((2,), t.astype(jnp.float32))

    whole, _ = S.capture_scan(spec, S.init_table(spec), step_fn,
                              jnp.zeros(()), 12, 3)
    chunked = S.init_table(spec)
    for base in (0, 6):
        chunked, _ = S.capture_scan(spec, chunked, step_fn, jnp.zeros(()),
                                    6, 3, t0=base)
    for x, y, name in zip(whole, chunked, whole._fields):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), name)


def test_put_stream_folds_trajectory():
    spec = TableSpec("t", shape=(3,), capacity=16, engine="ring")
    t_steps, ranks = 4, 2
    keys = S.make_key(
        jnp.broadcast_to(jnp.arange(ranks)[None, :], (t_steps, ranks)),
        jnp.broadcast_to(jnp.arange(t_steps)[:, None], (t_steps, ranks)))
    vals = jnp.arange(t_steps * ranks, dtype=jnp.float32) \
        .reshape(t_steps, ranks, 1).repeat(3, -1)
    a = S.put_stream(spec, S.init_table(spec), keys, vals)
    b = S.init_table(spec)
    for t in range(t_steps):
        b = S.put_many(spec, b, keys[t], vals[t])
    for x, y, name in zip(a, b, a._fields):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y), name)
    assert int(a.count) == t_steps * ranks


def test_sample_and_step_fuses_gather_and_microstep():
    spec, st = _filled("ring")

    def micro(w, values):
        return w + jnp.sum(values), jnp.mean(values)

    w, aux, ok = S.sample_and_step(spec, st, jax.random.key(3), 4, micro,
                                   jnp.zeros(()))
    assert bool(ok)
    # reproduce with the unfused ops and the same rng
    vals, _, _ = S.sample(spec, st, jax.random.key(3), 4)
    np.testing.assert_allclose(float(w), float(jnp.sum(vals)), rtol=1e-6)
    np.testing.assert_allclose(float(aux), float(jnp.mean(vals)), rtol=1e-6)
