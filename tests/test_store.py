"""TensorStore semantics: unit + hypothesis property tests.

The property test drives the device store with random op sequences and
checks it against a pure-python dict model (the Redis semantics the paper
relies on): hash-engine put/get/poll/delete behave like a keyed map; the
ring engine holds exactly the last ``capacity`` writes; versions and the
watermark are monotone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core import store as S
from repro.core.server import StoreServer
from repro.core.store import TableSpec


def _spec(engine="hash", capacity=8, shape=(3,)):
    return TableSpec("t", shape=shape, capacity=capacity, engine=engine)


def _val(x, shape=(3,)):
    return jnp.full(shape, float(x), jnp.float32)


class TestHashEngine:
    def test_put_get_roundtrip(self):
        spec = _spec()
        st_ = S.init_table(spec)
        st_ = S.put(spec, st_, 42, _val(1.5))
        v, found = S.get(spec, st_, 42)
        assert bool(found) and np.allclose(v, 1.5)

    def test_get_missing(self):
        spec = _spec()
        st_ = S.init_table(spec)
        v, found = S.get(spec, st_, 7)
        assert not bool(found) and np.allclose(v, 0.0)

    def test_same_key_overwrites(self):
        spec = _spec()
        st_ = S.init_table(spec)
        st_ = S.put(spec, st_, 5, _val(1))
        st_ = S.put(spec, st_, 5, _val(2))
        v, found = S.get(spec, st_, 5)
        assert bool(found) and np.allclose(v, 2)
        assert int(S.valid_count(spec, st_)) == 1

    def test_delete(self):
        spec = _spec()
        st_ = S.init_table(spec)
        st_ = S.put(spec, st_, 5, _val(1))
        st_ = S.delete(spec, st_, 5)
        _, found = S.get(spec, st_, 5)
        assert not bool(found)

    def test_poll(self):
        spec = _spec()
        st_ = S.init_table(spec)
        assert not bool(S.poll(spec, st_, 9))
        st_ = S.put(spec, st_, 9, _val(0))
        assert bool(S.poll(spec, st_, 9))

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["put", "delete"]),
                              st.integers(0, 30),
                              st.floats(-5, 5, allow_nan=False)),
                    min_size=1, max_size=25))
    def test_matches_dict_model(self, ops):
        """Hash engine ≡ python dict (keys distinct mod capacity)."""
        cap = 64  # > key range so no collisions
        spec = _spec(capacity=cap)
        st_ = S.init_table(spec)
        model = {}
        for op, key, x in ops:
            if op == "put":
                st_ = S.put(spec, st_, key, _val(x))
                model[key] = x
            else:
                st_ = S.delete(spec, st_, key)
                model.pop(key, None)
        for key in range(31):
            v, found = S.get(spec, st_, key)
            assert bool(found) == (key in model)
            if key in model:
                assert np.allclose(v, model[key], atol=1e-6)
        assert int(S.valid_count(spec, st_)) == len(model)


class TestPutManyCollisions:
    """The docstring contract: batched slot collisions resolve
    last-writer-wins, exactly like the equivalent sequence of ``put``s."""

    def test_hash_distinct_mod_capacity_roundtrip(self):
        spec = _spec(capacity=8)
        st_ = S.init_table(spec)
        keys = jnp.array([1, 2, 3, 12], jnp.uint32)   # distinct mod 8
        st_ = S.put_many(spec, st_, keys, jnp.stack([_val(i) for i in range(4)]))
        for i, k in enumerate([1, 2, 3, 12]):
            v, found = S.get(spec, st_, k)
            assert bool(found) and np.allclose(v, i), k

    def test_hash_colliding_keys_match_sequential_puts(self):
        """keys 1 and 9 collide mod 8: the later key must win and the
        earlier key must read as absent — same as sequential puts."""
        spec = _spec(capacity=8)
        a = S.put_many(spec, S.init_table(spec),
                       jnp.array([1, 9], jnp.uint32),
                       jnp.stack([_val(1), _val(2)]))
        b = S.init_table(spec)
        b = S.put(spec, b, 1, _val(1))
        b = S.put(spec, b, 9, _val(2))
        for x, y, name in zip(a, b, a._fields):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name
        v, found = S.get(spec, a, 9)
        assert bool(found) and np.allclose(v, 2)
        _, found1 = S.get(spec, a, 1)
        assert not bool(found1)
        assert int(a.count) == 2          # collisions still bump the watermark

    def test_hash_same_key_twice_in_batch(self):
        spec = _spec(capacity=8)
        st_ = S.put_many(spec, S.init_table(spec),
                         jnp.array([7, 7], jnp.uint32),
                         jnp.stack([_val(1), _val(2)]))
        v, found = S.get(spec, st_, 7)
        assert bool(found) and np.allclose(v, 2)
        assert int(S.valid_count(spec, st_)) == 1

    def test_ring_batch_longer_than_capacity(self):
        """A ring batch wrapping the capacity keeps the *last* writes."""
        spec = _spec(engine="ring", capacity=4)
        n = 6
        keys = S.make_key(jnp.zeros(n, jnp.int32), jnp.arange(n))
        vals = jnp.arange(n, dtype=jnp.float32)[:, None].repeat(3, 1)
        a = S.put_many(spec, S.init_table(spec), keys, vals)
        b = S.init_table(spec)
        for i in range(n):
            b = S.put(spec, b, keys[i], vals[i])
        for x, y, name in zip(a, b, a._fields):
            assert np.array_equal(np.asarray(x), np.asarray(y)), name
        got = sorted(np.asarray(a.slab)[:, 0].tolist())
        assert got == [2.0, 3.0, 4.0, 5.0]


class TestDeleteSampleInteraction:
    @pytest.mark.parametrize("engine", ["hash", "ring"])
    def test_sample_excludes_tombstoned_slots(self, engine):
        cap = 8
        spec = _spec(engine=engine, capacity=cap)
        st_ = S.init_table(spec)
        keys = [1, 2, 3, 4, 5]
        for k in keys:
            st_ = S.put(spec, st_, k, _val(10 + k))
        st_ = S.delete(spec, st_, 2)
        st_ = S.delete(spec, st_, 4)
        vals, skeys, ok = S.sample(spec, st_, jax.random.key(0), 64)
        assert bool(ok)
        sampled = set(np.asarray(vals)[:, 0].tolist())
        assert sampled <= {11.0, 13.0, 15.0}, sampled
        assert not ({12.0, 14.0} & sampled)
        assert int(S.valid_count(spec, st_)) == 3

    def test_delete_all_then_sample_not_ok(self):
        spec = _spec(engine="ring", capacity=4)
        st_ = S.init_table(spec)
        st_ = S.put(spec, st_, 3, _val(1))
        st_ = S.delete(spec, st_, 3)
        vals, _, ok = S.sample(spec, st_, jax.random.key(1), 4)
        assert not bool(ok)
        assert np.allclose(vals, 0)


class TestRingEngine:
    def test_window_semantics(self):
        """Ring holds exactly the last ``capacity`` writes."""
        spec = _spec(engine="ring", capacity=4)
        st_ = S.init_table(spec)
        for i in range(7):
            st_ = S.put(spec, st_, S.make_key(0, i), _val(i))
        vals, keys, valid = S.latest(spec, st_, 4)
        assert np.all(np.asarray(valid))
        assert sorted(np.asarray(vals)[:, 0].tolist()) == [3, 4, 5, 6]

    def test_latest_order(self):
        spec = _spec(engine="ring", capacity=8)
        st_ = S.init_table(spec)
        for i in range(5):
            st_ = S.put(spec, st_, S.make_key(0, i), _val(i))
        vals, _, valid = S.latest(spec, st_, 3)
        assert np.asarray(vals)[:, 0].tolist() == [4, 3, 2]

    def test_put_many_equals_sequential(self):
        spec = _spec(engine="ring", capacity=8)
        a = S.init_table(spec)
        b = S.init_table(spec)
        keys = S.make_key(jnp.arange(5), jnp.zeros(5, jnp.int32))
        vals = jnp.arange(5, dtype=jnp.float32)[:, None].repeat(3, 1)
        a = S.put_many(spec, a, keys, vals)
        for i in range(5):
            b = S.put(spec, b, keys[i], vals[i])
        assert np.allclose(a.slab, b.slab)
        assert np.array_equal(np.asarray(a.keys), np.asarray(b.keys))
        assert int(a.count) == int(b.count)

    def test_watermark_monotone(self):
        spec = _spec(engine="ring", capacity=2)
        st_ = S.init_table(spec)
        last = 0
        for i in range(6):
            st_ = S.put(spec, st_, S.make_key(1, i), _val(i))
            assert int(st_.count) > last
            last = int(st_.count)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 30), st.integers(2, 8))
    def test_ring_holds_last_k(self, n_puts, cap):
        spec = _spec(engine="ring", capacity=cap)
        st_ = S.init_table(spec)
        for i in range(n_puts):
            st_ = S.put(spec, st_, S.make_key(0, i), _val(i))
        expect = list(range(max(0, n_puts - cap), n_puts))
        vals, _, valid = S.latest(spec, st_, cap)
        got = sorted(np.asarray(vals)[np.asarray(valid), 0].tolist())
        assert got == expect


class TestSample:
    def test_sample_only_valid(self):
        spec = _spec(engine="ring", capacity=8)
        st_ = S.init_table(spec)
        for i in range(3):
            st_ = S.put(spec, st_, S.make_key(0, i), _val(i + 10))
        vals, keys, ok = S.sample(spec, st_, jax.random.key(0), 16)
        assert bool(ok)
        assert set(np.asarray(vals)[:, 0].tolist()) <= {10.0, 11.0, 12.0}

    def test_sample_empty(self):
        spec = _spec(engine="ring", capacity=4)
        st_ = S.init_table(spec)
        vals, keys, ok = S.sample(spec, st_, jax.random.key(0), 4)
        assert not bool(ok)
        assert np.allclose(vals, 0)


class TestServer:
    def test_threadsafe_watermark(self):
        import threading
        srv = StoreServer()
        srv.create_table(_spec(engine="ring", capacity=64))

        def writer(rank):
            for i in range(10):
                srv.put("t", S.make_key(rank, i), _val(i))

        threads = [threading.Thread(target=writer, args=(r,))
                   for r in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert srv.watermark("t") == 40

    def test_wait_watermark_timeout(self):
        srv = StoreServer()
        srv.create_table(_spec())
        assert not srv.wait_watermark("t", 1, timeout=0.05,
                                      strict=False)
        srv.put("t", 1, _val(0))
        assert srv.wait_watermark("t", 1, timeout=0.05)

    def test_model_registry(self):
        srv = StoreServer()
        srv.set_model("double", lambda p, x: x * p["k"], {"k": 2.0})
        assert srv.has_model("double")
        y = srv.run_model("double", jnp.ones(3))
        assert np.allclose(y, 2.0)

    def test_snapshot_restore(self):
        srv = StoreServer()
        srv.create_table(_spec())
        srv.put("t", 1, _val(5))
        snap = srv.snapshot()
        srv.put("t", 1, _val(9))
        srv.restore(snap)
        v, found = srv.get("t", 1)
        assert bool(found) and np.allclose(v, 5)


def test_make_key_unique():
    ranks, steps = np.meshgrid(np.arange(32), np.arange(64))
    keys = np.asarray(S.make_key(jnp.asarray(ranks.ravel()),
                                 jnp.asarray(steps.ravel())))
    assert len(np.unique(keys)) == keys.size


def test_name_key_stable():
    assert S.name_key("x.3.120") == S.name_key("x.3.120")
    assert S.name_key("a") != S.name_key("b")


class TestChunkBucketing:
    """Tail-chunk bucketing: pad to the power-of-two bucket with no-op
    steps so each (table, bucket) compiles once (ROADMAP follow-up)."""

    def test_bucket_length(self):
        assert S.bucket_length(1) == 8
        assert S.bucket_length(8) == 8
        assert S.bucket_length(9) == 16
        assert S.bucket_length(16) == 16
        assert S.bucket_length(100) == 128
        with pytest.raises(ValueError):
            S.bucket_length(0)

    def test_bucketed_scan_equals_sequential_puts(self):
        """A bucketed tail must leave the table byte-identical to the
        unpadded sequential reference (no phantom puts, exact carry)."""
        from repro.core.client import Client
        spec = _spec(engine="ring", capacity=64)
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)

        def step_fn(c, t):
            val = jnp.full((3,), t.astype(jnp.float32))
            return c + 1.0, S.make_key(0, t), val

        carry = jnp.zeros(())
        total = 0
        for t0, k in [(0, 16), (16, 16), (32, 7)]:      # 7 = odd tail
            carry = client.capture_scan("t", step_fn, carry, k, 2, t0=t0,
                                        bucket=True)
            total += k
        assert float(carry) == total       # padded steps never ran
        got = srv.checkout("t")
        ref = S.init_table(spec)
        for t in range(0, 39, 2):
            ref = S.put(spec, ref, S.make_key(0, t),
                        _val(float(t)))
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert srv.watermark("t") == 20 == srv.watermark_device("t")

    def test_compile_cache_hits_across_tail_lengths(self):
        """Five distinct tail lengths inside one bucket range must compile
        at most two executables (the 8- and 16-buckets), where the
        unbucketed path compiles all five."""
        from repro.core.client import Client
        spec = TableSpec("bkt", shape=(3,), capacity=64, engine="ring")
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)

        def step_fn(c, t):
            return c, S.make_key(0, t), jnp.full((3,), t.astype(jnp.float32))

        c0 = S.capture_scan._cache_size()
        for t0, k in [(0, 5), (5, 7), (12, 9), (21, 12), (33, 6)]:
            client.capture_scan("bkt", step_fn, jnp.zeros(()), k, 1,
                                t0=t0, bucket=True)
        assert S.capture_scan._cache_size() - c0 <= 2

    def test_multi_rank_bucketed_scan(self):
        from repro.core.client import Client
        spec = TableSpec("mb", shape=(3,), capacity=64, engine="ring")
        srv = StoreServer()
        srv.create_table(spec)
        client = Client(srv)

        def step_fn(c, rank, t):
            return c, S.make_key(rank, t), jnp.full((3,),
                                                    t.astype(jnp.float32))

        client.capture_scan("mb", step_fn, jnp.zeros((3,)), 5, 1,
                            n_ranks=3, bucket=True)
        assert srv.watermark("mb") == 15 == srv.watermark_device("mb")


def _rank_t_val(rank, t):
    return jnp.stack([jnp.asarray(rank, jnp.float32),
                      jnp.asarray(t, jnp.float32),
                      jnp.asarray(rank, jnp.float32)
                      * jnp.asarray(t, jnp.float32)])


class TestCaptureTailEdgeCases:
    """Boundary conditions of the bucketing + fused-capture machinery:
    chunk lengths exactly at power-of-two bucket edges, chunks longer than
    the ring capacity, and multi-rank interleave with more ranks than
    slots — every case must stay byte-identical to the sequential
    per-verb replay."""

    def test_bucket_length_at_pow2_boundaries(self):
        # below / at / above each boundary, incl. the min_bucket floor
        for k, want in [(7, 8), (8, 8), (9, 16), (15, 16), (16, 16),
                        (17, 32), (31, 32), (32, 32), (33, 64)]:
            assert S.bucket_length(k) == want, k
        # the floor: short tails never compile a tiny one-off executable
        assert S.bucket_length(1) == 8
        assert S.bucket_length(1, min_bucket=2) == 2
        assert S.bucket_length(3, min_bucket=2) == 4
        assert S.bucket_length(5, min_bucket=16) == 16

    def test_bucketed_capture_at_exact_boundary_lengths(self):
        """A chunk landing exactly on its bucket (valid == padded length)
        and one past it must both replay like sequential puts."""
        from repro.core.client import Client
        for k in (8, 9, 16):
            spec = TableSpec("bd", shape=(3,), capacity=32, engine="ring")
            srv = StoreServer()
            srv.create_table(spec)
            client = Client(srv)

            def step_fn(c, t):
                return c + 1.0, S.make_key(0, t), _rank_t_val(0, t)

            carry = client.capture_scan("bd", step_fn, jnp.zeros(()), k, 1,
                                        bucket=True)
            assert float(carry) == k          # padding never advanced it
            ref = S.init_table(spec)
            for t in range(k):
                ref = S.put(spec, ref, S.make_key(0, t), _rank_t_val(0, t))
            for a, b in zip(srv.checkout("bd"), ref):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert srv.watermark("bd") == k == srv.watermark_device("bd")

    def test_chunk_longer_than_capacity_wraps_last_writer_wins(self):
        """One fused chunk writing 3x the ring capacity: wrap-around slot
        collisions must resolve exactly like the sequential replay (count
        still bumped per put, oldest rows overwritten)."""
        spec = TableSpec("wr", shape=(3,), capacity=8, engine="ring")
        n = 24

        def step_fn(c, t):
            return c, S.make_key(0, t), _rank_t_val(0, t)

        got, _ = S.capture_scan(spec, S.init_table(spec), step_fn,
                                jnp.zeros(()), n, 1)
        ref = S.init_table(spec)
        for t in range(n):
            ref = S.put(spec, ref, S.make_key(0, t), _rank_t_val(0, t))
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(got.count) == n            # collisions still count

    def test_more_ranks_than_capacity_interleaves_like_sequential(self):
        """R > capacity: each emitting step's rank-major put_many spills
        around the ring; the interleave must equal R sequential puts per
        step, step by step."""
        spec = TableSpec("rc", shape=(3,), capacity=4, engine="ring")
        ranks, length = 6, 3

        def step_fn(c, rank, t):
            return c, S.make_key(rank, t), _rank_t_val(rank, t)

        got, _ = S.capture_scan_multi(spec, S.init_table(spec), step_fn,
                                      jnp.zeros((ranks,)), length, ranks, 1)
        ref = S.init_table(spec)
        for t in range(length):
            for r in range(ranks):
                ref = S.put(spec, ref, S.make_key(r, t), _rank_t_val(r, t))
        for a, b in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert int(got.count) == ranks * length
