"""Shared pytest fixtures/utilities.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
single real CPU device; multi-device tests spawn subprocesses that set
``--xla_force_host_platform_device_count`` themselves (see ``run_subprocess``).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_subprocess(code: str, n_devices: int = 4, timeout: float = 420.0):
    """Run ``code`` in a fresh interpreter with N host platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(SRC) + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode}):\n"
            f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture(scope="session")
def rng_key():
    import jax
    return jax.random.key(0)


@pytest.fixture(autouse=True)
def _lock_order_witness(request):
    """Every ``@pytest.mark.chaos`` test runs under the LockTracker
    runtime witness: all StoreServer locks are wrapped, the realised
    lock-order graph is collected across threads, and the test fails if
    the graph is cyclic — the dynamic twin of repro-lint's lock rules."""
    if request.node.get_closest_marker("chaos") is None:
        yield
        return
    from repro.core.locktrack import LockTracker
    with LockTracker.instrument() as tracker:
        yield
    tracker.assert_acyclic()
