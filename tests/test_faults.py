"""The fault/recovery epoch: typed taxonomy, retry policy, exactly-once
chunk delivery, WAL replay after store restarts, snapshot/restore round
trips, straggler telemetry, and the orchestrator's prompt shutdown.

The chaos *grid* (random FaultPlans over the whole deployment grid with
bit-identical-to-baseline assertions) lives in ``test_plan_properties``;
this module pins each mechanism down in isolation first.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (Client, InSituDriver, StoreServer, StragglerPolicy,
                        TableSpec)
from repro.core import store as S
from repro.core.deployment import make_clustered_1d
from repro.core.faults import (FaultEvent, FaultPlan, InjectedCrash,
                               RetryPolicy, StoreError, StoreTimeout,
                               StoreUnavailable, TransferDropped,
                               WatermarkTimeout, call_with_retry)
from repro.insitu import InSituSession, Producer
from repro.parallel.sharding import data_mesh, slab_sharding

SPEC = TableSpec("t", shape=(3,), capacity=8, engine="ring")


def _server(*events, deployment=None, retry=None, table=True):
    plan = FaultPlan(events=tuple(events),
                     retry=retry or RetryPolicy(interval=1e-4,
                                                max_interval=1e-3))
    srv = StoreServer(deployment, faults=plan)
    if table:
        srv.create_table(SPEC)
    return srv


def _fill(client, n, start=0):
    for i in range(start, start + n):
        client.put_tensor(f"x{i}", jnp.full((3,), float(i)), table="t")
    return client


def _table_leaves(srv, table="t"):
    return [np.asarray(x) for x in jax.tree.leaves(srv.checkout(table))]


# ---------------------------------------------------------------------------
# Retry policy
# ---------------------------------------------------------------------------

class TestRetryPolicy:
    def test_sleeps_seeded_and_bounded(self):
        pol = RetryPolicy(max_attempts=6, interval=0.01, max_interval=0.04,
                          timeout=60.0, jitter=0.25, seed=3)
        a, b = list(pol.sleeps()), list(pol.sleeps())
        assert a == b                      # seeded jitter: deterministic
        assert len(a) == pol.max_attempts - 1
        expect = [0.01, 0.02, 0.04, 0.04, 0.04]   # doubling, capped
        for s, base in zip(a, expect):
            assert base <= s <= base * (1 + pol.jitter)

    def test_deadline_clamp(self):
        # an expired deadline yields no sleeps at all...
        assert list(RetryPolicy(timeout=0.0).sleeps()) == []
        # ...and a tiny budget clamps each sleep to the time remaining
        pol = RetryPolicy(max_attempts=10, interval=1.0, timeout=0.01,
                          jitter=0.0)
        for s in pol.sleeps():
            assert s <= 0.01

    def test_call_with_retry_counts_and_succeeds(self):
        calls, retries = [0], [0]

        def flaky():
            calls[0] += 1
            if calls[0] < 3:
                raise StoreUnavailable("transient")
            return "ok"

        pol = RetryPolicy(interval=1e-5, max_interval=1e-4)
        out = call_with_retry(flaky, pol, lambda: retries.__setitem__(
            0, retries[0] + 1))
        assert out == "ok" and calls[0] == 3 and retries[0] == 2

    def test_call_with_retry_exhausts_and_reraises(self):
        pol = RetryPolicy(max_attempts=3, interval=1e-5, max_interval=1e-4)
        calls = [0]

        def always():
            calls[0] += 1
            raise StoreUnavailable("down")

        with pytest.raises(StoreUnavailable):
            call_with_retry(always, pol)
        assert calls[0] == pol.max_attempts

    def test_non_transient_propagates_immediately(self):
        calls = [0]

        def boom():
            calls[0] += 1
            raise ValueError("not retryable")

        with pytest.raises(ValueError):
            call_with_retry(boom, RetryPolicy())
        assert calls[0] == 1


# ---------------------------------------------------------------------------
# Typed failure taxonomy
# ---------------------------------------------------------------------------

class TestTaxonomy:
    def test_hierarchy(self):
        assert issubclass(WatermarkTimeout, StoreTimeout)
        assert issubclass(StoreTimeout, StoreError)
        assert issubclass(TransferDropped, StoreUnavailable)
        assert issubclass(StoreError, RuntimeError)

    def test_wait_watermark_raises_typed(self):
        srv = StoreServer()
        srv.create_table(SPEC)
        with pytest.raises(WatermarkTimeout) as ei:
            srv.wait_watermark("t", 5, timeout=0.02)
        e = ei.value
        assert (e.table, e.minimum, e.watermark) == ("t", 5, 0)
        assert "wanted >= 5" in str(e)
        # the straggler-mitigation contract survives as strict=False
        assert srv.wait_watermark("t", 5, timeout=0.02,
                                  strict=False) is False

    def test_wait_meta_raises_typed(self):
        srv = StoreServer()
        with pytest.raises(StoreTimeout) as ei:
            srv.wait_meta("never", timeout=0.02)
        assert ei.value.name == "never"
        assert srv.wait_meta("never", timeout=0.02, strict=False) is None

    def test_poll_tensor_raises_typed(self):
        srv = StoreServer()
        srv.create_table(SPEC)
        client = Client(srv)
        with pytest.raises(StoreTimeout):
            client.poll_tensor("ghost", table="t", timeout=0.02)
        assert client.poll_tensor("ghost", table="t", timeout=0.02,
                                  strict=False) is False

    def test_error_type_reaches_component_result(self):
        driver = InSituDriver(tables=[SPEC])

        def consumer(client, stop):
            client.server.wait_watermark("t", 99, timeout=0.02)

        res = driver.run({"ml": consumer}, max_wall_s=30)
        assert res.components["ml"].error_type == "WatermarkTimeout"
        assert res.failed == "ml"

    def test_fault_event_validation(self):
        with pytest.raises(ValueError):
            FaultEvent("meteor")
        with pytest.raises(ValueError):
            FaultEvent("unavailable")              # needs a verb
        with pytest.raises(ValueError):
            FaultEvent("crash")                    # needs a component
        with pytest.raises(ValueError):
            FaultEvent("drop_chunk")               # needs a table

    def test_random_plan_deterministic(self):
        a = FaultPlan.random(7, n_events=4)
        b = FaultPlan.random(7, n_events=4)
        assert a == b and len(a.events) == 4
        assert FaultPlan.random(8, n_events=4) != a


# ---------------------------------------------------------------------------
# Transient unavailability absorbed by the client fault boundary
# ---------------------------------------------------------------------------

class TestUnavailableRetry:
    def test_put_retried_and_counted(self):
        srv = _server(FaultEvent("unavailable", verb="put", at=0, count=2))
        client = Client(srv)
        client.put_tensor("x", jnp.ones((3,)), table="t")
        v, found = client.get_tensor("x", table="t")
        assert bool(found)
        assert client.retries == 2
        st = srv.stats()
        assert st["retries"] == 2 and st["faults_injected"] == 2
        assert srv.watermark("t") == 1     # failed attempts dispatch nothing

    def test_retry_exhaustion_raises(self):
        srv = _server(
            FaultEvent("unavailable", verb="put", at=0, count=99),
            retry=RetryPolicy(max_attempts=3, interval=1e-5,
                              max_interval=1e-4))
        client = Client(srv)
        with pytest.raises(StoreUnavailable):
            client.put_tensor("x", jnp.ones((3,)), table="t")
        assert client.retries == 2
        assert srv.watermark("t") == 0

    def test_sample_window_absorbed(self):
        srv = _server(FaultEvent("unavailable", verb="sample", at=1))
        client = _fill(Client(srv), 4)
        k = jax.random.key(0)
        client.sample_batch("t", 2, k)              # attempt 0: clean
        vals, _, ok = client.sample_batch("t", 2, k)  # 1 fails, retried
        assert vals.shape == (2, 3) and client.retries == 1


# ---------------------------------------------------------------------------
# Exactly-once chunk delivery (ack set over a non-idempotent put)
# ---------------------------------------------------------------------------

class TestExactlyOnce:
    def _chunk(self, n=3, start=0):
        keys = jnp.arange(start, start + n).astype(S.KEY_DTYPE)
        vals = jnp.stack([jnp.full((3,), float(start + i))
                          for i in range(n)])
        return keys, vals, jnp.ones((n,), bool)

    def test_duplicate_chunk_id_is_deduplicated(self):
        srv = _server()
        keys, vals, mask = self._chunk()
        with srv.capture("t") as txn:
            srv.apply_chunk("t", (0, 0), txn, keys, vals, mask, puts=3)
        before = _table_leaves(srv)
        # the duplicate delivery: same chunk id — must be a no-op
        with srv.capture("t") as txn:
            srv.apply_chunk("t", (0, 0), txn, keys, vals, mask, puts=3)
        assert srv.watermark("t") == 3 == srv.watermark_device("t")
        for a, b in zip(before, _table_leaves(srv)):
            np.testing.assert_array_equal(a, b)

    def test_same_payload_new_id_applies(self):
        # put_masked is NOT idempotent: the same payload under a NEW chunk
        # id advances ptr/count again — which is why dedup must key on the
        # id, not the bytes.
        srv = _server()
        keys, vals, mask = self._chunk()
        for seq in range(2):
            with srv.capture("t") as txn:
                srv.apply_chunk("t", (0, seq), txn, keys, vals, mask,
                                puts=3)
        assert srv.watermark("t") == 6 == srv.watermark_device("t")

    def test_drop_and_dup_converge_to_baseline(self):
        """A dropped first transfer (client retries under the same id) and
        a duplicated later one leave the table byte-identical to the
        fault-free run."""
        def run(events):
            srv = _server(*events)
            client = Client(srv)
            carry = jnp.zeros(())

            def step(c, t):
                return c, S.make_key(0, t), jnp.full((3,), 1.0) * t

            for base in range(0, 6, 3):
                client.capture_scan("t", step, carry, 3, t0=base)
            return srv, client

        base_srv, _ = run(())
        srv, client = run((
            FaultEvent("drop_chunk", table="t", at=0),
            FaultEvent("dup_chunk", table="t", at=2),
        ))
        assert client.retries == 1
        assert srv.stats()["faults_injected"] == 2
        assert srv.watermark("t") == base_srv.watermark("t") == 6
        for a, b in zip(_table_leaves(base_srv), _table_leaves(srv)):
            np.testing.assert_array_equal(a, b)
        # local deployment: faults never fabricate cross-mesh traffic
        assert srv.stats()["staged_transfers"] == 0


# ---------------------------------------------------------------------------
# Store restart + WAL replay
# ---------------------------------------------------------------------------

class TestRestartRecovery:
    def test_restart_replays_wal_to_identical_state(self):
        base = _server()
        _fill(Client(base), 5)
        srv = _server(FaultEvent("restart", table="t", at=3))
        client = _fill(Client(srv), 5)
        assert srv.stats()["recoveries"] == 1
        assert srv.watermark("t") == 5 == srv.watermark_device("t")
        # replaying 3 WAL entries costs 3 extra real dispatches
        assert srv.op_count == base.op_count + 3
        for a, b in zip(_table_leaves(base), _table_leaves(srv)):
            np.testing.assert_array_equal(a, b)
        v, found = client.get_tensor("x0", table="t")
        assert bool(found)
        np.testing.assert_array_equal(np.asarray(v), np.zeros(3))

    def test_snapshot_truncates_replay_tail(self):
        base = _server()
        _fill(Client(base), 6)
        srv = _server(FaultEvent("snapshot", table="t", at=2),
                      FaultEvent("restart", table="t", at=5))
        _fill(Client(srv), 6)
        # only the 3 commits after the snapshot replay
        assert srv.op_count == base.op_count + 3
        assert srv.stats()["recoveries"] == 1
        for a, b in zip(_table_leaves(base), _table_leaves(srv)):
            np.testing.assert_array_equal(a, b)

    def test_snapshot_image_survives_two_restarts(self):
        base = _server()
        _fill(Client(base), 6)
        srv = _server(FaultEvent("snapshot", table="t", at=2),
                      FaultEvent("restart", table="t", at=4),
                      FaultEvent("restart", table="t", at=6))
        _fill(Client(srv), 6)
        assert srv.stats()["recoveries"] == 2
        for a, b in zip(_table_leaves(base), _table_leaves(srv)):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# snapshot() / restore() round trips (the in-RAM checkpoint surface)
# ---------------------------------------------------------------------------

class TestSnapshotRestore:
    def _roundtrip(self, srv):
        client = _fill(Client(srv), 3)
        snap = srv.snapshot()
        _fill(client, 3, start=3)
        assert srv.watermark("t") == 6
        srv.restore(snap)
        assert srv.watermark("t") == 3 == srv.watermark_device("t")
        v, found = client.get_tensor("x1", table="t")
        assert bool(found)
        np.testing.assert_array_equal(np.asarray(v), np.ones(3))
        _, found = client.get_tensor("x4", table="t")
        assert not bool(found)

    def test_default_placement(self):
        srv = StoreServer()
        srv.create_table(SPEC)
        self._roundtrip(srv)

    def test_slab_sharded_table(self):
        srv = StoreServer()
        sh = slab_sharding(SPEC, data_mesh(1))
        srv.create_table(SPEC, slab_sharding=sh)
        self._roundtrip(srv)
        # the restored slab still lives on the explicit placement
        assert srv.checkout("t").slab.sharding.spec == sh.spec

    def test_clustered_placed_table(self):
        srv = StoreServer(make_clustered_1d())
        srv.create_table(SPEC)
        self._roundtrip(srv)

    def test_model_registry_survives_restore(self):
        srv = StoreServer()
        srv.create_table(SPEC)
        srv.set_model("head", lambda p, x: x @ p["w"],
                      {"w": jnp.ones((3, 2))})
        snap = srv.snapshot()
        srv.restore(snap)
        assert srv.has_model("head")
        out = srv.run_model("head", jnp.arange(3.0))
        np.testing.assert_allclose(np.asarray(out), [3.0, 3.0])


# ---------------------------------------------------------------------------
# Injected component crashes + recovery loops
# ---------------------------------------------------------------------------

@pytest.mark.chaos
class TestCrashRecovery:
    def test_crash_fires_exactly_once(self):
        srv = _server(FaultEvent("crash", component="sim", at=2))
        client = Client(srv)
        client.fault_point("sim", 0)
        client.fault_point("sim", 1)
        with pytest.raises(InjectedCrash) as ei:
            client.fault_point("sim", 2)
        assert (ei.value.component, ei.value.at) == ("sim", 2)
        client.fault_point("sim", 2)       # the restarted rank passes

    def test_producer_crash_preserves_stream(self):
        def run(events):
            sess = InSituSession(
                tables=[SPEC],
                components=[Producer(
                    lambda c, r, t: (c, S.make_key(r, t),
                                     jnp.full((3,), 1.0) * t),
                    table="t", steps=6, carry=jnp.zeros(()), chunk=3)],
                faults=FaultPlan(events=tuple(events)))
            res = sess.run(sequential=True)
            assert res.ok, {k: v.error
                            for k, v in res.run.components.items()}
            return res

        base = run(())
        res = run((FaultEvent("crash", component="producer", at=1),))
        assert res.restarts == 1
        assert res.run.components["producer"].restarts == 1
        assert res.plan.components[0].restarts == 1
        assert res.server.watermark("t") == base.server.watermark("t") == 6
        assert res.op_delta("producer") == base.op_delta("producer")
        for a, b in zip(_table_leaves(base.server),
                        _table_leaves(res.server)):
            np.testing.assert_array_equal(a, b)

    def test_sharded_producer_crash_resumes_from_watermark(self):
        """Chaos cell for the element-sharded tier: a domain-decomposed
        producer (sim.distributed, halo-exchange solver) crashes mid-run
        and the restarted chunk loop resumes from the table watermark —
        the re-initialized carry replays the SAME sharded puts, so the
        final table is bit-identical to the fault-free run (halo state
        is a pure function of (initializer, step), never of the crash)."""
        from repro.parallel.sharding import space_mesh
        from repro.sim import distributed as fd

        cfg = fd.FDConfig(n=8, jacobi_iters=8)
        step_fn, s0, es = fd.make_producer(cfg, space_mesh(1))
        spec = TableSpec("field", shape=(2, cfg.n, cfg.n), capacity=16)

        def run(events):
            sess = InSituSession(
                tables=[spec],
                components=[Producer(step_fn, table="field", steps=12,
                                     chunk=4, carry=s0,
                                     elem_sharding=es)],
                faults=FaultPlan(events=tuple(events)))
            plan = sess.plan()
            assert plan.components[0].tier == "capture_scan_sharded"
            res = sess.run(plan=plan, sequential=True)
            assert res.ok, {k: v.error
                            for k, v in res.run.components.items()}
            return res

        base = run(())
        res = run((FaultEvent("crash", component="producer", at=2),))
        assert res.restarts == 1
        assert res.plan.components[0].restarts == 1
        assert res.server.watermark("field") \
            == base.server.watermark("field") == 12
        assert res.op_delta("producer") == base.op_delta("producer")
        for a, b in zip(_table_leaves(base.server, "field"),
                        _table_leaves(res.server, "field")):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Straggler policy surface
# ---------------------------------------------------------------------------

class TestStragglerPolicy:
    def _session(self, policy):
        return InSituSession(
            tables=[SPEC],
            components=[Producer(
                lambda c, r, t: (c, S.make_key(r, t), jnp.ones((3,))),
                table="t", steps=3, carry=jnp.zeros(()), tier="per_verb",
                warmup=False)],
            straggler=policy)

    def test_zero_deadline_flags_every_step(self):
        res = self._session(StragglerPolicy(max_step_s=0.0)).run(
            sequential=True)
        assert res.ok
        assert res.run.components["producer"].straggler_events == 3
        assert res.straggler_events == 3

    def test_default_deadline_flags_nothing(self):
        res = self._session(None).run(sequential=True)
        assert res.ok and res.straggler_events == 0


# ---------------------------------------------------------------------------
# Orchestrator prompt shutdown
# ---------------------------------------------------------------------------

class TestPromptShutdown:
    def test_sibling_drains_immediately(self):
        driver = InSituDriver(tables=[SPEC])

        def slow_producer(client, stop):
            done = 0
            for _ in range(1000):
                if stop.is_set():
                    break
                time.sleep(0.01)
                done += 1
            return done

        def failing_consumer(client, stop):
            raise ValueError("dead on arrival")

        t0 = time.perf_counter()
        res = driver.run({"sim": slow_producer, "ml": failing_consumer},
                         max_wall_s=120)
        wall = time.perf_counter() - t0
        assert res.failed == "ml"
        assert res.components["ml"].error_type == "ValueError"
        assert res.components["sim"].ok
        assert res.components["sim"].steps < 1000   # drained early
        assert wall < 60.0
