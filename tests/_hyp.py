"""Optional-hypothesis shim.

The property tests use hypothesis when it is installed (CI installs it);
without it, collection must still succeed and the property tests skip
cleanly instead of killing the whole tier-1 run with an ImportError.

Usage in test modules::

    from _hyp import given, settings, st
"""

try:
    from hypothesis import (HealthCheck, given, settings,  # noqa: F401
                            strategies as st)
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class HealthCheck:
        """Attribute sink: ``HealthCheck.too_slow`` etc. at decoration
        time must not raise when hypothesis is absent."""

        def __getattr__(self, name):
            return name
    HealthCheck = HealthCheck()

    def given(*_args, **_kwargs):
        def deco(fn):
            # Zero-arg stub: hypothesis-strategy params must not be seen by
            # pytest (it would treat them as fixtures).
            def stub(*a, **k):  # *a absorbs ``self`` on method tests
                pytest.skip("hypothesis not installed")
            stub.__name__ = fn.__name__
            stub.__doc__ = fn.__doc__
            return stub
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: strategy constructors are
        only evaluated at decoration time, so returning None is safe."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
