"""Docs stay truthful: the link/import checker must pass, and the
quickstart's entry points must exist (the CI docs job runs the same
checker standalone)."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_check_passes():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_docs
        assert check_docs.main() == 0
    finally:
        sys.path.remove(str(REPO / "tools"))


def test_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "architecture.md").exists()
    assert (REPO / "docs" / "benchmarks.md").exists()


def test_quickstart_entry_points_import():
    """The modules the README tells users to run must import."""
    import importlib
    for mod in ("repro.launch.insitu", "benchmarks.run"):
        importlib.import_module(mod)
