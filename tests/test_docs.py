"""Docs stay truthful: the link/import checker must pass, and the
quickstart's entry points must exist (the CI docs job runs the same
checker standalone)."""

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_check_passes():
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_docs
        assert check_docs.main() == 0
    finally:
        sys.path.remove(str(REPO / "tools"))


def test_docs_exist():
    assert (REPO / "README.md").exists()
    assert (REPO / "docs" / "architecture.md").exists()
    assert (REPO / "docs" / "benchmarks.md").exists()
    assert (REPO / "docs" / "static-analysis.md").exists()


EXPECTED_RULE_IDS = {
    "budget-collective", "lock-holds", "lock-leaf", "lock-mutation",
    "lock-order", "parity-fault", "parity-verb", "trace-host",
    "type-check",
}


def test_list_rules_output_is_stable_and_documented():
    """``run_static_analysis.py --list-rules`` is a public surface: its
    rule-id set is pinned here, and every id must appear in the rule
    catalogue (docs/static-analysis.md)."""
    import subprocess
    import sys as _sys
    proc = subprocess.run(
        [_sys.executable, str(REPO / "tools" / "run_static_analysis.py"),
         "--list-rules"],
        capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    lines = [ln for ln in proc.stdout.splitlines() if ln.strip()]
    ids = {ln.split()[0] for ln in lines}
    assert ids == EXPECTED_RULE_IDS, ids
    # ids are listed sorted, each with a one-line summary
    assert [ln.split()[0] for ln in lines] == sorted(ids)
    assert all(len(ln.split(None, 1)) == 2 for ln in lines)
    catalogue = (REPO / "docs" / "static-analysis.md").read_text()
    for rid in ids:
        assert f"`{rid}`" in catalogue, \
            f"rule {rid} missing from docs/static-analysis.md"


def test_quickstart_entry_points_import():
    """The modules the README tells users to run must import."""
    import importlib
    for mod in ("repro.launch.insitu", "benchmarks.run"):
        importlib.import_module(mod)
