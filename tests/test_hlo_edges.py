"""Edge cases of the HLO text parser (`repro.analysis.hlo`) that the
collective-budget phase of repro-lint leans on: tuple result shapes,
fp8 dtypes, ROOT-op lines, and scalar (empty-dim) shapes."""

from repro.analysis import hlo


class TestParseShapeBytes:
    def test_scalar_empty_dims(self):
        assert hlo.parse_shape_bytes("f32[]") == 4
        assert hlo.parse_shape_bytes("pred[]") == 1
        assert hlo.parse_shape_bytes("s64[]") == 8

    def test_fp8_dtypes(self):
        assert hlo.parse_shape_bytes("f8e4m3fn[8,2]") == 16
        assert hlo.parse_shape_bytes("f8e5m2[4]") == 4
        assert hlo.parse_shape_bytes("(f8e4m3fn[4], f8e5m2[4])") == 8

    def test_tuple_of_mixed_dtypes(self):
        assert hlo.parse_shape_bytes(
            "(bf16[2,4]{1,0}, f32[8]{0}, pred[])") == 16 + 32 + 1

    def test_unknown_dtype_contributes_zero(self):
        assert hlo.parse_shape_bytes("token[]") == 0
        assert hlo.parse_shape_bytes("(token[], f32[2])") == 8


class TestCountOps:
    def test_root_line_counted(self):
        txt = ("ENTRY %e {\n"
               "  ROOT %r = f32[4]{0} all-gather(%p), dimensions={0}\n"
               "}\n")
        assert hlo.count_ops(txt) == {"all-gather": 1}

    def test_tuple_result_counted(self):
        txt = ("%ar = (f32[4]{0}, f32[4]{0}) all-reduce(%a, %b), "
               "replica_groups={}\n")
        assert hlo.count_ops(txt) == {"all-reduce": 1}

    def test_op_suffix_forms(self):
        # dotted id, paren-immediate, and space-separated forms all match
        txt = ("%a = f32[4] all-reduce.5(%x)\n"
               "%b = f32[4] collective-permute(%y)\n"
               "%c = f32[4] reduce-scatter(%z), dimensions={0}\n")
        assert hlo.count_ops(txt) == {"all-reduce": 1,
                                      "collective-permute": 1,
                                      "reduce-scatter": 1}

    def test_mentions_in_metadata_not_counted(self):
        # an op name appearing outside the `= <shape> <op>` position
        # (e.g. in a fusion's metadata string) must not count
        txt = '%f = f32[4] fusion(%x), metadata={op_name="all-reduce"}\n'
        assert hlo.count_ops(txt) == {}

    def test_clean_module_empty(self):
        assert hlo.count_ops("%add = f32[4] add(%a, %b)") == {}


class TestCollectiveBytes:
    def test_tuple_all_reduce_bytes(self):
        txt = "%ar = (bf16[2,4]{1,0}, f32[8]{0}) all-reduce(%a, %b)\n"
        cb = hlo.collective_bytes(txt)
        assert cb["all-reduce"] == 16 + 32
        assert cb["total"] == 48
        # all-reduce rings move ~2x the result bytes per device
        assert cb["link_bytes"] == 96

    def test_scalar_root_all_gather(self):
        txt = "ROOT %r = f32[]{} all-gather(%p)\n"
        cb = hlo.collective_bytes(txt)
        assert cb["all-gather"] == 4
        assert cb["link_bytes"] == 4


class TestAssertCollectiveFree:
    def test_raises_naming_ops(self):
        txt = "%ar = f32[8]{0} all-reduce(%a)\n"
        try:
            hlo.assert_collective_free(txt, what="fused put")
        except AssertionError as e:
            assert "fused put" in str(e) and "all-reduce" in str(e)
        else:
            raise AssertionError("expected AssertionError")

    def test_passes_on_clean(self):
        hlo.assert_collective_free("%add = f32[4] add(%a, %b)")
