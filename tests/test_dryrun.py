"""Dry-run machinery: registry cells, HLO collective parsing, analytic
FLOPs sanity, roofline terms, and one real (small-arch) compile per step
kind via subprocess (512 placeholder devices)."""

import json

import pytest

from repro.analysis import hlo
from repro.analysis.flops import analyze
from repro.analysis.roofline import roofline
from repro.configs.registry import (ARCH_IDS, SHAPES, cell_applicable, cells,
                                    get_config)

from conftest import run_subprocess


class TestRegistry:
    def test_40_cells(self):
        cs = cells()
        assert len(cs) == 40
        ok = [c for c in cs if c[2]]
        assert len(ok) == 32            # 8 full-attn archs skip long_500k

    def test_long_context_applicability(self):
        assert cell_applicable(get_config("mamba2_1_3b"),
                               SHAPES["long_500k"])[0]
        assert cell_applicable(get_config("jamba_1_5_large_398b"),
                               SHAPES["long_500k"])[0]
        ok, reason = cell_applicable(get_config("starcoder2_7b"),
                                     SHAPES["long_500k"])
        assert not ok and "full-attention" in reason

    def test_aliases(self):
        assert get_config("llama4-scout-17b-a16e").name == \
            "llama4-scout-17b-a16e"
        assert get_config("phi4-mini-3.8b").vocab == 200064


class TestHLOParse:
    def test_shape_bytes(self):
        assert hlo.parse_shape_bytes("bf16[8,128]") == 8 * 128 * 2
        assert hlo.parse_shape_bytes("f32[16]{0}") == 64
        assert hlo.parse_shape_bytes("(bf16[2,2], f32[4])") == 8 + 16

    def test_collective_bytes(self):
        txt = """
  %all-reduce.5 = bf16[4096]{0} all-reduce(%x), replica_groups={}
  %ag = f32[8,16]{1,0} all-gather(%y), dimensions={0}
  %normal.op = f32[4]{0} add(%a, %b)
"""
        cb = hlo.collective_bytes(txt)
        assert cb["all-reduce"] == 8192
        assert cb["all-gather"] == 512
        assert cb["total"] == 8704
        # ring-traffic weighting: all-reduce counts 2x
        assert cb["link_bytes"] == 2 * 8192 + 512

    def test_no_false_positives(self):
        cb = hlo.collective_bytes("%add = f32[4] add(%a, %b)")
        assert cb["total"] == 0 and cb["link_bytes"] == 0


class TestAnalyticFlops:
    def test_train_flops_scale_with_params(self):
        small = analyze(get_config("starcoder2_3b"), SHAPES["train_4k"])
        big = analyze(get_config("starcoder2_7b"), SHAPES["train_4k"])
        assert big.model_flops > 2 * small.model_flops

    def test_model_flops_is_6nd(self):
        cfg = get_config("phi4_mini_3_8b")
        rep = analyze(cfg, SHAPES["train_4k"])
        tokens = 256 * 4096
        assert rep.model_flops == pytest.approx(
            6 * cfg.active_param_count() * tokens)

    def test_machine_ge_model_for_train(self):
        for arch in ("starcoder2_3b", "qwen3_moe_235b_a22b", "mamba2_1_3b"):
            rep = analyze(get_config(arch), SHAPES["train_4k"])
            assert rep.machine_flops > rep.model_flops * 0.5

    def test_decode_memory_dominated(self):
        cfg = get_config("starcoder2_7b")
        rep = analyze(cfg, SHAPES["decode_32k"])
        rt = roofline("a", "s", "m", 256, rep.machine_flops,
                      rep.model_flops, rep.hbm_bytes, 0.0)
        assert rt.bound == "memory"

    def test_moe_decode_reads_fewer_params(self):
        # at decode batch 128 × top-8 every expert is touched (=> full
        # param reads); at batch 1 only top_k of 128 experts are
        from repro.configs.registry import ShapeSpec
        cfg = get_config("qwen3_moe_235b_a22b")
        big = analyze(cfg, SHAPES["decode_32k"])
        assert big.param_bytes == pytest.approx(cfg.param_count() * 2,
                                                rel=0.01)
        small = analyze(cfg, ShapeSpec("d1", 1024, 1, "decode"))
        assert small.param_bytes < 0.2 * cfg.param_count() * 2


class TestRoofline:
    def test_terms_and_bound(self):
        rt = roofline("a", "s", "single", 256,
                      machine_flops=1e18, model_flops=6e17,
                      hbm_bytes=1e15, collective_bytes=1e10)
        assert rt.t_compute == pytest.approx(1e18 / (256 * 197e12))
        assert rt.t_memory == pytest.approx(1e15 / (256 * 819e9))
        # collective bytes are per-device (post-SPMD HLO): one chip's links
        assert rt.t_collective == pytest.approx(1e10 / (4 * 50e9))
        assert rt.bound == "compute"
        assert 0 < rt.roofline_fraction <= 1.0

    def test_memory_bound_fraction_uses_bytes(self):
        rt = roofline("a", "s", "single", 256,
                      machine_flops=1e12, model_flops=1e12,
                      hbm_bytes=1e15, collective_bytes=0.0,
                      useful_bytes=8e14)
        assert rt.bound == "memory"
        assert rt.roofline_fraction == pytest.approx(0.8)


@pytest.mark.slow
def test_dryrun_cell_compiles_256_and_512():
    """One real dry-run compile per mesh through the actual module."""
    out = run_subprocess("""
        from repro.launch.dryrun import run_cell
        for mesh in ("single", "multi"):
            res = run_cell("starcoder2_3b", "decode_32k", mesh,
                           correction=False)
            assert res["status"] == "ok", res.get("error")
            assert res["chips"] == (512 if mesh == "multi" else 256)
            assert res["roofline"]["bound"] in ("compute", "memory",
                                                "collective")
        print("DRYRUN_CELL_OK")
    """, n_devices=512, timeout=560)
    assert "DRYRUN_CELL_OK" in out


class TestCommModel:
    def test_ep_dominates_qwen3_train(self):
        from repro.analysis.comm import collective_model
        from repro.launch.steps import rules_for
        import dataclasses
        cfg = get_config("qwen3_moe_235b_a22b")
        shape = SHAPES["train_4k"]
        base = collective_model(cfg, shape, "single", rules_for(cfg, shape))
        assert base.breakdown["ep_all_to_all"] > base.breakdown["fsdp_gather"]
        noep = dataclasses.replace(cfg, moe_ep=False)
        opt = collective_model(noep, shape, "single", rules_for(noep, shape))
        assert "ep_all_to_all" not in opt.breakdown
        assert opt.per_device_bytes < 0.3 * base.per_device_bytes

    def test_2d_tp_kills_decode_gathers(self):
        from repro.analysis.comm import collective_model
        from repro.launch.steps import rules_for
        import dataclasses
        cfg = get_config("llama4_scout_17b_a16e")
        shape = SHAPES["decode_32k"]
        base = collective_model(cfg, shape, "single", rules_for(cfg, shape))
        assert base.breakdown["fsdp_gather"] > 0
        tp2d = dataclasses.replace(cfg, serve_2d_tp=True)
        opt = collective_model(tp2d, shape, "single", rules_for(tp2d, shape))
        assert opt.breakdown["fsdp_gather"] == 0
        assert opt.per_device_bytes < 0.05 * base.per_device_bytes

    def test_multi_pod_adds_pod_grad_allreduce(self):
        from repro.analysis.comm import collective_model
        from repro.launch.steps import rules_for
        cfg = get_config("starcoder2_3b")
        shape = SHAPES["train_4k"]
        single = collective_model(cfg, shape, "single", rules_for(cfg, shape))
        multi = collective_model(cfg, shape, "multi", rules_for(cfg, shape))
        assert "pod_grad_allreduce" in multi.breakdown
        assert "pod_grad_allreduce" not in single.breakdown


class TestPerfLevers:
    def test_flash_halves_attention_flops(self):
        import dataclasses
        cfg = get_config("starcoder2_7b")
        base = analyze(cfg, SHAPES["train_4k"])
        flash = analyze(dataclasses.replace(cfg, attn_impl="flash"),
                        SHAPES["train_4k"])
        assert flash.breakdown["attn_score"] == pytest.approx(
            base.breakdown["attn_score"] / 2)

    def test_int8_kv_halves_cache_bytes(self):
        import dataclasses
        cfg = get_config("llama4_scout_17b_a16e")
        base = analyze(cfg, SHAPES["decode_32k"])
        q = analyze(dataclasses.replace(cfg, kv_cache_quant=True),
                    SHAPES["decode_32k"])
        assert q.cache_bytes < 0.6 * base.cache_bytes

    def test_dots_remat_cuts_recompute(self):
        import dataclasses
        cfg = get_config("starcoder2_7b")
        base = analyze(cfg, SHAPES["train_4k"])
        dots = analyze(dataclasses.replace(cfg, remat_policy="dots"),
                       SHAPES["train_4k"])
        assert dots.machine_flops < 0.85 * base.machine_flops
