"""InSituSession / Plan: tier resolution as data, dispatch-prediction
parity against ``StoreServer.stats()``, bit-identical results across
tiers, HLO collective predictions, and the deployment scenario grid."""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

from repro.core import Clustered, TableSpec, split_devices
from repro.core import store as S
from repro.insitu import (InferenceConsumer, InSituSession, Producer,
                          TrainerConsumer)
from repro.insitu import plan as P
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.sim import flatplate as fp

FCFG = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
N = FCFG.n_points
COORDS = fp.grid_coords(FCFG)


def _step(carry, rank, t):
    return carry, S.make_key(rank, t), fp.snapshot(
        FCFG, jax.random.fold_in(jax.random.key(0), rank), t)


def _cfg(epochs=3, fused=True, **kw):
    return tr.TrainerConfig(
        ae=ae.AEConfig(n_points=N, mode="ref", latent=16, mlp_width=16),
        epochs=epochs, gather=6, batch_size=4, lr=1e-3, fused=fused, **kw)


def _session(p_tier=None, t_tier=None, ranks=1, steps=20, epochs=3,
             deployment=None, count=1, model_key=None, extra=()):
    carry = jnp.zeros(()) if ranks == 1 else jnp.zeros((ranks,))
    cfg = _cfg(epochs=epochs, fused=(t_tier != "per_verb"))
    return InSituSession(
        tables=[TableSpec("field", shape=(4, N), capacity=16,
                          engine="ring")],
        components=[
            Producer(_step, table="field", steps=steps, ranks=ranks,
                     carry=carry, emit_every=2, tier=p_tier),
            TrainerConsumer(cfg, COORDS, tier=t_tier, count=count,
                            model_key=model_key),
            *extra,
        ],
        deployment=deployment)


class TestPlanResolution:
    def test_default_tiers(self):
        plan = _session().plan()
        assert plan.component("producer").tier == "capture_scan"
        assert plan.component("trainer").tier == "fused"

    def test_multi_rank_picks_multi_capture(self):
        plan = _session(ranks=3).plan()
        assert plan.component("producer").tier == "capture_scan_multi"

    def test_untraceable_pins_per_verb(self):
        sess = InSituSession(
            tables=[TableSpec("field", shape=(4, N), capacity=16)],
            components=[Producer(_step, table="field", steps=4,
                                 traceable=False)])
        assert sess.plan().component("producer").tier == "per_verb"

    def test_unfused_cfg_pins_per_verb_trainer(self):
        cfg = _cfg(fused=False)
        sess = InSituSession(
            tables=[TableSpec("field", shape=(4, N), capacity=16)],
            components=[TrainerConsumer(cfg, COORDS)])
        assert sess.plan().component("trainer").tier == "per_verb"

    def test_forced_tier_validation(self):
        with pytest.raises(ValueError):
            P.producer_tier(Producer(_step, table="f", steps=4,
                                     tier="warp_drive"))
        with pytest.raises(ValueError):
            P.producer_tier(Producer(_step, table="f", steps=4, ranks=2,
                                     tier="capture_scan"))
        with pytest.raises(ValueError):
            P.producer_tier(Producer(_step, table="f", steps=4,
                                     traceable=False, tier="capture_scan"))
        with pytest.raises(ValueError):
            P.trainer_tier(_cfg(), "sharded_fused")   # no mesh
        with pytest.raises(ValueError):
            P.trainer_tier(_cfg(fused=False), "fused")

    def test_unknown_table_rejected(self):
        with pytest.raises(ValueError):
            InSituSession(
                tables=[TableSpec("field", shape=(4, N), capacity=16)],
                components=[Producer(_step, table="nope", steps=4)])

    def test_insitu_train_rejects_unknown_tier(self):
        from repro.core import Client, StoreServer
        srv = StoreServer()
        srv.create_table(TableSpec("field", shape=(4, N), capacity=16))
        with pytest.raises(ValueError):
            tr.insitu_train(Client(srv), COORDS, _cfg(), tier="warp")


class TestDispatchParity:
    """plan.explain() predictions == measured StoreServer.stats()."""

    @pytest.mark.parametrize("p_tier,t_tier", [
        ("per_verb", "per_verb"),
        ("capture_scan", "fused"),
    ])
    def test_predictions_match_measured(self, p_tier, t_tier):
        sess = _session(p_tier=p_tier, t_tier=t_tier)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=420)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        for entry in plan.components:
            assert res.op_delta(entry.name) == entry.store_dispatches, \
                (entry.name, entry.tier)
        assert res.server.stats()["op_count"] == plan.store_dispatches
        # the fused epoch invariant, from the explain() view
        ex = plan.explain()["components"]["trainer"]
        assert ex["dispatches_per_epoch"] == 1.0

    def test_three_step_inference_prediction(self):
        def feed(client, step):
            return jnp.zeros((1, 4))

        sess = InSituSession(
            tables=[TableSpec("field", shape=(4, N), capacity=16)],
            components=[
                InferenceConsumer("m", feed, steps=3, wait_meta=None,
                                  tier="three_step"),
            ])
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=120,
                       preload=lambda srv: srv.set_model(
                           "m", lambda p, x: x @ p["w"],
                           {"w": jnp.ones((4, 2))}))
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        entry = plan.component("inference")
        assert res.op_delta("inference") == entry.store_dispatches == 12


class TestTierParity:
    """The same declaration must produce bit-identical results across the
    per-verb and fused plans (the sharded tier is covered in the
    subprocess grid below at float-reduction tolerance)."""

    def test_per_verb_and_fused_bitwise_identical(self):
        outs, tables = {}, {}
        for p_tier, t_tier in [("per_verb", "per_verb"),
                               ("capture_scan", "fused")]:
            res = _session(p_tier=p_tier, t_tier=t_tier).run(
                sequential=True, max_wall_s=420)
            assert res.ok, \
                {k: v.error for k, v in res.run.components.items()}
            outs[t_tier] = res.output("trainer").state
            tables[t_tier] = res.server.checkout("field")
        # producer tables byte-identical (fused ring == per-verb ring)
        for a, b in zip(tables["per_verb"], tables["fused"]):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # final TrainState bitwise identical
        a, b = outs["per_verb"], outs["fused"]
        assert int(a.step) == int(b.step)
        for la, lb in zip(jax.tree.leaves(a.params),
                          jax.tree.leaves(b.params)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_multi_producer_fused_equals_per_verb(self):
        tables = {}
        for tier in ("per_verb", "capture_scan_multi"):
            sess = InSituSession(
                tables=[TableSpec("field", shape=(4, N), capacity=16)],
                components=[Producer(_step, table="field", steps=10,
                                     ranks=3, carry=jnp.zeros((3,)),
                                     emit_every=2, tier=tier)])
            res = sess.run(sequential=True, max_wall_s=240)
            assert res.ok, \
                {k: v.error for k, v in res.run.components.items()}
            tables[tier] = res.server.checkout("field")
            assert res.server.watermark("field") == 3 * 5
        for a, b in zip(*tables.values()):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestScenarioGrid:
    def test_clustered_deployment_runs(self):
        """Degenerate 1-device clustered deployment: same declaration,
        staged transfers counted and predicted, still correct."""
        client_devs, db_devs = split_devices()
        mk = lambda devs: jax.sharding.Mesh(np.asarray(devs), ("data",))
        dep = Clustered(client_mesh=mk(client_devs), db_mesh=mk(db_devs))
        sess = _session(deployment=dep, steps=12, epochs=2)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=420)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        out = res.output("trainer")
        assert len(out.history) == 2
        assert all(np.isfinite(h.train_loss) for h in out.history)
        # THE clustered fused claim: ONE staged transfer per chunk, and
        # the plan said so before the run
        stats = res.server.stats()
        assert stats["staged_transfers"] == plan.staged_transfers
        prod = plan.component("producer")
        # 1 hop per capture chunk; the overlap pipeline adds ONE drain
        # dispatch at capture end that inserts without re-staging
        assert res.staged_delta("producer") == prod.staged_transfers \
            == dict(prod.dispatches)["capture"]
        assert prod.store_dispatches == prod.staged_transfers + 1
        assert res.op_delta("producer") == prod.store_dispatches
        ex = plan.explain()
        assert ex["components"]["producer"]["staged_per_chunk"] == 1.0
        assert ex["fan_in"] == dep.fan_in

    def test_clustered_per_verb_stages_per_element(self):
        """The per-verb tier pays one hop per element — the contrast the
        fused tier's one-hop-per-chunk claim is measured against."""
        client_devs, db_devs = split_devices()
        mk = lambda devs: jax.sharding.Mesh(np.asarray(devs), ("data",))
        dep = Clustered(client_mesh=mk(client_devs), db_mesh=mk(db_devs))
        sess = _session(p_tier="per_verb", t_tier="per_verb",
                        deployment=dep, steps=12, epochs=2)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=420)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        prod = plan.component("producer")
        assert prod.staged_transfers == 6     # one per emitting step
        assert res.staged_delta("producer") == 6
        assert res.server.stats()["staged_transfers"] \
            == plan.staged_transfers

    def test_clustered_three_step_inference_staged(self):
        """The three-step protocol stages its two put legs per step
        (input in, prediction out) — predicted and measured."""
        def feed(client, step):
            return jnp.zeros((1, 4))

        client_devs, db_devs = split_devices()
        mk = lambda devs: jax.sharding.Mesh(np.asarray(devs), ("data",))
        dep = Clustered(client_mesh=mk(client_devs), db_mesh=mk(db_devs))
        sess = InSituSession(
            tables=[TableSpec("field", shape=(4, N), capacity=16)],
            components=[
                InferenceConsumer("m", feed, steps=3, wait_meta=None,
                                  tier="three_step"),
            ], deployment=dep)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=120,
                       preload=lambda srv: srv.set_model(
                           "m", lambda p, x: x @ p["w"],
                           {"w": jnp.ones((4, 2))}))
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        entry = plan.component("inference")
        assert entry.staged_transfers == 6        # 2 hops × 3 steps
        assert res.staged_delta("inference") == 6
        assert res.server.stats()["staged_transfers"] == 6

    def test_plan_hlo_clustered_collective_free_put(self):
        """plan(hlo=True) under the clustered deployment: the put path
        (collect + staged insert) compiles collective-free — the plan's
        former "no claim" hole is closed, and check_collectives verifies
        it instead of skipping."""
        client_devs, db_devs = split_devices()
        mk = lambda devs: jax.sharding.Mesh(np.asarray(devs), ("data",))
        dep = Clustered(client_mesh=mk(client_devs), db_mesh=mk(db_devs))
        plan = _session(deployment=dep, steps=8, epochs=2).plan(hlo=True)
        prod = plan.component("producer")
        assert prod.predicted_collectives is not None
        prod.check_collectives()
        assert all(n == 0 for _, n in prod.collectives), prod.collectives

    def test_concurrent_full_pipeline_with_inference(self):
        """Producer + trainer + inference coupled live (the paper §4
        workflow) through one declaration."""
        def feed(client, step):
            mu, sd = client.get_metadata("norm_stats")
            snap = fp.snapshot(FCFG, jax.random.key(0), 100 + step)
            return (snap.T[None] - mu) / sd

        sess = _session(steps=30, epochs=3, model_key="encoder",
                        extra=(InferenceConsumer("encoder", feed, steps=2),))
        res = sess.run(max_wall_s=420)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        assert res.output("producer").steps == 30
        assert res.output("trainer").steps == 3
        z = res.output("inference").last
        assert z.shape == (1, 16) and bool(jnp.isfinite(z).all())

    def test_plan_hlo_colocated_collective_free(self):
        """plan(hlo=True): the co-located fused producer put path and the
        single-device fused epoch must compile collective-free."""
        from repro.core.deployment import make_colocated_1d
        dep = make_colocated_1d(ndim=2)
        sess = _session(steps=8, epochs=2, deployment=dep)
        plan = sess.plan(hlo=True)
        for entry in plan.components:
            assert entry.collectives is not None
            assert all(n == 0 for _, n in entry.collectives), \
                (entry.name, entry.collectives)


@pytest.mark.slow
def test_slab_sharded_session_and_placement_predictions():
    """The slab-sharded tier through the SESSION path on a forced
    2-device host: the declaration resolves tier ``slab_sharded``, the
    table is placed pre-partitioned, dispatch attribution stays exact,
    ``plan(hlo=True)`` proves zero table all-gather — and the collective
    predictions are *placement-aware*: a replicated-entry mesh trainer
    reading a sharded-placed table (co-located ``capacity_axis``) is
    predicted to all-gather it, so ``check_collectives`` passes on both
    by-design configurations instead of false-alarming."""
    run_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import TableSpec
        from repro.core import store as S
        from repro.core.deployment import Colocated
        from repro.insitu import InSituSession, Producer, TrainerConsumer
        from repro.ml import autoencoder as ae, trainer as tr
        from repro.parallel.sharding import data_mesh
        from repro.sim import flatplate as fp

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        coords = fp.grid_coords(fcfg)
        # precomputed snapshots: pure indexing in-dispatch, so producer
        # bytes are placement-independent (see docs/architecture.md)
        snaps = jnp.stack([fp.snapshot(fcfg, jax.random.key(0), t)
                           for t in range(10)])

        def step(carry, rank, t):
            return carry, S.make_key(rank, t), snaps[t % 10]

        def build(slab, deployment=None):
            cfg = tr.TrainerConfig(
                ae=ae.AEConfig(n_points=n, mode="ref", latent=16,
                               mlp_width=16),
                epochs=2, gather=6, batch_size=4, lr=1e-3,
                mesh=data_mesh(2), slab_sharded=slab)
            return InSituSession(
                tables=[TableSpec("field", shape=(4, n), capacity=16,
                                  engine="ring")],
                components=[
                    Producer(step, table="field", steps=12,
                             carry=jnp.zeros(()), emit_every=2),
                    TrainerConsumer(cfg, coords),
                ], deployment=deployment)

        # --- slab-sharded session: tier, dispatches, no all-gather ------
        sess = build(True)
        plan = sess.plan(hlo=True)
        assert plan.component("trainer").tier == "slab_sharded"
        for entry in plan.components:
            entry.check_collectives()
        coll = dict(plan.component("trainer").collectives)
        assert coll["all-gather"] == 0 and coll["all-reduce"] > 0, coll
        res = sess.run(plan=plan, sequential=True, max_wall_s=380)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        for entry in plan.components:
            assert res.op_delta(entry.name) == entry.store_dispatches, \\
                (entry.name, entry.tier)

        # --- bit-identical to the replicated-entry tier -----------------
        res2 = build(False).run(sequential=True, max_wall_s=380)
        assert res2.ok
        for a, b in zip(
                jax.tree.leaves(res.output("trainer").state.params),
                jax.tree.leaves(res2.output("trainer").state.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # --- placement-aware prediction: replicated entry on a
        #     sharded-placed table MUST all-gather, and the plan says so -
        dep = Colocated(data_mesh(2), elem_spec=P(None, None),
                        capacity_axis="data")
        sess3 = build(False, deployment=dep)
        plan3 = sess3.plan(hlo=True)
        assert plan3.component("trainer").tier == "sharded_fused"
        pred = dict(plan3.component("trainer").predicted_collectives)
        assert pred["all-gather"] is True, pred
        for entry in plan3.components:
            entry.check_collectives()          # no false alarm
        coll3 = dict(plan3.component("trainer").collectives)
        assert coll3["all-gather"] > 0, coll3
        print("SLAB_SESSION_OK")
    """), n_devices=2, timeout=900.0)


@pytest.mark.slow
def test_clustered_session_real_split_mesh():
    """The first-class clustered scenario on a REAL 4-device split
    (2 clients + 2 db): the declaration resolves the
    ``slab_sharded_clustered`` tier, the slab lives slot-partitioned on
    the db devices only, ``plan(hlo=True)`` proves the whole put path
    collective-free and the read path all-gather-free (db-side gather
    psum + client-side DDP all-reduce present), dispatch AND staged
    predictions are exact, and the final ``TrainState`` matches the
    local fused tier."""
    run_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import TableSpec, make_clustered_1d
        from repro.core import store as S
        from repro.insitu import InSituSession, Producer, TrainerConsumer
        from repro.ml import autoencoder as ae, trainer as tr
        from repro.sim import flatplate as fp

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        coords = fp.grid_coords(fcfg)
        # precomputed snapshots: pure indexing in-dispatch, so producer
        # bytes are placement-independent (see docs/architecture.md)
        snaps = jnp.stack([fp.snapshot(fcfg, jax.random.key(0), t)
                           for t in range(10)])

        def step(carry, rank, t):
            return carry, S.make_key(rank, t), snaps[t % 10]

        aecfg = ae.AEConfig(n_points=n, mode="ref", latent=16,
                            mlp_width=16)

        def build(dep, mesh=None, slab=False):
            cfg = tr.TrainerConfig(ae=aecfg, epochs=2, gather=6,
                                   batch_size=4, lr=1e-3, mesh=mesh,
                                   slab_sharded=slab)
            return InSituSession(
                tables=[TableSpec("field", shape=(4, n), capacity=16,
                                  engine="ring")],
                components=[
                    Producer(step, table="field", steps=12, ranks=2,
                             carry=jnp.zeros((2,)), emit_every=2),
                    TrainerConsumer(cfg, coords),
                ], deployment=dep)

        dep = make_clustered_1d(db_fraction=0.5, slab_axis="data")
        assert dep.fan_in == 1
        sess = build(dep, mesh=dep.client_mesh, slab=True)
        plan = sess.plan(hlo=True)
        assert plan.component("trainer").tier == "slab_sharded_clustered"
        for entry in plan.components:
            entry.check_collectives()
        pcoll = dict(plan.component("producer").collectives)
        assert all(v == 0 for v in pcoll.values()), pcoll
        tcoll = dict(plan.component("trainer").collectives)
        assert tcoll["all-gather"] == 0 and tcoll["all-reduce"] > 0, tcoll

        res = sess.run(plan=plan, sequential=True, max_wall_s=600)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        stats = res.server.stats()
        assert stats["op_count"] == plan.store_dispatches
        assert stats["staged_transfers"] == plan.staged_transfers
        for entry in plan.components:
            assert res.op_delta(entry.name) == entry.store_dispatches
            assert res.staged_delta(entry.name) == entry.staged_transfers

        # the slab lives slot-partitioned on the 2 db devices ONLY
        slab = res.server.checkout("field").slab
        devs = {s.device.id for s in slab.addressable_shards}
        db_ids = {d.id for d in dep.db_mesh.devices.ravel()}
        assert devs == db_ids, (devs, db_ids)
        assert max(s.data.nbytes for s in slab.addressable_shards) \\
            == slab.nbytes // 2

        # numerics match the local fused tier (same rng stream)
        res2 = build(None).run(sequential=True, max_wall_s=600)
        assert res2.ok
        for a, b in zip(
                jax.tree.leaves(res.output("trainer").state.params),
                jax.tree.leaves(res2.output("trainer").state.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)
        print("CLUSTERED_SESSION_OK")
    """), n_devices=4, timeout=900.0)


@pytest.mark.slow
def test_sharded_grid_subprocess():
    """The same declaration on a forced 4-device host: sharded-fused
    single consumer parity with the fused tier, plan HLO all-reduce
    prediction, and multi-consumer disjoint-mesh training."""
    run_subprocess(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import TableSpec
        from repro.core import store as S
        from repro.insitu import InSituSession, Producer, TrainerConsumer
        from repro.ml import autoencoder as ae, trainer as tr
        from repro.parallel.sharding import data_mesh
        from repro.sim import flatplate as fp

        fcfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        n = fcfg.n_points
        coords = fp.grid_coords(fcfg)

        def step(carry, rank, t):
            return carry, S.make_key(rank, t), fp.snapshot(
                fcfg, jax.random.key(0), t)

        def build(mesh, count=1):
            cfg = tr.TrainerConfig(
                ae=ae.AEConfig(n_points=n, mode="ref", latent=16,
                               mlp_width=16),
                epochs=3, gather=6, batch_size=4, lr=1e-3, mesh=mesh)
            return InSituSession(
                tables=[TableSpec("field", shape=(4, n), capacity=16,
                                  engine="ring")],
                components=[
                    Producer(step, table="field", steps=20,
                             carry=jnp.zeros(()), emit_every=2),
                    TrainerConsumer(cfg, coords, count=count),
                ])

        # --- fused (mesh=None) vs sharded_fused (mesh=2): same stream --
        states = {}
        for mesh in (None, data_mesh(2)):
            sess = build(mesh)
            plan = sess.plan()
            tier = plan.component("trainer").tier
            res = sess.run(plan=plan, sequential=True, max_wall_s=380)
            assert res.ok, \\
                {k: v.error for k, v in res.run.components.items()}
            assert res.op_delta("trainer") == \\
                plan.component("trainer").store_dispatches
            states[tier] = res.output("trainer").state
        assert set(states) == {"fused", "sharded_fused"}
        for a, b in zip(jax.tree.leaves(states["fused"].params),
                        jax.tree.leaves(states["sharded_fused"].params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-5, atol=1e-6)

        # --- plan(hlo=True) predicts the DDP all-reduce ----------------
        sess = build(data_mesh(2))
        plan = sess.plan(hlo=True)
        coll = dict(plan.component("trainer").collectives)
        assert coll["all-reduce"] > 0, coll
        pcoll = dict(plan.component("producer").collectives)
        assert all(v == 0 for v in pcoll.values()), pcoll

        # --- multi-consumer: 2 replicas on disjoint 2-device slices ----
        sess = build(None, count=2)
        plan = sess.plan()
        names = [c.name for c in plan.components if c.kind == "trainer"]
        assert names == ["trainer0", "trainer1"]
        assert all(plan.component(nm).tier == "sharded_fused"
                   and plan.component(nm).mesh_devices == 2
                   for nm in names)
        res = sess.run(plan=plan, sequential=True, max_wall_s=380)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        for nm in names:
            out = res.output(nm)
            assert len(out.history) == 3
            assert all(np.isfinite(h.train_loss) for h in out.history)
            assert res.op_delta(nm) == plan.component(nm).store_dispatches
        # replicas trained on different seeds -> different params
        pa = jax.tree.leaves(res.output("trainer0").state.params)
        pb = jax.tree.leaves(res.output("trainer1").state.params)
        assert any(not np.array_equal(np.asarray(a), np.asarray(b))
                   for a, b in zip(pa, pb))
        print("SESSION_SHARDED_GRID_OK")
    """), n_devices=4, timeout=900.0)
