"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles.

Kernels execute under ``interpret=True`` on CPU (the TPU BlockSpec path run
in Python), asserted allclose against ``ref.py``.  Hypothesis drives random
shapes; fixed sweeps cover the MXU-aligned and the ragged/padded cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.quadconv import quadconv_contract, quadconv_contract_ref


def _rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape).astype(dtype) * 0.3


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("B,I,C,J,O", [
    (1, 16, 4, 8, 8),        # tiny
    (4, 96, 4, 48, 16),      # paper-ish channels
    (2, 128, 16, 128, 16),   # MXU-aligned K and N
    (3, 50, 3, 17, 5),       # ragged everything (exercises padding)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quadconv_kernel_sweep(B, I, C, J, O, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    f = _rand(ks[0], B, I, C, dtype=dtype)
    w = jax.random.uniform(ks[1], (I,)).astype(dtype)
    g = _rand(ks[2], J, I, O, C, dtype=dtype)
    ref = quadconv_contract_ref(f, w, g)
    out = quadconv_contract(f, w, g, "interpret", 8, 128, 128)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 6),
       st.integers(1, 24), st.integers(1, 8))
def test_quadconv_kernel_property(B, I, C, J, O):
    ks = jax.random.split(jax.random.key(B * 1000 + I), 3)
    f = _rand(ks[0], B, I, C)
    w = jax.random.uniform(ks[1], (I,))
    g = _rand(ks[2], J, I, O, C)
    ref = quadconv_contract_ref(f, w, g)
    out = quadconv_contract(f, w, g, "interpret", 8, 128, 128)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


def test_quadconv_kernel_grads_match_ref():
    ks = jax.random.split(jax.random.key(1), 3)
    f = _rand(ks[0], 2, 32, 4)
    w = jax.random.uniform(ks[1], (32,))
    g = _rand(ks[2], 16, 32, 8, 4)

    def loss(f, w, g, mode):
        return jnp.sum(quadconv_contract(f, w, g, mode) ** 2)

    g_ref = jax.grad(loss, argnums=(0, 1, 2))(f, w, g, "ref")
    g_int = jax.grad(loss, argnums=(0, 1, 2))(f, w, g, "interpret")
    for a, b in zip(g_ref, g_int):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_quadconv_linearity():
    """Contraction is linear in f: K(af1 + bf2) == aK(f1) + bK(f2)."""
    ks = jax.random.split(jax.random.key(2), 4)
    f1, f2 = _rand(ks[0], 2, 24, 4), _rand(ks[1], 2, 24, 4)
    w = jax.random.uniform(ks[2], (24,))
    g = _rand(ks[3], 12, 24, 8, 4)
    lhs = quadconv_contract(2.0 * f1 + 3.0 * f2, w, g, "interpret")
    rhs = 2.0 * quadconv_contract(f1, w, g, "interpret") \
        + 3.0 * quadconv_contract(f2, w, g, "interpret")
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-4)


# ---------------------------------------------------------------------------
# Flash attention kernel
# ---------------------------------------------------------------------------

from repro.kernels.attention import mha, mha_ref


@pytest.mark.parametrize("B,S,H,K,dh,causal", [
    (1, 128, 2, 2, 64, True),       # MHA
    (2, 256, 4, 2, 64, True),       # GQA 2:1
    (1, 128, 8, 2, 128, True),      # GQA 4:1, wide head
    (1, 128, 4, 4, 64, False),      # bidirectional (encoder)
    (1, 384, 2, 1, 64, True),       # MQA, 3 kv blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, K, dh, causal, dtype):
    ks = jax.random.split(jax.random.key(B * S + H), 3)
    q = (jax.random.normal(ks[0], (B, S, H, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, S, K, dh)) * 0.5).astype(dtype)
    v = (jax.random.normal(ks[2], (B, S, K, dh)) * 0.5).astype(dtype)
    ref = mha_ref(q, k, v, causal)
    out = mha(q, k, v, causal, "interpret")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_grads():
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64)) * 0.5
    k = jax.random.normal(ks[1], (1, 128, 2, 64)) * 0.5
    v = jax.random.normal(ks[2], (1, 128, 2, 64)) * 0.5
    g1 = jax.grad(lambda q_: jnp.sum(mha(q_, k, v, True, "interpret") ** 2))(q)
    g2 = jax.grad(lambda q_: jnp.sum(mha_ref(q_, k, v, True) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_flash_attention_long_context_numerics():
    """Streaming softmax stays exact over many KV blocks."""
    ks = jax.random.split(jax.random.key(11), 3)
    q = jax.random.normal(ks[0], (1, 512, 1, 64))
    k = jax.random.normal(ks[1], (1, 512, 1, 64))
    v = jax.random.normal(ks[2], (1, 512, 1, 64))
    ref = mha_ref(q, k, v, True)
    out = mha(q, k, v, True, "interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


# ---------------------------------------------------------------------------
# SSD intra-chunk kernel
# ---------------------------------------------------------------------------

from repro.kernels.ssd import ssd_scan
from repro.models.ssd import ssd_scan_ref


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (1, 16, 2, 4, 8, 8),
    (2, 32, 4, 8, 16, 8),
    (1, 64, 8, 16, 32, 16),     # multi head-block
    (2, 24, 6, 8, 16, 8),       # H not a multiple of default blk_h
])
def test_ssd_kernel_sweep(B, S, H, P, N, Q):
    ks = jax.random.split(jax.random.key(B * 100 + S), 4)
    xdt = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    b = jax.random.normal(ks[2], (B, S, N)) * 0.5
    c = jax.random.normal(ks[3], (B, S, N)) * 0.5
    y_ref, h_ref = ssd_scan_ref(xdt, a, b, c)
    blk = H if H % 2 else 2
    from repro.kernels.ssd.ops import ssd_scan as scan
    y, h = scan(xdt, a, b, c, chunk=Q, mode="interpret")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=3e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(1, 2), st.integers(1, 4), st.integers(1, 3),
       st.integers(1, 3))
def test_ssd_kernel_property(B, nc, h2, p2):
    H, P, N, Q = 2 * h2, 4 * p2, 8, 8
    S = nc * Q
    ks = jax.random.split(jax.random.key(B * 7 + S), 4)
    xdt = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    b = jax.random.normal(ks[2], (B, S, N)) * 0.5
    c = jax.random.normal(ks[3], (B, S, N)) * 0.5
    y_ref, h_ref = ssd_scan_ref(xdt, a, b, c)
    y, h = ssd_scan(xdt, a, b, c, chunk=Q, mode="interpret")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5)


@pytest.mark.parametrize("B,S,H,K,dh,causal", [
    (1, 128, 2, 2, 64, True),       # MHA causal
    (2, 256, 4, 2, 64, True),       # GQA (group-summed dk/dv)
    (1, 128, 4, 4, 64, False),      # bidirectional
    (1, 384, 2, 1, 64, True),       # MQA, 3 kv blocks
])
def test_flash_attention_bwd_kernel(B, S, H, K, dh, causal):
    """Pallas FA-2 backward == oracle VJP (dq, dk, dv)."""
    ks = jax.random.split(jax.random.key(B * S + H), 4)
    q = jax.random.normal(ks[0], (B, S, H, dh)) * 0.5
    k = jax.random.normal(ks[1], (B, S, K, dh)) * 0.5
    v = jax.random.normal(ks[2], (B, S, K, dh)) * 0.5
    ct = jax.random.normal(ks[3], (B, S, H, dh)) * 0.5
    g1 = jax.grad(lambda *a: jnp.sum(mha(*a, causal, "interpret") * ct),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: jnp.sum(mha_ref(*a, causal) * ct),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
