"""Serving plane: store-backed continuous batching with model hot-swap.

Four claim families over the serving tier (PR 7's tentpole):

* **Parity** — continuous batching is bit-identical to the paper's
  one-at-a-time ``put → run_model → get`` three-step baseline, on every
  deployment in {local, colocated, clustered}.
* **Hot-swap** — the trainer publishes versioned checkpoints into the
  model registry; the serving loop adopts a new generation ATOMICALLY
  between batches (never a torn (fn, params) pair), and mid-stream swaps
  yield responses bit-identical to the pre-/post-swap single-model
  baselines.
* **Recovery** — a crashed serving consumer re-cursors from the results
  watermark and answers every request exactly once, without re-binding
  the model (the swap count stays exactly what the plan predicted); a
  store restart mid-hot-swap replays the WAL and the registry (host
  memory) survives.
* **Plan exactness** — ``plan.explain()`` names request dispatches,
  drained batches and swaps, and each ``== StoreServer.stats()``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Client, StoreServer, TableSpec
from repro.core.faults import (FaultEvent, FaultPlan, InjectedCrash,
                               RetryPolicy)
from repro.insitu import (InSituSession, Producer, ServingClients,
                          ServingConsumer, TrainerConsumer)
from repro.insitu import plan as P
from repro.ml import autoencoder as ae
from repro.ml import trainer as tr
from repro.serve.engine import ServeLoop, request_key, submitted_meta
from repro.sim import flatplate as fp

SHAPE = (2, 4)
_DEPLOYMENTS = ("none", "colocated", "clustered")
_FAST_RETRY = RetryPolicy(interval=1e-4, max_interval=1e-3)


def _feed(c, s):
    return jnp.full(SHAPE, float(100 * c + s))


def _model(p, x):
    return p * x + 1.0


def _preload(server):
    server.set_model("m", _model, jnp.asarray(2.0))


def _make_deployment(kind):
    from repro.core.deployment import make_clustered_1d, make_colocated_1d
    if kind == "colocated":
        return make_colocated_1d(ndim=2)
    if kind == "clustered":
        return make_clustered_1d()
    return None


def _session(tier, deployment="none", *, clients=3, requests=4, max_batch=4,
             order_seed=None, faults=None, capacity=32):
    return InSituSession(
        tables=[TableSpec("req", shape=SHAPE, capacity=capacity,
                          engine="ring"),
                TableSpec("res", shape=SHAPE, capacity=capacity,
                          engine="ring")],
        components=[
            ServingClients(_feed, table="req", clients=clients,
                           requests=requests, submit=True, collect=False,
                           order_seed=order_seed, name="writers"),
            ServingConsumer("m", table="req", results="res",
                            clients=clients, requests=requests,
                            max_batch=max_batch, tier=tier),
            ServingClients(_feed, table="req", clients=clients,
                           requests=requests, submit=False, collect=True,
                           name="readers")],
        deployment=_make_deployment(deployment),
        faults=faults)


def _responses(res):
    return res.output("readers").responses


# ---------------------------------------------------------------------------
# parity: continuous batching == three-step baseline, bit for bit
# ---------------------------------------------------------------------------


class TestParity:
    @pytest.mark.parametrize("deployment", _DEPLOYMENTS)
    def test_bit_identical_across_tiers(self, deployment):
        """The fused gather → model → scatter drain returns byte-identical
        responses to the paper's three-step protocol, per deployment."""
        runs = {}
        for tier in ("continuous_batch", "three_step"):
            res = _session(tier, deployment).run(
                sequential=True, preload=_preload, max_wall_s=240)
            assert res.ok, {k: v.error
                            for k, v in res.run.components.items()}
            runs[tier] = _responses(res)
        a, b = runs["continuous_batch"], runs["three_step"]
        assert sorted(a) == sorted(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))
            np.testing.assert_array_equal(
                np.asarray(a[k]), np.asarray(_model(2.0, _feed(*k))))

    def test_arrival_order_invariance(self):
        """Any submission interleave yields the same responses AND the
        same drained-batch count (round-robin discovery canonicalizes
        admission order)."""
        base = None
        for seed in (None, 3, 99):
            res = _session("continuous_batch", order_seed=seed).run(
                sequential=True, preload=_preload, max_wall_s=240)
            assert res.ok
            assert res.output("serving").batches == 3  # ceil(12 / 4)
            out = _responses(res)
            if base is None:
                base = out
                continue
            assert sorted(out) == sorted(base)
            for k in base:
                np.testing.assert_array_equal(np.asarray(out[k]),
                                              np.asarray(base[k]))


# ---------------------------------------------------------------------------
# plan exactness and explain() fields
# ---------------------------------------------------------------------------


class TestPlanPrediction:
    def test_explain_names_serving_structure(self):
        sess = _session("continuous_batch", clients=3, requests=4,
                        max_batch=4)
        plan = sess.plan()
        ex = plan.explain()
        serving = ex["components"]["serving"]
        assert serving["requests"] == 12
        assert serving["drained_batches"] == 3
        assert serving["model_swaps"] == 1
        assert serving["dispatches_per_batch"] == 1.0
        assert ex["components"]["writers"]["requests"] == 12
        assert ex["model_swaps"] == 1
        assert "swaps=1" in plan.describe()

    def test_three_step_prediction(self):
        plan = _session("three_step", clients=2, requests=3).plan()
        serving = next(e for e in plan.components if e.name == "serving")
        # one get + one put per request, no fused dispatches, no swap
        assert serving.store_dispatches == 12
        assert serving.swaps == 0
        assert dict(serving.dispatches) == {"get": 6, "put": 6}

    def test_prediction_matches_measured(self):
        sess = _session("continuous_batch", clients=2, requests=5,
                        max_batch=3)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, preload=_preload,
                       max_wall_s=240)
        assert res.ok
        stats = res.server.stats()
        assert stats["op_count"] == plan.store_dispatches
        assert stats["model_swaps"] == plan.model_swaps == 1
        # 10 request puts + ceil(10/3)=4 fused serves + 10 response gets
        assert plan.store_dispatches == 24
        assert res.output("serving").batches == 4


# ---------------------------------------------------------------------------
# hot-swap: versioned checkpoints, atomic adoption, mid-stream parity
# ---------------------------------------------------------------------------


def _serve_pair(faults=None):
    server = StoreServer(faults=faults)
    server.create_table(TableSpec("req", shape=SHAPE, capacity=32,
                                  engine="ring"))
    server.create_table(TableSpec("res", shape=SHAPE, capacity=32,
                                  engine="ring"))
    return server, Client(server)


def _submit(server, client, c, s):
    client.put_kv("req", request_key(c, s), _feed(c, s))
    server.put_meta(submitted_meta("req", c), s + 1)


def _loop(client, **kw):
    args = dict(model_key="m", request_table="req", response_table="res",
                clients=2, requests=4, max_batch=2)
    args.update(kw)
    return ServeLoop(client, **args)


def _collect(client, clients, requests):
    return {(c, s): np.asarray(client.get_kv("res", request_key(c, s))[0])
            for c in range(clients) for s in range(requests)}


class TestHotSwap:
    def test_mid_stream_swap_matches_single_model_baselines(self):
        """Swap generations halfway: the first half of the responses is
        bit-identical to an all-v1 run, the second half to an all-v2 run
        — and the loop counts exactly two adoptions."""
        def run_single(param):
            server, client = _serve_pair()
            server.set_model("m", _model, jnp.asarray(param))
            for c in range(2):
                for s in range(4):
                    _submit(server, client, c, s)
            loop = _loop(client)
            loop.run(timeout=30.0)
            return _collect(client, 2, 4)

        v1, v2 = run_single(2.0), run_single(-3.0)

        server, client = _serve_pair()
        server.set_model("m", _model, jnp.asarray(2.0))
        for c in range(2):
            for s in range(2):
                _submit(server, client, c, s)
        loop = _loop(client)
        loop.wait_model(timeout=30.0)
        while loop.served < 4:
            loop.step()
        server.set_model("m", _model, jnp.asarray(-3.0))   # v2 published
        for c in range(2):
            for s in range(2, 4):
                _submit(server, client, c, s)
        while loop.served < 8:
            loop.step()
        assert loop.swaps == 2
        assert server.stats()["model_swaps"] == 2
        assert server.model_version("m") == 2
        got = _collect(client, 2, 4)
        for (c, s), v in got.items():
            ref = v1 if s < 2 else v2
            np.testing.assert_array_equal(v, ref[(c, s)])

    def test_adoption_is_atomic_never_torn(self):
        """Publish a fn+params pair per generation; every response must
        come from ONE generation (a torn pair would mix a stale fn with
        fresh params and match no generation's output)."""
        def gen_fn(k):
            return lambda p, x: float(k) * x + p

        server, client = _serve_pair()
        loop = _loop(client, clients=1, requests=6, max_batch=1,
                     reload_every=1)
        outputs = {}
        for k in range(1, 7):
            server.set_model("m", gen_fn(k), jnp.asarray(100.0 * k))
            outputs[k] = {
                s: np.asarray(gen_fn(k)(100.0 * k, _feed(0, s)))
                for s in range(6)}
        for s in range(6):
            _submit(server, client, 0, s)
            if s < 5:   # publish another generation between batches
                server.set_model("m", gen_fn(s + 7),
                                 jnp.asarray(100.0 * (s + 7)))
                outputs[s + 7] = {
                    t: np.asarray(gen_fn(s + 7)(100.0 * (s + 7),
                                                _feed(0, t)))
                    for t in range(6)}
        loop.wait_model(timeout=30.0)
        while loop.served < 6:
            loop.step()
        got = _collect(client, 1, 6)
        for (c, s), v in got.items():
            assert any(np.array_equal(v, gen[s])
                       for gen in outputs.values()), (c, s)

    def test_reload_every_batches(self):
        """``reload_every=N`` checks the registry every N batches (plus
        always before the first) — published generations between checks
        coalesce into one adoption."""
        server, client = _serve_pair()
        server.set_model("m", _model, jnp.asarray(2.0))
        loop = _loop(client, clients=1, requests=4, max_batch=1,
                     reload_every=4)
        loop.wait_model(timeout=30.0)
        for s in range(4):
            _submit(server, client, 0, s)
            loop.step()
            server.set_model("m", _model, jnp.asarray(float(s)))
        # one initial bind; batches 1..3 skip the version check
        assert loop.swaps == 1
        assert server.model_version("m") == 5

    def test_trainer_publishes_serving_adopts(self):
        """End-to-end hot-swap producer side: the trainer publishes a
        versioned checkpoint per epoch (``publish_every=1``); the serving
        consumer adopts the freshest generation exactly once in a
        sequential run, with the dispatch plan exact."""
        fcfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
        n = fcfg.n_points
        coords = fp.grid_coords(fcfg)
        cfg = tr.TrainerConfig(
            ae=ae.AEConfig(n_points=n, mode="ref", latent=4, internal=4,
                           blocks=1, mlp_width=8, mlp_depth=2),
            epochs=2, gather=4, batch_size=2, lr=1e-3, fused=True)
        snaps = [fp.snapshot(fcfg, jax.random.key(0), t) for t in range(8)]

        def sim_step(carry, rank, t):
            return carry, 0, jnp.stack(snaps)[t % 8]

        def serve_feed(c, s):
            return snaps[(3 * c + s) % 8].T[None]

        def make(tier):
            return InSituSession(
                tables=[
                    TableSpec("field", shape=(4, n), capacity=16,
                              engine="ring"),
                    TableSpec("sreq", shape=(1, n, 4), capacity=16,
                              engine="ring"),
                    TableSpec("sres", shape=(1, 4), capacity=16,
                              engine="ring")],
                components=[
                    Producer(sim_step, table="field", steps=8,
                             carry=jnp.zeros(())),
                    TrainerConsumer(cfg, coords, model_key="enc",
                                    publish_every=1),
                    ServingClients(serve_feed, table="sreq", clients=2,
                                   requests=3, submit=True, collect=False,
                                   name="writers"),
                    ServingConsumer("enc", table="sreq", results="sres",
                                    clients=2, requests=3, max_batch=4,
                                    tier=tier),
                    ServingClients(serve_feed, table="sreq", clients=2,
                                   requests=3, submit=False, collect=True,
                                   name="readers")])

        sess = make("continuous_batch")
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, max_wall_s=420)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        stats = res.server.stats()
        assert stats["op_count"] == plan.store_dispatches
        # 2 per-epoch publishes + the final publish = 3 generations; the
        # sequential drain adopts only the freshest — exactly one swap.
        assert res.server.model_version("enc") == 3
        assert res.output("serving").swaps == 1
        assert stats["model_swaps"] == plan.model_swaps == 1
        # the adopted generation IS the final one: responses match the
        # trained encoder applied to each request
        out = _responses(res)
        state = res.output("trainer").state
        levels = ae.coords_pyramid(cfg.ae, coords)
        for (c, s), v in out.items():
            ref = ae.encode(state.params, cfg.ae, levels, serve_feed(c, s))
            np.testing.assert_allclose(np.asarray(v), np.asarray(ref),
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# recovery: crashes and restarts answer exactly once, no torn version
# ---------------------------------------------------------------------------


@pytest.mark.chaos
class TestServingRecovery:
    def test_crash_recovers_exactly_once(self):
        """A serving crash mid-drain re-cursors from the results
        watermark: every request answered once, no extra dispatches, no
        extra swap."""
        faults = FaultPlan(events=(
            FaultEvent("crash", component="serving", at=1),),
            retry=_FAST_RETRY)
        sess = _session("continuous_batch", faults=faults)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, preload=_preload,
                       max_wall_s=240)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        assert res.run.components["serving"].restarts == 1
        stats = res.server.stats()
        assert stats["op_count"] == plan.store_dispatches
        assert stats["model_swaps"] == 1        # recovery never re-binds
        assert res.server.watermark("res") == 12
        out = _responses(res)
        assert len(out) == 12
        for k, v in out.items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(_model(2.0, _feed(*k))))

    def test_store_restart_mid_hot_swap(self):
        """A store restart BETWEEN publishing v2 and its adoption: the
        registry (host memory) and the WAL-replayed tables survive; the
        loop adopts v2 exactly once and no response mixes generations."""
        faults = FaultPlan(events=(
            FaultEvent("snapshot", table="res", at=1),
            FaultEvent("restart", table="res", at=2)), retry=_FAST_RETRY)
        server, client = _serve_pair(faults=faults)
        server.set_model("m", _model, jnp.asarray(2.0))
        for c in range(2):
            for s in range(4):
                _submit(server, client, c, s)
        loop = _loop(client, max_batch=2)
        loop.wait_model(timeout=30.0)
        loop.step()                                   # commit 1: snapshot
        server.set_model("m", _model, jnp.asarray(-3.0))   # v2 published
        loop.step()                       # commit 2: restart + WAL replay
        assert server.stats()["recoveries"] == 1
        while loop.served < 8:
            loop.step()
        assert loop.swaps == 2
        assert loop._version == server.model_version("m") == 2
        got = _collect(client, 2, 4)
        # first drained batch (admission order (0,0),(1,0)) answered by
        # v1; everything after the publish by v2 — nothing torn
        for (c, s), v in got.items():
            ref = _model(2.0 if s == 0 else -3.0, _feed(c, s))
            np.testing.assert_array_equal(v, np.asarray(ref))
        assert server.watermark("res") == 8

    def test_dropped_response_transfer_retries(self):
        """A dropped serve-commit transfer is retried with the same chunk
        id (exactly-once): responses complete and match the fault-free
        values."""
        faults = FaultPlan(events=(
            FaultEvent("drop_chunk", table="res", at=1),
            FaultEvent("unavailable", verb="serve", at=2, count=1)),
            retry=_FAST_RETRY)
        sess = _session("continuous_batch", faults=faults)
        plan = sess.plan()
        res = sess.run(plan=plan, sequential=True, preload=_preload,
                       max_wall_s=240)
        assert res.ok, {k: v.error for k, v in res.run.components.items()}
        assert res.run.components["serving"].retries == \
            next(e for e in plan.components if e.name == "serving").retries
        assert res.server.stats()["op_count"] == plan.store_dispatches
        for k, v in _responses(res).items():
            np.testing.assert_array_equal(np.asarray(v),
                                          np.asarray(_model(2.0, _feed(*k))))


# ---------------------------------------------------------------------------
# session validation
# ---------------------------------------------------------------------------


class TestValidation:
    def _tables(self, engine="ring", capacity=32):
        return [TableSpec("req", shape=SHAPE, capacity=capacity,
                          engine=engine),
                TableSpec("res", shape=SHAPE, capacity=capacity,
                          engine="ring")]

    def test_component_field_validation(self):
        with pytest.raises(ValueError):
            ServingConsumer("m", table="t", results="t")
        with pytest.raises(ValueError):
            ServingClients(_feed, table="t", submit=False, collect=False)
        with pytest.raises(ValueError):
            ServingConsumer("m", table="a", results="b", max_batch=0)
        with pytest.raises(ValueError):
            TrainerConsumer(tr.TrainerConfig(
                ae=ae.AEConfig(n_points=8)), None, publish_every=1)

    def test_requires_ring_engine(self):
        with pytest.raises(ValueError, match="ring"):
            InSituSession(
                tables=self._tables(engine="hash"),
                components=[ServingConsumer("m", table="req",
                                            results="res")])

    def test_requires_capacity(self):
        with pytest.raises(ValueError, match="capacity"):
            InSituSession(
                tables=self._tables(capacity=4),
                components=[ServingConsumer("m", table="req",
                                            results="res", clients=3,
                                            requests=4)])

    def test_requires_matching_submitter(self):
        with pytest.raises(ValueError, match="submit"):
            InSituSession(
                tables=self._tables(),
                components=[ServingConsumer("m", table="req",
                                            results="res")])
        with pytest.raises(ValueError, match="clients"):
            InSituSession(
                tables=self._tables(),
                components=[
                    ServingClients(_feed, table="req", clients=2,
                                   requests=4),
                    ServingConsumer("m", table="req", results="res",
                                    clients=3, requests=4)])

    def test_collect_requires_consumer(self):
        with pytest.raises(ValueError, match="drains"):
            InSituSession(
                tables=[TableSpec("req", shape=SHAPE, capacity=32,
                                  engine="ring")],
                components=[ServingClients(_feed, table="req")])

    def test_forced_tier_validated(self):
        with pytest.raises(ValueError):
            P.serving_tier(ServingConsumer("m", table="a", results="b",
                                           tier="nope"))
        assert P.serving_tier(
            ServingConsumer("m", table="a", results="b")) \
            == "continuous_batch"
