"""CFD substrate: spectral solver exactness + flat-plate generator."""

import jax
import jax.numpy as jnp
import math
import numpy as np
import pytest

from repro.sim import flatplate as fp
from repro.sim import spectral as sp


@pytest.fixture(scope="module")
def cfg():
    return sp.NSConfig(n=16, nu=0.05, dt=0.01)


def test_tgv2d_exact_decay(cfg):
    """2-D Taylor-Green is an exact NS solution: E(t) = E0·e^{-4νt}."""
    state = sp.taylor_green_2d(cfg)
    e0 = float(sp.energy(cfg, state))
    for _ in range(20):
        state = sp.step(cfg, state)
    e = float(sp.energy(cfg, state))
    expected = e0 * math.exp(-4 * cfg.nu * float(state.t))
    assert abs(e - expected) / expected < 1e-5


def test_divergence_free(cfg):
    state = sp.taylor_green(cfg)
    for _ in range(10):
        state = sp.step(cfg, state)
    assert float(sp.max_divergence(cfg, state)) < 1e-10


def test_energy_monotone_decay_unforced(cfg):
    state = sp.taylor_green(cfg)
    es = [float(sp.energy(cfg, state))]
    for _ in range(8):
        state = sp.step(cfg, state)
        es.append(float(sp.energy(cfg, state)))
    assert all(a >= b for a, b in zip(es, es[1:]))


def test_forcing_sustains_energy():
    cfg = sp.NSConfig(n=16, nu=0.02, dt=0.01, forcing=True, f_amp=0.15)
    state = sp.random_turbulence(cfg, jax.random.key(0), e0=0.3)
    e0 = float(sp.energy(cfg, state))
    for _ in range(30):
        state = sp.step(cfg, state)
    e = float(sp.energy(cfg, state))
    assert e > 0.2 * e0            # forced flow does not die out


def test_snapshot_shape_and_finite(cfg):
    state = sp.taylor_green(cfg)
    snap = sp.snapshot(cfg, state)
    assert snap.shape == (4, cfg.n_points)
    assert bool(jnp.isfinite(snap).all())
    # pressure gauge: zero mean
    assert abs(float(snap[0].mean())) < 1e-6


def test_partition_snapshot_roundtrip(cfg):
    state = sp.taylor_green(cfg)
    snap = sp.snapshot(cfg, state)
    parts = sp.partition_snapshot(snap, 8)
    assert parts.shape == (8, 4, cfg.n_points // 8)
    rebuilt = parts.transpose(1, 0, 2).reshape(4, -1)
    np.testing.assert_allclose(np.asarray(rebuilt), np.asarray(snap))


class TestFlatPlate:
    def test_shapes_and_coords(self):
        cfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        coords = fp.grid_coords(cfg)
        snap = fp.snapshot(cfg, jax.random.key(0), 0)
        assert coords.shape == (cfg.n_points, 3)
        assert snap.shape == (4, cfg.n_points)
        assert bool(jnp.isfinite(snap).all())

    def test_wall_normal_stretching(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=16, nz=2)
        coords = fp.grid_coords(cfg)
        y = np.unique(np.asarray(coords[:, 1]))
        dy = np.diff(y)
        assert dy[0] < dy[-1] * 0.5          # clustered at the wall

    def test_temporal_correlation(self):
        cfg = fp.FlatPlateConfig(nx=8, ny=8, nz=4)
        s0 = fp.snapshot(cfg, jax.random.key(0), 0)
        s1 = fp.snapshot(cfg, jax.random.key(0), 1)
        s9 = fp.snapshot(cfg, jax.random.key(0), 40)
        c1 = float(jnp.corrcoef(s0[1], s1[1])[0, 1])
        c9 = float(jnp.corrcoef(s0[1], s9[1])[0, 1])
        assert c1 > 0.9 and c9 < c1          # decorrelates over time

    def test_deterministic(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
        a = fp.snapshot(cfg, jax.random.key(3), 7)
        b = fp.snapshot(cfg, jax.random.key(3), 7)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_batch(self):
        cfg = fp.FlatPlateConfig(nx=4, ny=4, nz=2)
        batch = fp.snapshot_batch(cfg, jax.random.key(0), 0, 3)
        assert batch.shape == (3, 4, cfg.n_points)
